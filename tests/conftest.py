import dataclasses

import jax
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace

jax.config.update("jax_platform_name", "cpu")

TINY_PERF = perf_replace(DEFAULT_PERF, scan_chunk=32, remat="none",
                         block_q=64, block_k=64)


def tiny_config(arch: str = "llama3.2-3b"):
    cfg = reduced(get_config(arch))
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="session")
def tiny_llama():
    cfg = tiny_config("llama3.2-3b")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0), cfg.dtype)
    return cfg, params

"""Dry-run machinery integration: one representative cell per step kind
lowers + compiles on the production meshes (subprocess with 512 fake
devices), producing memory/cost/roofline records — the deliverable-(e)
pipeline exercised inside the test suite."""
import json
import os
import subprocess
import sys

import pytest


def _run_cells(cells, mesh):
    code = f"""
import json
from repro.launch.dryrun import run_cell
out = []
for arch, shape in {cells!r}:
    rec = run_cell(arch, shape, {mesh!r} == "multi")
    out.append(rec)
print("CELLJSON:" + json.dumps(out))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # run_cell is imported from dryrun, whose first lines set XLA_FLAGS
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("CELLJSON:")]
    return json.loads(line[0][len("CELLJSON:"):])


@pytest.mark.slow
def test_dryrun_cells_compile_single_pod():
    recs = _run_cells([("llama3.2-3b", "train_4k"),
                       ("llama3.2-3b", "decode_32k"),
                       ("xlstm-350m", "prefill_32k")], "single")
    for rec in recs:
        assert rec["applicable"] and "error" not in rec, rec
        assert rec["n_chips"] == 256
        r = rec["roofline"]
        assert r["compute_s"] > 0 and r["memory_s"] > 0
        assert r["dominant"] in ("compute_s", "memory_s", "collective_s")
        assert 0 < r["useful_flop_ratio"] < 2.0
        assert rec["memory"]["per_device_bytes"] > 0
    # the 3B train cell must fit a 16 GiB chip
    assert recs[0]["memory"]["fits_hbm"]


@pytest.mark.slow
def test_dryrun_multi_pod_shards_pod_axis():
    recs = _run_cells([("llama3.2-3b", "train_4k")], "multi")
    rec = recs[0]
    assert rec["n_chips"] == 512 and "error" not in rec
    # cross-pod (DCN) traffic exists: gradients sync over the pod axis
    assert rec["hlo"]["coll_dcn_bytes"] > 0
    assert rec["memory"]["fits_hbm"]


def test_dryrun_skips_are_recorded():
    from repro.configs import SHAPES, cell_applicability, get_config
    ok, reason = cell_applicability(get_config("hubert-xlarge"),
                                    SHAPES["decode_32k"])
    assert not ok and "encoder-only" in reason
    ok, reason = cell_applicability(get_config("phi3-medium-14b"),
                                    SHAPES["long_500k"])
    assert not ok and "sub-quadratic" in reason

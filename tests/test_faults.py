"""Fault-injection harness (core/faults.py): conformance of the
fault-free wrapper for every backend kind, deterministic replayable
injection schedules, loud failure on wedges, and seeded chaos fuzz
whose failing plans are dumped as replayable JSON artifacts."""
import dataclasses
import os

import pytest

from repro.core import domains as D
from repro.core.cgroup import AgentCgroup, DomainSpec, HostTreeBackend
from repro.core.daemon import AsyncDaemonBackend, DaemonError
from repro.core.escalation import (EscalationExhausted, EscalationPolicy,
                                   Escalator)
from repro.core.faults import (FaultPlan, FaultyBackend,
                               TransientBackendError)
from repro.testing.conformance import (BACKEND_KINDS, ConformanceSuite,
                                       backend_features,
                                       faulty_backend_factory)

SUITE = ConformanceSuite()


# ------------------------------------------------------------- conformance


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_fault_free_wrapper_is_conformant(kind):
    """With the default (no-fault) plan, FaultyBackend around every
    backend kind is bit-exact with the reference — the wrapper itself
    perturbs nothing."""
    report = SUITE.run(faulty_backend_factory(kind),
                       features=backend_features(kind))
    assert report.ok, report.summary()


def test_transient_plan_with_auto_retry_is_conformant():
    """A transient-only plan + auto_retry self-heals into the identical
    run: transients fire BEFORE the inner op, so the retried op applies
    exactly once."""
    plan = FaultPlan(seed=7, p_transient=0.5)
    report = SUITE.run(
        faulty_backend_factory("host", plan=plan, auto_retry=1),
        features=backend_features("host"))
    assert report.ok, report.summary()


# ------------------------------------------------------------ determinism


def test_fault_plan_json_roundtrip():
    plan = FaultPlan(seed=42, p_transient=0.25, p_delay=0.1, delay_s=0.002,
                     p_spurious_kill=0.05, p_wedge=0.01, wedge_s=0.5,
                     ops=("mkdir", "kill"))
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert FaultPlan.from_json(FaultPlan().to_json()) == FaultPlan()


def _scripted_run(plan: FaultPlan) -> list:
    be = FaultyBackend(HostTreeBackend(500), plan)
    cg = AgentCgroup(be)
    for i in range(4):
        try:
            cg.mkdir(f"/s{i}", DomainSpec(high=60))
        except TransientBackendError:
            continue
        for step, mb in ((0, 30), (1, 20), (2, 40)):
            try:
                cg.try_charge(f"/s{i}", mb, step=step)
            except TransientBackendError:
                pass
    return list(be.injected)


def test_injection_schedule_is_deterministic():
    """Same plan + same op sequence -> identical injected faults: every
    chaos failure replays from the plan alone."""
    plan = FaultPlan(seed=3, p_transient=0.3, p_delay=0.2, delay_s=0.0001,
                     p_spurious_kill=0.1)
    a = _scripted_run(plan)
    b = _scripted_run(plan)
    assert a == b
    assert a                                 # something actually fired
    assert _scripted_run(FaultPlan(seed=4, p_transient=0.3, p_delay=0.2,
                                   delay_s=0.0001,
                                   p_spurious_kill=0.1)) != a


def test_transient_raised_before_inner_op_applies():
    plan = FaultPlan(seed=0, p_transient=1.0, ops=("try_charge",))
    cg = AgentCgroup(FaultyBackend(HostTreeBackend(500), plan))
    cg.mkdir("/s")                           # not in ops: untouched
    with pytest.raises(TransientBackendError):
        cg.try_charge("/s", 30)
    assert cg.usage("/s") == 0               # the op did NOT apply


# ---------------------------------------------------------- loud failure


def test_wedge_inside_async_daemon_poisons_loudly():
    """A wedged op on the daemon thread times the flush out: the caller
    gets DaemonError (not a hang), and the backend stays poisoned until
    closed and rebuilt — the engine's rebuild path recovers from this
    exact state."""
    plan = FaultPlan(seed=0, p_wedge=1.0, wedge_s=30.0, ops=("freeze",))
    faulty = FaultyBackend(HostTreeBackend(500), plan)
    be = AsyncDaemonBackend(faulty, flush_timeout_s=0.3)
    cg = AgentCgroup(be)
    cg.mkdir("/s")
    cg.freeze("/s")                          # queues; daemon wedges on apply
    with pytest.raises(DaemonError, match="timed out"):
        cg.flush()
    with pytest.raises(DaemonError, match="close and rebuild"):
        cg.mkdir("/t")                       # poisoned: loud, never silent
    faulty.unwedge()
    be.close(flush=False)


def test_spurious_kill_routes_into_escalation_and_recovers():
    """An injected out-of-band kill (kernel OOM analogue) lands on the
    open lease; note_external_kill synthesizes the typed OomEvent and
    the escalation loop retries the call at a negotiated limit."""
    holder = {}
    plan = FaultPlan(seed=0, p_spurious_kill=1.0, ops=("uncharge",))
    be = FaultyBackend(
        HostTreeBackend(1000), plan,
        on_spurious_kill=lambda p, f:
            holder["cg"].intent.note_external_kill(p, freed=f))
    cg = AgentCgroup(be)
    holder["cg"] = cg
    cg.mkdir("/s")
    cg.try_charge("/s", 10)
    lease = cg.intent.declare("tool_1", None, parent="/s", high=50, max=50)
    cg.try_charge(lease.path, 30)
    cg.uncharge("/s", 5)                     # injection point: kills the lease
    assert lease.killed and lease.oom is not None
    assert lease.oom.residual_pages == 30    # freed routed via the callback
    new, neg = Escalator(cg, EscalationPolicy()).escalate(lease)
    assert new.attempt == 2 and neg.grant_pages == 100
    assert cg.read(new.path, "memory.max") == 100
    new.close()


# --------------------------------------- sharded reconciliation under chaos


def test_sharded_reconcile_transient_between_shard_gathers():
    """Chaos at the reconciliation seam: FaultPlan-driven transients
    fire BETWEEN the per-shard gathers of ``reconcile`` (the
    ``reconcile_hook`` seam), so a root read can fail mid-gather.
    Retrying converges to the exact total — the interrupted gather
    never perturbed state."""
    from repro.core.sharded import ShardedTableBackend
    inner = ShardedTableBackend(500, n_domains=16)
    plan = FaultPlan(seed=3, p_transient=0.4, ops=("reconcile",))
    injector = FaultyBackend(inner, plan)

    def hook(shard):
        # draw once per reconcile (shard 0) so the seeded schedule is
        # identical whatever the device count
        if shard == 0 and injector._pre_fault("reconcile"):
            raise TransientBackendError(
                f"injected between shard gathers (shard {shard})")

    inner.reconcile_hook = hook
    cg = AgentCgroup(injector)
    cg.mkdir("/t0")
    cg.mkdir("/t1")
    assert cg.try_charge("/t0", 40).granted
    assert cg.try_charge("/t1", 25).granted
    fired, total = 0, None
    for _ in range(32):
        try:
            total = cg.usage("/")
            break
        except TransientBackendError:
            fired += 1
    assert total == 65
    assert fired > 0                     # the seam actually fired (seeded)
    inner.reconcile_hook = None
    assert cg.usage("/") == 65


def test_sharded_reconcile_concurrent_lifecycle_op():
    """A lifecycle op landing between shard gathers (the async-daemon
    interleaving) leaves accounting consistent: the mid-reconciliation
    read sees the pre- or post-op total (never garbage), and a clean
    re-read returns the exact post-op value."""
    from repro.core.sharded import ShardedTableBackend
    inner = ShardedTableBackend(500, n_domains=16)
    cg = AgentCgroup(inner)
    cg.mkdir("/t0")
    assert cg.try_charge("/t0", 60).granted
    fired = []

    def hook(shard):
        if not fired:                    # one-shot: lands mid-gather once
            fired.append(shard)
            inner.uncharge("/t0", 10)

    inner.reconcile_hook = hook
    mid = cg.usage("/")
    assert mid in (50, 60)
    inner.reconcile_hook = None
    assert fired and cg.usage("/") == 50
    assert cg.usage("/t0") == 50


# ------------------------------------------- freeze/offload chaos points


def _freeze_script(plan: FaultPlan) -> tuple:
    """Charge a session, freeze it, observe — returns (injected, cg)."""
    be = FaultyBackend(HostTreeBackend(500), plan)
    cg = AgentCgroup(be)
    cg.mkdir("/s")
    cg.mkdir("/s/sess", DomainSpec(high=100))
    cg.try_charge("/s/sess", 80, step=0)
    cg.freeze("/s/sess")
    return list(be.injected), cg


def test_kill_mid_freeze_deterministic():
    """p_kill_mid_freeze: the subtree dies while the freezer quiesces —
    usage is released BEFORE the freeze applies, the domain ends both
    killed and frozen (denying charges), and the schedule replays
    identically from the plan alone."""
    plan = FaultPlan(seed=11, p_kill_mid_freeze=1.0)
    injected, cg = _freeze_script(plan)
    assert [(op, fault, d) for _, op, fault, d in injected] == \
        [("freeze", "kill_mid_freeze", "/s/sess")]
    assert cg.usage("/") == 0                # the kill released the pages
    assert cg.read("/s/sess", "cgroup.freeze") == 1
    t = cg.try_charge("/s/sess", 1, step=1)  # dead AND frozen: denied
    assert not t.granted
    assert _freeze_script(plan)[0] == injected      # replayable


def test_kill_mid_freeze_hook_and_stream_isolation():
    """The kill routes through on_spurious_kill (escalation's entry
    point), and enabling the new chaos points does not shift the
    original four-draw schedule of an existing plan."""
    seen = []
    plan = FaultPlan(seed=11, p_kill_mid_freeze=1.0)
    be = FaultyBackend(HostTreeBackend(500), plan,
                       on_spurious_kill=lambda p, f: seen.append((p, f)))
    cg = AgentCgroup(be)
    cg.mkdir("/s")
    cg.try_charge("/s", 40, step=0)
    cg.freeze("/s")
    assert seen == [("/s", 40)]
    # separate stream: the classic fault schedule is unchanged
    base = FaultPlan(seed=3, p_transient=0.3, p_delay=0.2, delay_s=0.0001,
                     p_spurious_kill=0.1)
    with_chaos = dataclasses.replace(base, p_kill_mid_freeze=1.0,
                                     p_offload_transient=1.0)
    assert _scripted_run(base) == _scripted_run(with_chaos)


def test_offload_transient_leaves_no_partial_entry():
    """p_offload_transient through the FrozenStore.offload_hook seam:
    the device->host offload fails BEFORE the entry commits — the
    store is untouched (no partial entry, no accounting drift) and the
    retry freezes exactly once."""
    import numpy as np

    from repro.core.freezer import FrozenStore

    plan = FaultPlan(seed=5, p_offload_transient=1.0)
    faulty = FaultyBackend(HostTreeBackend(500), plan)
    store = FrozenStore()
    store.offload_hook = faulty.offload_fault
    blob = {"kv": np.ones((4, 4), np.float32)}
    with pytest.raises(TransientBackendError):
        store.freeze("sess_1", blob, pages=10, now=3.0)
    assert not store.is_frozen("sess_1")     # nothing committed
    assert store.n_freezes == 0 and store.bytes_held == 0
    assert [(op, fault, d) for _, op, fault, d in faulty.injected] == \
        [("offload", "transient", "sess_1")]
    store.offload_hook = None                # transient cleared: retry
    store.freeze("sess_1", blob, pages=10, now=4.0)
    assert store.is_frozen("sess_1") and store.n_freezes == 1
    entry = store.thaw("sess_1")
    assert entry.pages == 10 and entry.frozen_at == 4.0


def test_chaos_plan_json_roundtrip_and_back_compat():
    """The new chaos fields survive the JSON artifact roundtrip, and a
    pre-chaos artifact (no such keys) loads with them defaulted off."""
    import json

    plan = FaultPlan(seed=9, p_kill_mid_freeze=0.2, p_offload_transient=0.3)
    assert FaultPlan.from_json(plan.to_json()) == plan
    old = json.loads(FaultPlan(seed=9).to_json())
    del old["p_kill_mid_freeze"], old["p_offload_transient"]
    assert FaultPlan.from_json(json.dumps(old)) == FaultPlan(seed=9)


def test_replay_over_faulty_backend_bit_identical():
    """The whole §6 trace-replay simulation driven over a FaultyBackend
    (``Replay(..., backend=...)``) with a transient-only plan and
    auto-retry: every injected transient self-heals before the op
    applies, so the full result — survival, latencies, peaks, per-task
    outcomes — is bit-identical to the default run."""
    from repro.core.policy import AgentCgroupPolicy
    from repro.traces.generator import named_trace
    from repro.traces.replay import Replay, ReplayConfig

    def results(backend=None):
        tr = [named_trace("dask/dask#11628", seed=1),
              named_trace("sigmavirus24/github3.py#673", seed=2)]
        r = Replay(tr, [D.HIGH, D.LOW], AgentCgroupPolicy(),
                   ReplayConfig(capacity_mb=1100), backend=backend).run()
        hi = r.latency_of(D.HIGH)
        return (r.survival, r.throttle_count, r.peak_pool_mb,
                hi.p50, hi.p95,
                {k: (v.completed, v.killed, v.finish_ms)
                 for k, v in r.tasks.items()})

    want = results()
    plan = FaultPlan(seed=11, p_transient=0.2)
    faulty = FaultyBackend(HostTreeBackend(1100), plan, auto_retry=1)
    assert results(faulty) == want
    assert any(f == "transient" for _, _, f, _ in faulty.injected)


# -------------------------------------------------------------- chaos fuzz


def _chaos_plan(seed: int) -> FaultPlan:
    return FaultPlan(seed=seed, p_transient=0.15, p_delay=0.05,
                     delay_s=0.0002, p_spurious_kill=0.08)


def _chaos_run(plan: FaultPlan) -> int:
    """A lease-heavy workload under the plan's faults.  Transients
    self-heal (auto_retry), spurious kills route into escalation; the
    run must end with clean accounting or have failed loudly."""
    holder = {}
    be = FaultyBackend(
        HostTreeBackend(1000), plan, auto_retry=1,
        on_spurious_kill=lambda p, f:
            holder["cg"].intent.note_external_kill(p, freed=f))
    cg = AgentCgroup(be)
    holder["cg"] = cg
    esc = Escalator(cg, EscalationPolicy(max_attempts=3))
    cg.mkdir("/s", DomainSpec(max=600))
    clock = 0.0
    completed = 0
    for i in range(6):
        lease = cg.intent.declare(f"tool_{i}", None, parent="/s",
                                  high=40, max=40)
        need = 30 + 15 * (i % 3)             # some calls exceed the max
        charged = 0
        for _ in range(30):
            if charged >= need or lease.closed:
                break
            if lease.killed:
                try:
                    lease, _ = esc.escalate(lease)
                except EscalationExhausted:
                    break
                charged = 0
                continue
            clock += 500.0                   # expire throttle windows
            cg.set_time(clock)
            if cg.usage(lease.path) + 10 > lease.max:
                cg.kill(lease.path)          # memcg-max breach -> semantic OOM
                continue
            if cg.try_charge(lease.path, 10).granted:
                charged += 10
        if not lease.closed:
            if charged >= need and not lease.killed:
                completed += 1
            lease.close()
    # invariants: every lease resolved, accounting sane and bounded
    assert cg.intent.open_leases() == []
    assert 0 <= cg.usage("/") <= 1000
    assert cg.usage("/s") == cg.usage("/")
    return completed


CHAOS_SEEDS = list(range(8))


@pytest.mark.parametrize("seed", CHAOS_SEEDS)
def test_chaos_fuzz_invariants_hold(seed):
    """Seeded chaos sweep.  A failing seed dumps its FaultPlan JSON to
    ``$CHAOS_ARTIFACT_DIR`` (or cwd) — replay the failure with exactly
    that plan via ``FaultPlan.from_json``."""
    plan = _chaos_plan(seed)
    try:
        _chaos_run(plan)
    except BaseException:
        art = os.environ.get("CHAOS_ARTIFACT_DIR", ".")
        os.makedirs(art, exist_ok=True)
        with open(os.path.join(art, f"chaos-faultplan-{seed}.json"),
                  "w") as f:
            f.write(plan.to_json())
        raise


def test_chaos_fuzz_hypothesis():
    """Property-based sweep over plan space (skips when hypothesis is
    not installed; the seeded sweep above always runs)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None)
    @hyp.given(seed=st.integers(min_value=0, max_value=2**31 - 1),
               p_tr=st.floats(0.0, 0.4), p_ki=st.floats(0.0, 0.2))
    def prop(seed, p_tr, p_ki):
        plan = FaultPlan(seed=seed, p_transient=p_tr, p_delay=0.02,
                         delay_s=0.0001, p_spurious_kill=p_ki)
        try:
            _chaos_run(plan)
        except BaseException:
            art = os.environ.get("CHAOS_ARTIFACT_DIR", ".")
            os.makedirs(art, exist_ok=True)
            with open(os.path.join(art, f"chaos-faultplan-{seed}.json"),
                      "w") as f:
                f.write(plan.to_json())
            raise

    prop()

"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode, plus the custom-VJP flash backward vs autodiff of the naive
oracle (assignment req: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_decode_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import ssd_pallas

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype, k):
    return jax.random.normal(jax.random.fold_in(KEY, k), shape, jnp.float32
                             ).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hkv,dk,causal", [
    (1, 128, 4, 4, 32, True),       # MHA
    (2, 256, 8, 2, 64, True),       # GQA 4:1
    (1, 128, 6, 2, 80, False),      # non-causal, odd head_dim
    (2, 192, 4, 1, 64, True),       # MQA, non-pow2 seq
])
def test_flash_attention_pallas(B, S, H, hkv, dk, causal, dtype):
    q = rand((B, S, H, dk), dtype, 1)
    k = rand((B, S, hkv, dk), dtype, 2)
    v = rand((B, S, hkv, dk), dtype, 3)
    want = ref.attention_naive(q, k, v, causal=causal)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,hkv,dk,Smax", [
    (2, 8, 4, 64, 256), (1, 4, 4, 32, 128), (3, 6, 2, 128, 192),
])
def test_decode_attention_pallas(B, H, hkv, dk, Smax, dtype):
    q = rand((B, H, dk), dtype, 4)
    kc = rand((B, Smax, hkv, dk), dtype, 5)
    vc = rand((B, Smax, hkv, dk), dtype, 6)
    lengths = jnp.arange(1, B + 1) * (Smax // (B + 1))
    want = ref.decode_attention_ref(q, kc, vc, lengths, block_s=64)
    got = decode_attention_pallas(q, kc, vc, lengths, block_s=64,
                                  interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("page,npp", [(16, 8), (32, 4)])
def test_paged_decode_pallas(page, npp):
    B, H, hkv, dk = 2, 8, 4, 64
    n_pages = 64
    q = rand((B, H, dk), jnp.float32, 7)
    kp = rand((n_pages, page, hkv, dk), jnp.float32, 8)
    vp = rand((n_pages, page, hkv, dk), jnp.float32, 9)
    pt = jax.random.permutation(KEY, n_pages)[: B * npp].reshape(B, npp)
    lengths = jnp.array([page * npp // 2 + 3, page * npp], jnp.int32)
    want = ref.paged_decode_attention_ref(q, kp, vp, pt, lengths)
    got = paged_decode_attention_pallas(q, kp, vp, pt, lengths,
                                        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


@pytest.mark.parametrize("b,s,nh,dh,N,chunk", [
    (1, 128, 2, 32, 16, 32), (2, 64, 4, 16, 8, 64), (1, 96, 1, 64, 4, 32),
])
def test_ssd_pallas(b, s, nh, dh, N, chunk):
    x = rand((b, s, nh, dh), jnp.float32, 10)
    dt = jax.nn.softplus(rand((b, s, nh), jnp.float32, 11))
    A = -jnp.exp(rand((nh,), jnp.float32, 12) * 0.5)
    Bm = rand((b, s, N), jnp.float32, 13)
    Cm = rand((b, s, N), jnp.float32, 14)
    Dm = rand((nh,), jnp.float32, 15)
    want_y, want_h = ref.ssd_sequential(x, dt, A, Bm, Cm, Dm)
    got_y, got_h = ssd_pallas(x, dt, A, Bm, Cm, Dm, chunk=chunk,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               atol=2e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                               atol=2e-3, rtol=1e-3)


def test_ssd_chunked_matches_sequential():
    b, s, nh, dh, N = 2, 128, 2, 16, 8
    x = rand((b, s, nh, dh), jnp.float32, 16)
    dt = jax.nn.softplus(rand((b, s, nh), jnp.float32, 17))
    A = -jnp.exp(rand((nh,), jnp.float32, 18) * 0.5)
    Bm = rand((b, s, N), jnp.float32, 19)
    Cm = rand((b, s, N), jnp.float32, 20)
    Dm = rand((nh,), jnp.float32, 21)
    y0, h0 = ref.ssd_sequential(x, dt, A, Bm, Cm, Dm)
    y1, h1 = ref.ssd_chunked(x, dt, A, Bm, Cm, Dm, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-3,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=2e-3,
                               rtol=1e-3)


def test_mlstm_chunked_matches_sequential():
    b, s, nh, dh = 2, 128, 2, 16
    q = rand((b, s, nh, dh), jnp.float32, 22)
    k = rand((b, s, nh, dh), jnp.float32, 23)
    v = rand((b, s, nh, dh), jnp.float32, 24)
    ig = rand((b, s, nh), jnp.float32, 25)
    fg = rand((b, s, nh), jnp.float32, 26) + 2.0
    y0, (C0, n0, m0) = ref.mlstm_sequential(q, k, v, ig, fg)
    y1, (C1, n1, m1) = ref.mlstm_chunked(q, k, v, ig, fg, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(C1), np.asarray(C0), atol=2e-3,
                               rtol=1e-3)


def test_flash_custom_vjp_matches_autodiff():
    B, S, H, hkv, dk = 2, 128, 4, 2, 32
    q = rand((B, S, H, dk), jnp.float32, 27)
    k = rand((B, S, hkv, dk), jnp.float32, 28)
    v = rand((B, S, hkv, dk), jnp.float32, 29)
    ct = rand((B, S, H, dk), jnp.float32, 30)
    for causal in (True, False):
        g0 = jax.grad(lambda *a: (ref.attention_naive(
            *a, causal=causal) * ct).sum(), argnums=(0, 1, 2))(q, k, v)
        g1 = jax.grad(lambda *a: (ref.flash_attention_blockwise(
            *a, causal=causal, block_q=32, block_k=64) * ct).sum(),
            argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g0, g1):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                       atol=1e-4, rtol=1e-3)


def test_decode_matches_full_attention():
    """Decode against a cache == last row of full causal attention."""
    B, S, H, hkv, dk = 1, 33, 4, 2, 16
    q = rand((B, S, H, dk), jnp.float32, 31)
    k = rand((B, S, hkv, dk), jnp.float32, 32)
    v = rand((B, S, hkv, dk), jnp.float32, 33)
    full = ref.attention_naive(q, k, v, causal=True)
    Smax = 64
    kc = jnp.zeros((B, Smax, hkv, dk)).at[:, :S].set(k)
    vc = jnp.zeros((B, Smax, hkv, dk)).at[:, :S].set(v)
    got = ref.decode_attention_ref(q[:, -1], kc, vc,
                                   jnp.array([S]), block_s=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1]),
                               atol=2e-5)

"""Property tests (hypothesis) for the resource-control core.

Two families:
  * memcg-contract invariants of the pure-python ``DomainTree`` under
    random op sequences;
  * host/device cross-validation driven through the unified
    ``AgentCgroup`` control plane — the SAME op sequence runs against
    ``HostTreeBackend`` and ``DeviceTableBackend`` and must produce
    identical grant decisions and usage.

This module skips cleanly when ``hypothesis`` is absent (the directed
cases in ``test_domains.py`` / ``test_controller.py`` /
``test_cgroup.py`` run unconditionally).
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings
from hypothesis.stateful import (RuleBasedStateMachine, rule,
                                 run_state_machine_as_test)

from repro.core import domains as D
from repro.core.cgroup import AgentCgroup, DomainSpec, HostTreeBackend
from repro.core.controller import ControllerConfig
from repro.core.daemon import AsyncDaemonBackend
from repro.core.progs import GraduatedThrottleProgram


def mk_tree(cap=1000):
    t = D.DomainTree(cap)
    t.create("/a", high=400, priority=D.HIGH)
    t.create("/b", max=300, priority=D.LOW)
    t.create("/a/s1")
    t.create("/a/s1/tool", high=50)
    t.create("/b/s2")
    return t


LEAVES = ["/a/s1/tool", "/a/s1", "/b/s2", "/a", "/b"]

ops = st.lists(
    st.tuples(st.sampled_from(["charge", "uncharge", "kill", "freeze",
                               "thaw"]),
              st.sampled_from(LEAVES),
              st.integers(min_value=1, max_value=200)),
    min_size=1, max_size=60)


@given(ops)
@settings(max_examples=200, deadline=None)
def test_invariants_random_ops(op_list):
    t = mk_tree()
    charged = {p: 0 for p in LEAVES}       # net direct charges per domain
    for op, path, amt in op_list:
        if op == "charge":
            d = t.get(path)
            before = {n.name: n.usage for n in d.ancestors()}
            res = t.try_charge(path, amt)
            if not res.ok:
                # atomicity: a failed charge changes nothing
                for n in d.ancestors():
                    assert n.usage == before[n.name]
            else:
                charged[path] += amt
        elif op == "uncharge":
            take = min(amt, t.get(path).usage, charged[path])
            if take > 0:
                t.uncharge(path, take)
                charged[path] -= take
        elif op == "kill":
            t.kill(path)
            for sub in t.subtree(path):
                for p in charged:
                    if p == sub.name or p.startswith(sub.name + "/"):
                        charged[p] = 0
        elif op == "freeze":
            t.freeze(path)
        else:
            t.thaw(path)

        # ---- invariants after every op ----
        # no domain exceeds its hard limit
        for n in t.subtree("/"):
            assert n.usage <= n.max
            assert n.usage >= 0
            assert n.peak >= n.usage
        # hierarchical accounting: parent usage >= sum of children
        for n in t.subtree("/"):
            s = sum(c.usage for c in n.children.values())
            assert n.usage >= s


@given(st.integers(1, 500), st.integers(1, 500))
@settings(max_examples=100, deadline=None)
def test_charge_uncharge_roundtrip(a, b):
    t = mk_tree(cap=2000)
    r1 = t.try_charge("/a/s1", a)
    r2 = t.try_charge("/b/s2", b)
    if r1.ok:
        t.uncharge("/a/s1", a)
    if r2.ok:
        t.uncharge("/b/s2", b)
    assert t.root.usage == 0
    assert t.get("/a").usage == 0 and t.get("/b").usage == 0


# ---------------------------------------------- host/device cross-validation


def _mk_cg(kind: str) -> AgentCgroup:
    # zero-delay program on BOTH backends: grant/deny semantics compared
    # in isolation (throttle parity gets its own fuzz test below)
    if kind == "host":
        cg = AgentCgroup(HostTreeBackend(
            500, prog=GraduatedThrottleProgram(base_delay_ms=0.0,
                                               max_delay_ms=0.0)))
    else:
        from repro.core.cgroup import DeviceTableBackend
        cg = AgentCgroup(DeviceTableBackend(
            500, n_domains=16,
            cfg=ControllerConfig(base_delay_ms=0.0, max_delay_ms=0.0)))
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=120))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    cg.mkdir("/t/a/tool", DomainSpec(high=40))
    return cg


PATHS = ["/t/a/tool", "/t/a", "/t/b", "/t"]


@given(st.lists(st.tuples(st.sampled_from(PATHS),
                          st.integers(min_value=1, max_value=150)),
                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_device_matches_host_via_cgroup_api(seq):
    host, dev = _mk_cg("host"), _mk_cg("device")
    for i, (path, amt) in enumerate(seq):
        want = host.try_charge(path, amt, step=i)
        got = dev.try_charge(path, amt, step=i)
        assert got.granted == want.granted, (i, path, amt)
    for path in PATHS + ["/"]:
        assert dev.usage(path) == host.usage(path), path
        assert dev.peak(path) == host.peak(path), path


# -------------------------------------- runtime update_params fuzz (progs)


KNOBS = ["base_delay_ms", "max_delay_ms", "overage_gain",
         "high_priority_discount"]

prog_ops = st.lists(
    st.one_of(
        st.tuples(st.just("charge"), st.sampled_from(PATHS),
                  st.integers(min_value=1, max_value=150)),
        st.tuples(st.just("retune"), st.sampled_from(PATHS + ["/"]),
                  st.tuples(st.sampled_from(KNOBS),
                            st.integers(min_value=0, max_value=400))),
    ),
    min_size=1, max_size=40)


def _mk_throttling_cg(kind: str) -> AgentCgroup:
    """Same tree as ``_mk_cg`` but with the stock graduated program LIVE
    (non-zero delays), so throttle windows — and their runtime retunes —
    participate in the parity check."""
    if kind == "host":
        cg = AgentCgroup(HostTreeBackend(500))
    else:
        from repro.core.cgroup import DeviceTableBackend
        cg = AgentCgroup(DeviceTableBackend(500, n_domains=16))
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=120))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    cg.mkdir("/t/a/tool", DomainSpec(high=40))
    return cg


@given(prog_ops)
@settings(max_examples=40, deadline=None)
def test_update_params_parity_under_fuzz(op_list):
    """Interleave charges with random live ``update_params`` writes:
    host and device must keep bit-identical grant/stall/delay behaviour
    — the same decision code reading the same (retuned) param tables."""
    host, dev = _mk_throttling_cg("host"), _mk_throttling_cg("device")
    for i, op in enumerate(op_list):
        if op[0] == "charge":
            _, path, amt = op
            want = host.try_charge(path, amt, step=i)
            got = dev.try_charge(path, amt, step=i)
            assert got.granted == want.granted, (i, path, amt)
            assert got.stalled == want.stalled, (i, path, amt)
            assert got.delay_ms == want.delay_ms, (i, path, amt)
        else:
            _, path, (knob, val) = op
            host.update_params(path, **{knob: float(val)})
            dev.update_params(path, **{knob: float(val)})
    for path in PATHS + ["/"]:
        assert dev.usage(path) == host.usage(path), path
        assert dev.peak(path) == host.peak(path), path


# ----------------------- weighted scheduler fuzz (cpu.weight rewrites)


def _mk_sched_cg(kind: str) -> AgentCgroup:
    from repro.core.sched import WeightedFairProgram
    from repro.testing.conformance import standard_backend_factory
    cg = AgentCgroup(standard_backend_factory(kind)(500, 16))
    cg.attach("/", WeightedFairProgram(base_delay_ms=0.0, max_delay_ms=0.0))
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(weight=300))
    cg.mkdir("/t/b", DomainSpec(weight=100, priority=D.LOW))
    cg.mkdir("/t/a/tool")
    return cg


sched_ops = st.lists(
    st.one_of(
        st.tuples(st.just("round"), st.integers(min_value=1, max_value=4)),
        st.tuples(st.just("weight"), st.sampled_from(PATHS),
                  st.integers(min_value=1, max_value=10000)),
        st.tuples(st.just("boost"), st.sampled_from(PATHS + ["/"]),
                  st.integers(min_value=-3, max_value=3)),
        st.tuples(st.just("freeze"), st.sampled_from(PATHS)),
        st.tuples(st.just("thaw"), st.sampled_from(PATHS)),
    ),
    min_size=1, max_size=40)


@given(sched_ops)
@settings(max_examples=40, deadline=None)
def test_schedule_parity_under_weight_fuzz(op_list):
    """Interleave scheduling rounds with random live ``cpu.weight``
    rewrites, ``sched_boost`` retunes and freeze/thaw flips: host and
    device must emit bit-identical advance sets every round — the same
    flattened weights and the same vruntime accounts."""
    host, dev = _mk_sched_cg("host"), _mk_sched_cg("device")
    costs = [1] * len(PATHS)
    step = 0
    for op in op_list:
        if op[0] == "round":
            want = host.schedule(PATHS, costs, step, op[1])
            got = dev.schedule(PATHS, costs, step, op[1])
            assert got == want, (step, op)
            step += 1
        elif op[0] == "weight":
            host.write(op[1], "cpu.weight", op[2])
            dev.write(op[1], "cpu.weight", op[2])
        elif op[0] == "boost":
            host.update_params(op[1], sched_boost=float(op[2]))
            dev.update_params(op[1], sched_boost=float(op[2]))
        elif op[0] == "freeze":
            host.freeze(op[1])
            dev.freeze(op[1])
        else:
            host.thaw(op[1])
            dev.thaw(op[1])


# ----------------------------- pressure accounting fuzz (host vs device)


def _mk_pressure_cg(kind: str) -> AgentCgroup:
    from repro.core.sched import WeightedFairProgram
    from repro.testing.conformance import standard_backend_factory
    cg = AgentCgroup(standard_backend_factory(kind)(500, 16))
    cg.attach("/", WeightedFairProgram())     # stock delays: throttles live
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=40))
    cg.mkdir("/t/b", DomainSpec(max=100, priority=D.LOW))
    return cg


pressure_ops = st.lists(
    st.one_of(
        st.tuples(st.just("charge"), st.sampled_from(["/t/a", "/t/b"]),
                  st.integers(min_value=1, max_value=60)),
        st.tuples(st.just("round"), st.integers(min_value=1, max_value=2)),
        st.tuples(st.just("uncharge"), st.sampled_from(["/t/a", "/t/b"]),
                  st.integers(min_value=1, max_value=40)),
        st.just(("tick",)),
    ),
    min_size=1, max_size=40)


@given(pressure_ops)
@settings(max_examples=40, deadline=None)
def test_pressure_parity_under_fuzz(op_list):
    """Random charge/gate/clock sequences: host and device accumulate
    bit-identical stall counters after every op, and the facade meters
    — fed the same counters on the same clock — render identical PSI
    strings (the in-step accounting + host-side averaging contract all
    six kinds inherit)."""
    host, dev = _mk_pressure_cg("host"), _mk_pressure_cg("device")
    paths = ["/t/a", "/t/b"]
    watch = ("/", "/t", "/t/a", "/t/b")
    now, step = 0.0, 0
    for op in op_list:
        if op[0] == "charge":
            want = host.try_charge(op[1], op[2], step=step)
            got = dev.try_charge(op[1], op[2], step=step)
            assert (got.granted, got.stalled) == (want.granted,
                                                  want.stalled), (op, step)
            step += 1
        elif op[0] == "round":
            want = host.schedule(paths, [1, 1], step, op[1])
            got = dev.schedule(paths, [1, 1], step, op[1])
            assert got == want, (op, step)
            step += 1
        elif op[0] == "uncharge":
            amt = min(op[2], host.usage(op[1]))
            if amt:
                host.uncharge(op[1], amt)
                dev.uncharge(op[1], amt)
        else:
            now += 25.0
            host.set_time(now)
            dev.set_time(now)
            for p in watch:
                for f in ("memory.pressure", "cpu.pressure"):
                    assert dev.read(p, f) == host.read(p, f), (p, f)
        for p in watch:
            for f in ("memory.stall", "cpu.stall"):
                assert dev.read(p, f) == host.read(p, f), (p, f)
    for p in watch:
        for f in ("memory.pressure", "cpu.pressure"):
            assert dev.read(p, f) == host.read(p, f), (p, f)


# ------------------------------ async daemon vs inner backend (stateful)


class AsyncVsInnerMachine(RuleBasedStateMachine):
    """Random interleavings of lifecycle ops and charges against
    ``AsyncDaemonBackend`` vs. its inner backend driven synchronously:
    after every rule the async side is flushed to an epoch boundary and
    the two trees must be state-equivalent (the wrapper's bit-exactness
    contract).  Result-bearing ops (charge grants/stalls/delays, rmdir
    residuals, kill frees) are compared inline as well."""

    POOL = ["/a", "/b", "/a/s", "/b/s", "/a/s/tool"]
    SPECS = {"/a": {"high": 120}, "/b": {"max": 300, "priority": D.LOW},
             "/a/s": {}, "/b/s": {"high": 60}, "/a/s/tool": {"high": 40}}

    def __init__(self):
        super().__init__()
        self.sync = AgentCgroup(HostTreeBackend(800))
        self.asyn = AgentCgroup(AsyncDaemonBackend(HostTreeBackend(800),
                                                   flush_timeout_s=30.0))
        self.step = 0

    def both(self):
        return (self.sync, self.asyn)

    def teardown(self):
        self.asyn.backend.close()

    def _exists(self, path):
        return self.sync.exists(path)

    # ---- lifecycle ----

    @rule(path=st.sampled_from(POOL))
    def mkdir(self, path):
        from repro.core.cgroup import parent_path
        if self._exists(path) or not self._exists(parent_path(path)):
            return
        for cg in self.both():
            cg.mkdir(path, DomainSpec(**self.SPECS[path]))

    @rule(path=st.sampled_from(POOL))
    def rmdir_leaf(self, path):
        if not self._exists(path):
            return
        if any(p != path and p.startswith(path + "/")
               for p in self.sync.paths()):
            return                                   # only leaves
        r_s = self.sync.rmdir(path)
        r_a = self.asyn.rmdir(path)
        assert r_s == r_a, (path, r_s, r_a)

    @rule(path=st.sampled_from(POOL))
    def freeze(self, path):
        if self._exists(path):
            for cg in self.both():
                cg.freeze(path)

    @rule(path=st.sampled_from(POOL))
    def thaw(self, path):
        if self._exists(path):
            for cg in self.both():
                cg.thaw(path)

    @rule(path=st.sampled_from(POOL))
    def kill(self, path):
        if not self._exists(path):
            return
        k_s = self.sync.kill(path)
        k_a = self.asyn.kill(path)
        assert k_s == k_a, (path, k_s, k_a)

    @rule(path=st.sampled_from(POOL), val=st.integers(1, 400))
    def write_high(self, path, val):
        if self._exists(path):
            for cg in self.both():
                cg.write(path, "memory.high", val)

    @rule(knob=st.sampled_from(["base_delay_ms", "overage_gain",
                                "max_delay_ms"]),
          val=st.integers(0, 200))
    def retune(self, knob, val):
        for cg in self.both():
            cg.update_params("/", **{knob: float(val)})

    # ---- charging ----

    @rule(path=st.sampled_from(POOL), amt=st.integers(1, 150))
    def charge(self, path, amt):
        if not self._exists(path):
            return
        w = self.sync.try_charge(path, amt, step=self.step)
        g = self.asyn.try_charge(path, amt, step=self.step)
        self.step += 1
        assert (w.granted, w.stalled, w.delay_ms) == \
               (g.granted, g.stalled, g.delay_ms), (path, amt)

    @rule(path=st.sampled_from(POOL), amt=st.integers(1, 80))
    def uncharge(self, path, amt):
        if not self._exists(path):
            return
        take = min(amt, self.sync.usage(path))
        if take > 0:
            for cg in self.both():
                cg.uncharge(path, take)

    @rule(path=st.sampled_from(POOL), amt=st.integers(1, 40))
    def unchecked(self, path, amt):
        if self._exists(path):
            for cg in self.both():
                cg.charge_unchecked(path, amt)

    # ---- the equivalence check ----

    @rule()
    def epoch_flushed_equivalence(self):
        epoch = self.asyn.flush()
        assert isinstance(epoch, int)
        assert sorted(self.sync.paths()) == sorted(self.asyn.paths())
        for p in self.sync.paths():
            assert self.asyn.usage(p) == self.sync.usage(p), p
            assert self.asyn.peak(p) == self.sync.peak(p), p
            assert (self.asyn.read(p, "memory.events")
                    == self.sync.read(p, "memory.events")), p


def test_async_daemon_matches_inner_backend_stateful():
    run_state_machine_as_test(
        AsyncVsInnerMachine,
        settings=settings(max_examples=15, stateful_step_count=25,
                          deadline=None))

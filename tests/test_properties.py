"""Property tests (hypothesis) for the resource-control core.

Two families:
  * memcg-contract invariants of the pure-python ``DomainTree`` under
    random op sequences;
  * host/device cross-validation driven through the unified
    ``AgentCgroup`` control plane — the SAME op sequence runs against
    ``HostTreeBackend`` and ``DeviceTableBackend`` and must produce
    identical grant decisions and usage.

This module skips cleanly when ``hypothesis`` is absent (the directed
cases in ``test_domains.py`` / ``test_controller.py`` /
``test_cgroup.py`` run unconditionally).
"""
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import domains as D
from repro.core.cgroup import AgentCgroup, DomainSpec, HostTreeBackend
from repro.core.controller import ControllerConfig
from repro.core.progs import GraduatedThrottleProgram


def mk_tree(cap=1000):
    t = D.DomainTree(cap)
    t.create("/a", high=400, priority=D.HIGH)
    t.create("/b", max=300, priority=D.LOW)
    t.create("/a/s1")
    t.create("/a/s1/tool", high=50)
    t.create("/b/s2")
    return t


LEAVES = ["/a/s1/tool", "/a/s1", "/b/s2", "/a", "/b"]

ops = st.lists(
    st.tuples(st.sampled_from(["charge", "uncharge", "kill", "freeze",
                               "thaw"]),
              st.sampled_from(LEAVES),
              st.integers(min_value=1, max_value=200)),
    min_size=1, max_size=60)


@given(ops)
@settings(max_examples=200, deadline=None)
def test_invariants_random_ops(op_list):
    t = mk_tree()
    charged = {p: 0 for p in LEAVES}       # net direct charges per domain
    for op, path, amt in op_list:
        if op == "charge":
            d = t.get(path)
            before = {n.name: n.usage for n in d.ancestors()}
            res = t.try_charge(path, amt)
            if not res.ok:
                # atomicity: a failed charge changes nothing
                for n in d.ancestors():
                    assert n.usage == before[n.name]
            else:
                charged[path] += amt
        elif op == "uncharge":
            take = min(amt, t.get(path).usage, charged[path])
            if take > 0:
                t.uncharge(path, take)
                charged[path] -= take
        elif op == "kill":
            t.kill(path)
            for sub in t.subtree(path):
                for p in charged:
                    if p == sub.name or p.startswith(sub.name + "/"):
                        charged[p] = 0
        elif op == "freeze":
            t.freeze(path)
        else:
            t.thaw(path)

        # ---- invariants after every op ----
        # no domain exceeds its hard limit
        for n in t.subtree("/"):
            assert n.usage <= n.max
            assert n.usage >= 0
            assert n.peak >= n.usage
        # hierarchical accounting: parent usage >= sum of children
        for n in t.subtree("/"):
            s = sum(c.usage for c in n.children.values())
            assert n.usage >= s


@given(st.integers(1, 500), st.integers(1, 500))
@settings(max_examples=100, deadline=None)
def test_charge_uncharge_roundtrip(a, b):
    t = mk_tree(cap=2000)
    r1 = t.try_charge("/a/s1", a)
    r2 = t.try_charge("/b/s2", b)
    if r1.ok:
        t.uncharge("/a/s1", a)
    if r2.ok:
        t.uncharge("/b/s2", b)
    assert t.root.usage == 0
    assert t.get("/a").usage == 0 and t.get("/b").usage == 0


# ---------------------------------------------- host/device cross-validation


def _mk_cg(kind: str) -> AgentCgroup:
    # zero-delay program on BOTH backends: grant/deny semantics compared
    # in isolation (throttle parity gets its own fuzz test below)
    if kind == "host":
        cg = AgentCgroup(HostTreeBackend(
            500, prog=GraduatedThrottleProgram(base_delay_ms=0.0,
                                               max_delay_ms=0.0)))
    else:
        from repro.core.cgroup import DeviceTableBackend
        cg = AgentCgroup(DeviceTableBackend(
            500, n_domains=16,
            cfg=ControllerConfig(base_delay_ms=0.0, max_delay_ms=0.0)))
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=120))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    cg.mkdir("/t/a/tool", DomainSpec(high=40))
    return cg


PATHS = ["/t/a/tool", "/t/a", "/t/b", "/t"]


@given(st.lists(st.tuples(st.sampled_from(PATHS),
                          st.integers(min_value=1, max_value=150)),
                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_device_matches_host_via_cgroup_api(seq):
    host, dev = _mk_cg("host"), _mk_cg("device")
    for i, (path, amt) in enumerate(seq):
        want = host.try_charge(path, amt, step=i)
        got = dev.try_charge(path, amt, step=i)
        assert got.granted == want.granted, (i, path, amt)
    for path in PATHS + ["/"]:
        assert dev.usage(path) == host.usage(path), path
        assert dev.peak(path) == host.peak(path), path


# -------------------------------------- runtime update_params fuzz (progs)


KNOBS = ["base_delay_ms", "max_delay_ms", "overage_gain",
         "high_priority_discount"]

prog_ops = st.lists(
    st.one_of(
        st.tuples(st.just("charge"), st.sampled_from(PATHS),
                  st.integers(min_value=1, max_value=150)),
        st.tuples(st.just("retune"), st.sampled_from(PATHS + ["/"]),
                  st.tuples(st.sampled_from(KNOBS),
                            st.integers(min_value=0, max_value=400))),
    ),
    min_size=1, max_size=40)


def _mk_throttling_cg(kind: str) -> AgentCgroup:
    """Same tree as ``_mk_cg`` but with the stock graduated program LIVE
    (non-zero delays), so throttle windows — and their runtime retunes —
    participate in the parity check."""
    if kind == "host":
        cg = AgentCgroup(HostTreeBackend(500))
    else:
        from repro.core.cgroup import DeviceTableBackend
        cg = AgentCgroup(DeviceTableBackend(500, n_domains=16))
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=120))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    cg.mkdir("/t/a/tool", DomainSpec(high=40))
    return cg


@given(prog_ops)
@settings(max_examples=40, deadline=None)
def test_update_params_parity_under_fuzz(op_list):
    """Interleave charges with random live ``update_params`` writes:
    host and device must keep bit-identical grant/stall/delay behaviour
    — the same decision code reading the same (retuned) param tables."""
    host, dev = _mk_throttling_cg("host"), _mk_throttling_cg("device")
    for i, op in enumerate(op_list):
        if op[0] == "charge":
            _, path, amt = op
            want = host.try_charge(path, amt, step=i)
            got = dev.try_charge(path, amt, step=i)
            assert got.granted == want.granted, (i, path, amt)
            assert got.stalled == want.stalled, (i, path, amt)
            assert got.delay_ms == want.delay_ms, (i, path, amt)
        else:
            _, path, (knob, val) = op
            host.update_params(path, **{knob: float(val)})
            dev.update_params(path, **{knob: float(val)})
    for path in PATHS + ["/"]:
        assert dev.usage(path) == host.usage(path), path
        assert dev.peak(path) == host.peak(path), path

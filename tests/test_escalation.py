"""Semantic OOM escalation loop (core/escalation.py + the kill ->
OomEvent delivery path in core/cgroup.py) and its replay integration:
negotiation bounds, typed-event delivery, killed-lease close semantics,
and the end-to-end retry-completion / waste-saved acceptance on the
heavy-tailed spike corpus."""
import pytest

from repro.core import domains as D
from repro.core.cgroup import AgentCgroup, DomainSpec, HostTreeBackend
from repro.core.escalation import (EscalationExhausted, EscalationPolicy,
                                   Escalator, WasteLedger)
from repro.core.events import Ev, OomEvent
from repro.core.intent import Hint, feedback_from_oom
from repro.core.policy import AgentCgroupPolicy
from repro.traces.generator import generate_spike_corpus
from repro.traces.replay import ReplayConfig, replay


def mk_cg(cap: int = 1000) -> AgentCgroup:
    return AgentCgroup(HostTreeBackend(cap))


def ev(peak=80, limit=100, attempt=1, path="/s/tool", session="/s"):
    return OomEvent(path=path, session=session, peak_pages=peak,
                    limit_pages=limit, attempt=attempt, residual_pages=peak)


# ------------------------------------------------------------- negotiation


def test_negotiate_grows_exponentially_from_limit():
    pol = EscalationPolicy(growth=2.0, headroom=1.25)
    neg = pol.negotiate(ev(peak=40, limit=100), parent_max=10_000)
    assert neg.grant_pages == 200            # limit*growth dominates
    assert neg.attempt == 2


def test_negotiate_headroom_over_peak_skips_futile_attempts():
    pol = EscalationPolicy(growth=2.0, headroom=1.25)
    neg = pol.negotiate(ev(peak=400, limit=100), parent_max=10_000)
    assert neg.grant_pages == 500            # peak*headroom dominates


def test_negotiate_capped_by_parent_max():
    pol = EscalationPolicy()
    neg = pol.negotiate(ev(peak=80, limit=100), parent_max=150)
    assert neg.grant_pages == 150


def test_negotiate_exhausts_on_attempt_budget_and_ceiling():
    pol = EscalationPolicy(max_attempts=3)
    assert pol.negotiate(ev(attempt=3), parent_max=10_000) is None
    # cap allows no growth past the limit that already killed it
    assert pol.negotiate(ev(peak=80, limit=100), parent_max=100) is None


def test_backoff_is_deterministic_jittered_exponential():
    pol = EscalationPolicy(base_backoff_ms=20.0, backoff_factor=2.0,
                           jitter_frac=0.25)
    b1 = pol.backoff_ms("/s/tool", 1)
    b2 = pol.backoff_ms("/s/tool", 2)
    assert b1 == pol.backoff_ms("/s/tool", 1)        # same key: same jitter
    assert 20.0 <= b1 <= 25.0
    assert 40.0 <= b2 <= 50.0
    assert pol.backoff_ms("/s/other", 1) != b1       # key-dependent


# ------------------------------------------------ kill -> OomEvent delivery


def test_kill_delivers_typed_oom_event_to_owning_session():
    cg = mk_cg()
    cg.mkdir("/s")
    lease = cg.intent.declare("tool_1", Hint.LOW, parent="/s",
                              high=50, max=50)
    cg.try_charge(lease.path, 30)
    freed = cg.kill(lease.path)
    assert freed == 30
    assert lease.killed and lease.oom is not None
    got = cg.intent.oom_events("/s", clear=True)
    assert len(got) == 1
    e = got[0]
    assert e.path == "/s/tool_1" and e.session == "/s"
    assert e.limit_pages == 50 and e.residual_pages == 30
    assert e.attempt == 1
    assert cg.intent.oom_events("/s") == []          # cleared
    assert cg.log.count(Ev.OOM) == 1


def test_session_kill_delivers_events_for_all_open_leases():
    cg = mk_cg()
    cg.mkdir("/s")
    a = cg.intent.declare("a", None, parent="/s", high=40)
    b = cg.intent.declare("b", None, parent="/s", high=40)
    cg.try_charge(a.path, 10)
    cg.kill("/s")
    assert a.killed and b.killed
    assert len(cg.intent.oom_events("/s")) == 2


def test_killed_lease_close_emits_no_done():
    cg = mk_cg()
    cg.mkdir("/s")
    lease = cg.intent.declare("tool_1", None, parent="/s", high=50)
    cg.try_charge(lease.path, 10)
    cg.kill(lease.path)
    n_done = cg.log.count(Ev.DONE)
    assert lease.close() == 0                # kill already freed the pages
    assert cg.log.count(Ev.DONE) == n_done   # no DONE after a kill
    assert not cg.exists(lease.path)         # domain still reclaimed


def test_oom_event_renders_and_feeds_back():
    e = ev(peak=80, limit=100)
    assert "oom" in e.render().lower() or "/s/tool" in e.render()
    fb = feedback_from_oom(e)
    assert fb.reason == "oom_kill"
    assert fb.peak_pages == 80 and fb.limit_pages == 100


def test_feedback_distinguishes_zero_from_unset():
    cg = mk_cg()
    cg.mkdir("/s", DomainSpec(high=40))
    # explicit zero must survive (not be replaced by the domain's state)
    fb = cg.intent.feedback("/s", "throttled", peak=0, limit=0)
    assert fb.peak_pages == 0 and fb.limit_pages == 0
    cg.try_charge("/s", 30)
    fb2 = cg.intent.feedback("/s", "throttled")      # unset: read from tree
    assert fb2.peak_pages == 30 and fb2.limit_pages == 40


# ------------------------------------------------------------- escalator


def test_escalator_redeclare_at_negotiated_limit():
    cg = mk_cg()
    cg.mkdir("/s", DomainSpec(max=400))
    lease = cg.intent.declare("tool_1", Hint.LOW, parent="/s",
                              high=50, max=50)
    cg.try_charge(lease.path, 40)
    cg.kill(lease.path)
    esc = Escalator(cg, EscalationPolicy(growth=2.0))
    new, neg = esc.escalate(lease)
    assert lease.closed and not new.closed
    assert new.path == lease.path and new.tool_id == "tool_1"
    assert new.attempt == 2
    assert neg.grant_pages == 100
    assert cg.read(new.path, "memory.max") == 100
    # the cap is the tightest ancestor memory.max (/s here)
    cg.try_charge(new.path, 90)
    cg.kill(new.path)
    new2, neg2 = esc.escalate(new)
    assert neg2.grant_pages == 200
    cg.try_charge(new2.path, 190)
    cg.kill(new2.path)
    new3, neg3 = esc.escalate(new2)
    assert neg3.grant_pages == 400           # capped by /s memory.max


def test_escalator_exhaustion_is_loud_and_cleans_up():
    cg = mk_cg()
    cg.mkdir("/s")
    lease = cg.intent.declare("tool_1", None, parent="/s", high=50, max=50)
    cg.kill(lease.path)
    esc = Escalator(cg, EscalationPolicy(max_attempts=1))
    with pytest.raises(EscalationExhausted) as exc:
        esc.escalate(lease)
    assert exc.value.event is lease.oom
    assert lease.closed and not cg.exists(lease.path)
    assert esc.ledger.exhausted == 1


def test_waste_ledger_accounting():
    led = WasteLedger()
    led.record_kill("a", attempt_pages=10, baseline_pages=300)
    led.record_kill("a", attempt_pages=20, baseline_pages=999)  # 2nd attempt
    led.record_recovery("a")
    led.record_recovery("never_killed")      # ignored
    assert led.killed_calls == 1 and led.kills == 2
    assert led.recovered_calls == 1 and led.recovery_rate == 1.0
    assert led.baseline_waste_pages == 300   # first kill only
    assert led.attempt_waste_pages == 30
    assert led.saved_pages == 270


# --------------------------------------------------- replay integration


def test_spike_corpus_hits_paper_peak_to_avg():
    traces = generate_spike_corpus(4, seed=1)
    ratios = [t.peak_mb / t.avg_mb for t in traces]
    assert max(ratios) == pytest.approx(15.4, rel=0.01)
    # deterministic: same seed, same corpus
    again = generate_spike_corpus(4, seed=1)
    assert [t.peak_mb for t in again] == [t.peak_mb for t in traces]


def test_escalation_recovers_killed_tool_calls_on_spike_corpus():
    """The acceptance bar: >= 90% of killed tool calls complete after
    escalated retries, and the ledger shows waste saved vs. the
    no-retry baseline."""
    traces = generate_spike_corpus(4, seed=1)
    prios = [D.NORMAL] * len(traces)
    cfg = ReplayConfig(capacity_mb=24_000)
    static = replay(traces, prios,
                    AgentCgroupPolicy(lease_max_factor=1.0), cfg)
    esc = replay(traces, prios,
                 AgentCgroupPolicy(lease_max_factor=1.0,
                                   escalation=EscalationPolicy()), cfg)
    led = esc.escalation
    assert led is not None and static.escalation is None
    assert led["killed_calls"] > 0           # the corpus really spikes
    assert led["recovery_rate"] >= 0.90
    assert led["saved_pages"] > 0
    assert esc.survival > static.survival
    assert esc.survival == 1.0


def test_escalation_off_by_default_keeps_baseline_semantics():
    """Without opting in, AgentCgroupPolicy has unlimited lease maxes
    and no escalator — the pre-existing replay path, bit-for-bit."""
    pol = AgentCgroupPolicy()
    assert pol.escalation is None and pol.lease_max_factor is None
    traces = generate_spike_corpus(2, seed=3)
    res = replay(traces, [D.NORMAL] * 2,
                 AgentCgroupPolicy(), ReplayConfig(capacity_mb=24_000))
    assert res.escalation is None
    assert res.survival == 1.0
    assert res.log.count(Ev.OOM) == 0

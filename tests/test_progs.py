"""Pluggable policy programs (core/progs.py) — the memcg_bpf_ops API.

Three claims, each load-bearing for the redesign:

  * PARITY — one op sequence with the same program attached runs
    bit-identically (grants, stalls, delays, usage, peak) on every
    backend kind — host tree, device table, sharded table, and the
    async daemon over each.  Since PR 5 this is certified through the
    backend-conformance kit (``repro.testing.conformance``): the stock
    programs ride in the standard scenario set, and the custom program
    defined right here certifies via an extra scenario (the surface is
    user-extensible AND user-certifiable).
  * LIVE RETUNE — ``cg.update_params`` on a live jitted consumer is a
    pure state write: zero retraces (asserted via jit cache size and a
    trace counter), new curve effective on the following charge.
  * NEW SCENARIOS — ``TokenBucketProgram`` rate-limits (pages/step,
    per-priority refill), which the overage-delay curve cannot express.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend)
from repro.core.progs import (GraduatedThrottleProgram, PolicyProgram,
                              TokenBucketProgram, Verdict)
from repro.testing.conformance import (BACKEND_KINDS, ConformanceSuite,
                                       Scenario, backend_features,
                                       standard_backend_factory)

BACKENDS = ["host", "device", "sharded"]


def mk_cg(kind: str, prog: PolicyProgram, cap: int = 500) -> AgentCgroup:
    cg = AgentCgroup(standard_backend_factory(kind)(cap, 16))
    cg.attach("/", prog)
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=40))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    return cg


class BurstCapProgram(GraduatedThrottleProgram):
    """Test-local custom program: denies any single request larger than
    a per-domain ``burst_cap`` (0 disables) on top of the graduated
    throttle — proves the attach surface is open to user code."""

    param_names = GraduatedThrottleProgram.param_names + ("burst_cap",)

    def __init__(self, burst_cap: float = 0.0, **kw):
        super().__init__(**kw)
        self.burst_cap = float(burst_cap)

    def default_row(self):
        return np.concatenate([super().default_row(),
                               np.float32([self.burst_cap])])

    def on_charge(self, view, req):
        base = super().on_charge(view, req)
        cap = view.params[4]
        too_big = (cap > 0) & (req.amt > cap)
        return Verdict(base.grant & ~too_big, base.stall | too_big,
                       base.delay_ms, base.params)


# custom-program scenarios for the conformance kit: over-``high``
# charges impose throttle windows, charges inside a window stall,
# windows expire with the clock, and the burst cap denies what the
# graduated contract alone would grant
_PROG_OPS = (("attach", "/", "prog"),
             ("mkdir", "/t"),
             ("mkdir", "/t/a", {"high": 40}),
             ("mkdir", "/t/b", {"max": 200, "priority": D.LOW}),
             ("charge", "/t/a", 60, 0),    # over high=40 -> window
             ("charge", "/t/a", 5, 1),     # inside the window
             ("charge", "/t/b", 150, 2),
             ("charge", "/t/b", 100, 3),   # /t/b max=200 wall
             ("charge", "/t/b", 30, 4),
             ("charge", "/t/a", 5, 8),     # after the window
             ("charge", "/t/a", 5, 12),
             ("charge", "/t/b", 10, 20),
             ("charge", "/t/a", 120, 21))  # > burst_cap where attached

CUSTOM_SCENARIOS = [
    Scenario("prog_" + name, ops=_PROG_OPS, programs={"prog": factory})
    for name, factory in {
        "graduated": GraduatedThrottleProgram,
        "token_bucket": lambda: TokenBucketProgram(bucket_capacity=64,
                                                   refill=(2.0, 8.0, 32.0)),
        "burst_cap": lambda: BurstCapProgram(burst_cap=100),
    }.items()
]

CUSTOM_SUITE = ConformanceSuite(scenarios=CUSTOM_SCENARIOS)


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_custom_programs_certify_via_conformance_kit(kind):
    """THE acceptance loop of the redesign, now one kit call: identical
    grants, stalls, delays, usage, and peak on every backend kind, for
    stock and test-local custom programs alike."""
    report = CUSTOM_SUITE.run(standard_backend_factory(kind),
                              features=backend_features(kind))
    assert report.ok, report.summary()


def test_graduated_program_throttles_and_expires():
    cg = mk_cg("device", GraduatedThrottleProgram())
    t = cg.try_charge("/t/a", 60, step=0)
    # over_frac 0.5 -> 10*(1+10*0.5) = 60 ms -> 6 steps
    assert t.granted and t.delay_ms == 60.0
    assert not cg.try_charge("/t/a", 1, step=5).granted
    assert cg.try_charge("/t/a", 1, step=6).granted


# ------------------------------------------------------------ token bucket


def test_token_bucket_rate_limits_what_delay_cannot():
    """A domain far under ``high`` (no overage ever) is still paced to
    its refill rate — pages per step, not standing usage."""
    prog = TokenBucketProgram(bucket_capacity=10, refill=(1.0, 2.0, 4.0))
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.attach("/", prog)
    cg.mkdir("/s")                           # NORMAL: 2 pages/step
    assert cg.try_charge("/s", 10, step=0).granted     # full bucket
    assert not cg.try_charge("/s", 5, step=1).granted  # level 2 < 5
    assert cg.try_charge("/s", 5, step=3).granted      # level 6 >= 5
    # sustained: ~2 pages/step from here on
    grants = sum(cg.try_charge("/s", 2, step=s).granted
                 for s in range(4, 24))
    assert grants <= 20 and cg.usage("/s") <= 10 + 5 + 2 * 21


def test_token_bucket_priority_refill():
    prog = TokenBucketProgram(bucket_capacity=8, refill=(1.0, 2.0, 8.0))
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.attach("/", prog)
    cg.mkdir("/lo", DomainSpec(priority=D.LOW))
    cg.mkdir("/hi", DomainSpec(priority=D.HIGH))
    for p in ("/lo", "/hi"):
        assert cg.try_charge(p, 8, step=0).granted     # drain both
    # one step later: HIGH refilled 8, LOW only 1
    assert cg.try_charge("/hi", 8, step=1).granted
    assert not cg.try_charge("/lo", 8, step=1).granted


def test_token_bucket_neutral_outside_attach_scope():
    prog = TokenBucketProgram(bucket_capacity=4, refill=(1.0, 1.0, 1.0))
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.mkdir("/scoped")
    cg.mkdir("/free")
    cg.attach("/scoped", prog)
    assert not cg.try_charge("/scoped", 50, step=0).granted   # bucketed
    assert cg.try_charge("/free", 50, step=0).granted         # neutral row


# ------------------------------------------------------- live retuning


def test_update_params_no_retrace_new_curve_next_charge():
    """The adaptability pillar: retuning a live program is a param-table
    write — the jitted charge function is NOT retraced, and the new
    delay curve applies to the very next charge."""
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.mkdir("/s", DomainSpec(high=10))
    view = cg.device_view()
    traces = 0

    def charge(state, dom, amt, step):
        nonlocal traces
        traces += 1
        return view.charge(state, dom, amt, step)

    jcharge = jax.jit(charge)
    idx = cg.handle("/s")
    dom = jnp.array([idx])
    st, g, _ = jcharge(view.state, dom, jnp.array([20], jnp.int32), 0)
    view.commit(st)
    w0 = int(st["throttle_until"][idx])            # usage 20, over 1.0
    assert bool(g[0]) and w0 == 0 + 11             # 10*(1+10*1.0) -> 11 steps

    cg.update_params("/s", overage_gain=100.0, max_delay_ms=100_000.0)
    st, g, _ = jcharge(view.state, dom, jnp.array([10], jnp.int32), 50)
    view.commit(st)
    assert bool(g[0])
    # new curve: usage 30, over 2.0: 10*(1+100*2.0) = 2010 ms -> 201 steps
    assert int(st["throttle_until"][idx]) == 50 + 201
    assert traces == 1                             # no retrace
    assert jcharge._cache_size() == 1


def test_update_params_unknown_knob_raises():
    cg = AgentCgroup(HostTreeBackend(100))
    cg.mkdir("/s")
    with pytest.raises(KeyError):
        cg.update_params("/s", not_a_knob=1.0)


def test_update_params_subtree_and_inheritance():
    """Params write to the whole subtree, and new children inherit the
    parent's live row (cgroup settings propagate down)."""
    for kind in BACKENDS:
        cg = mk_cg(kind, GraduatedThrottleProgram())
        cg.update_params("/t", base_delay_ms=40.0)
        cg.mkdir("/t/a/kid", DomainSpec(high=10))
        t = cg.try_charge("/t/a/kid", 20, step=0)  # over 1.0 -> 40*(1+10)
        assert t.granted and t.delay_ms == 440.0, kind


def test_attach_program_on_live_engine():
    """Engine-level acceptance: swap the program on a live engine (one
    deliberate retrace), then retune it with zero retraces while the
    jitted step keeps running."""
    from repro.configs import get_config, reduced
    from repro.models import model as M
    from repro.models.schema import init_params
    from repro.perf import DEFAULT_PERF, replace as perf_replace
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.session import Phase, Session

    cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                              dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    eng = Engine(cfg, params, perf=perf_replace(DEFAULT_PERF, scan_chunk=32),
                 ecfg=EngineConfig(max_slots=2, s_max=128, pool_pages=64,
                                   page_tokens=16, mode="inkernel",
                                   use_freeze=False), seed=0)
    eng.attach_program(TokenBucketProgram(bucket_capacity=64,
                                          refill=(1.0, 2.0, 4.0)))
    eng.submit(Session(sid="s", tenant="t", priority=D.NORMAL,
                       prompt=list(range(2, 10)),
                       phases=[Phase(6, 8, "test"), Phase(6, 0)]))
    for _ in range(8):
        eng.step()
    cache0 = eng._step._cache_size()
    eng.update_params("/", refill_normal=9.0, bucket_capacity=128.0)
    for _ in range(8):
        eng.step()
    assert eng._step._cache_size() == cache0       # retune never re-jits
    row = eng.cg.snapshot()["params"][eng.cg.handle("/t")]
    assert row[eng.cg.program.col("refill_normal")] == 9.0

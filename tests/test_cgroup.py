"""The unified cgroupfs-style control plane (core/cgroup.py).

Host/device backend parity is the point of the facade: one op sequence,
two enforcement substrates, identical usage/peak/grant results.  Also
covers the control-file surface, the intent channel's lease lifecycle
(residual transfer on rmdir), and freeze->thaw re-charge parity.
"""
import pytest

from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, ChargeTicket, DeviceTableBackend,
                               DomainSpec, HostTreeBackend, ancestor_paths,
                               parent_path)
from repro.core.controller import ControllerConfig
from repro.core.intent import Hint

NO_THROTTLE = ControllerConfig(base_delay_ms=0.0, max_delay_ms=0.0)
BACKENDS = ["host", "device"]


def mk_cg(kind: str, cap: int = 500) -> AgentCgroup:
    if kind == "host":
        return AgentCgroup(HostTreeBackend(cap))
    return AgentCgroup(DeviceTableBackend(cap, n_domains=16,
                                          cfg=NO_THROTTLE))


def std_tree(cg: AgentCgroup) -> AgentCgroup:
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=120))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    cg.mkdir("/t/a/tool", DomainSpec(high=40))
    return cg


# one op sequence exercising charge/deny, uncharge, freeze/thaw,
# rmdir-with-residual, and unchecked lifecycle charges
OPS = [
    ("charge", "/t/a/tool", 60),      # grant; over tool high
    ("charge", "/t/b", 150),          # grant
    ("charge", "/t/b", 100),          # deny: /t/b max=200
    ("uncharge", "/t/b", 50),
    ("charge", "/t/b", 100),          # grant now
    ("freeze", "/t/a", 0),
    ("charge", "/t/a/tool", 5),       # deny: frozen ancestor
    ("thaw", "/t/a", 0),
    ("charge", "/t/a/tool", 5),       # grant again
    ("rmdir", "/t/a/tool", 0),        # residual 65 transfers to /t/a
    ("unchecked", "/t/a", 20),        # lifecycle bookkeeping charge
    ("uncharge", "/t/a", 30),
    ("charge", "/t/a", 400),          # deny: root capacity 500
]

# expected state after OPS — identical for BOTH backends by construction
EXPECTED_GRANTS = [True, True, False, True, False, True, False]
EXPECTED = {"/": 255, "/t": 255, "/t/a": 55, "/t/b": 200}
EXPECTED_PEAK = {"/": 285, "/t": 285, "/t/a": 85, "/t/b": 200}


def run_ops(cg: AgentCgroup):
    grants = []
    for step, (op, path, amt) in enumerate(OPS):
        if op == "charge":
            grants.append(cg.try_charge(path, amt, step=step).granted)
        elif op == "uncharge":
            cg.uncharge(path, amt)
        elif op == "unchecked":
            cg.charge_unchecked(path, amt)
        elif op == "freeze":
            cg.freeze(path)
        elif op == "thaw":
            cg.thaw(path)
        elif op == "rmdir":
            cg.rmdir(path)
    return grants


@pytest.mark.parametrize("kind", BACKENDS)
def test_same_op_sequence_same_results(kind):
    """THE acceptance loop: one op sequence via AgentCgroup against each
    backend; grants, usage, and peak must all match the shared golden
    values (hence each other)."""
    cg = std_tree(mk_cg(kind))
    assert run_ops(cg) == EXPECTED_GRANTS
    for path, want in EXPECTED.items():
        assert cg.usage(path) == want, (kind, path)
    for path, want in EXPECTED_PEAK.items():
        assert cg.peak(path) == want, (kind, path)


def test_backends_agree_directly():
    host, dev = std_tree(mk_cg("host")), std_tree(mk_cg("device"))
    assert run_ops(host) == run_ops(dev)
    for path in ["/", "/t", "/t/a", "/t/b"]:
        assert host.usage(path) == dev.usage(path)
        assert host.peak(path) == dev.peak(path)


# ------------------------------------------------------- lifecycle parity


@pytest.mark.parametrize("kind", BACKENDS)
def test_rmdir_residual_transfers_to_ancestors(kind):
    """Closing a non-empty tool domain keeps its retained pages
    accounted to the session chain (the residual-transfer rule)."""
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/tool", DomainSpec(high=40))
    assert cg.try_charge("/s/tool", 30).granted
    residual = cg.rmdir("/s/tool")
    assert residual == 30
    assert not cg.exists("/s/tool")
    assert cg.usage("/s") == 30 and cg.usage("/") == 30


@pytest.mark.parametrize("kind", BACKENDS)
def test_rmdir_without_transfer_releases(kind):
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/tool")
    cg.try_charge("/s/tool", 30)
    cg.rmdir("/s/tool", transfer_residual=False)
    assert cg.usage("/s") == 0 and cg.usage("/") == 0


@pytest.mark.parametrize("kind", BACKENDS)
def test_freeze_thaw_recharge_parity(kind):
    """The engine's freeze path: offload (uncharge) + freeze, then thaw
    + unchecked re-charge; ancestor usage must round-trip exactly."""
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/sess")
    assert cg.try_charge("/s/sess", 80).granted
    before = {p: cg.usage(p) for p in ["/", "/s", "/s/sess"]}
    pages = cg.usage("/s/sess")
    cg.uncharge("/s/sess", pages)
    cg.freeze("/s/sess")
    assert not cg.try_charge("/s/sess", 1).granted
    assert cg.usage("/") == 0
    cg.thaw("/s/sess")
    cg.charge_unchecked("/s/sess", pages)
    after = {p: cg.usage(p) for p in ["/", "/s", "/s/sess"]}
    assert after == before


@pytest.mark.parametrize("kind", BACKENDS)
def test_kill_releases_subtree(kind):
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/a")
    cg.try_charge("/s/a", 40)
    cg.try_charge("/s", 10)
    freed = cg.kill("/s")
    assert freed == 50
    assert cg.usage("/") == 0
    # killed domains stay registered and deny further charges — on
    # both backends
    assert cg.exists("/s") and cg.exists("/s/a")
    assert not cg.try_charge("/s", 5).granted
    assert not cg.try_charge("/s/a", 5).granted


def test_host_driven_throttle_expires_with_facade_clock():
    """A device-backend charge with no explicit step uses the facade
    clock, so an over-``high`` throttle expires instead of pinning all
    later host-driven charges at step 0."""
    cg = AgentCgroup(DeviceTableBackend(500, n_domains=8,
                                        cfg=ControllerConfig()))
    cg.mkdir("/s", DomainSpec(high=10))
    assert cg.try_charge("/s", 20).granted       # over high -> throttled
    assert not cg.try_charge("/s", 1).granted    # still step 0: denied
    cg.set_time(10_000)
    assert cg.try_charge("/s", 1).granted        # throttle expired


# ------------------------------------------------------------ control files


@pytest.mark.parametrize("kind", BACKENDS)
def test_read_write_files(kind):
    cg = mk_cg(kind)
    cg.mkdir("/s", DomainSpec(high=100, max=200, low=10, priority=D.HIGH))
    assert cg.read("/s", "memory.high") == 100
    assert cg.read("/s", "memory.max") == 200
    assert cg.read("/s", "memory.low") == 10
    assert cg.read("/s", "memory.priority") == D.HIGH
    cg.write("/s", "memory.high", 50)
    assert cg.read("/s", "memory.high") == 50
    cg.write("/s", "cgroup.freeze", 1)
    assert cg.read("/s", "cgroup.freeze") == 1
    assert not cg.try_charge("/s", 1).granted
    cg.write("/s", "cgroup.freeze", 0)
    assert cg.try_charge("/s", 1).granted
    with pytest.raises(AssertionError):
        cg.read("/s", "not.a.file")
    with pytest.raises(AssertionError):
        cg.write("/s", "memory.current", 3)      # read-only


def test_host_event_counters():
    cg = mk_cg("host")
    cg.mkdir("/s", DomainSpec(high=10, max=50))
    cg.try_charge("/s", 20)                      # high breach
    cg.try_charge("/s", 100)                     # max breach
    ev = cg.read("/s", "memory.events")
    assert ev["high"] == 1 and ev["max"] == 1


@pytest.mark.parametrize("kind", BACKENDS)
def test_mkdir_requires_parent(kind):
    cg = mk_cg(kind)
    with pytest.raises(FileNotFoundError):
        cg.mkdir("/nope/child")


# ------------------------------------------------------------ intent channel


@pytest.mark.parametrize("kind", BACKENDS)
def test_intent_lease_lifecycle(kind):
    cg = mk_cg(kind)
    cg.mkdir("/sess")
    lease = cg.intent.declare("tool_1", Hint.LOW, parent="/sess")
    assert cg.exists("/sess/tool_1")
    # hint mapped to a memory.high on the tool domain
    assert cg.read(lease.path, "memory.high") < D.UNLIMITED
    cg.try_charge(lease.path, 25)
    fb = lease.feedback("throttled")
    assert fb.reason == "throttled" and fb.peak_pages == 25
    resid = lease.close()
    assert resid == 25 and not cg.exists(lease.path)
    assert cg.usage("/sess") == 25               # residual moved up
    assert lease.close() == 0                    # idempotent
    assert cg.intent.n_declared == 1 and cg.intent.n_feedbacks == 1


def test_path_helpers():
    assert parent_path("/") is None
    assert parent_path("/a") == "/"
    assert parent_path("/a/b/c") == "/a/b"
    assert ancestor_paths("/a/b") == ["/a/b", "/a", "/"]

"""The unified cgroupfs-style control plane (core/cgroup.py).

Backend parity is the point of the facade: one op sequence, three
enforcement substrates (host tree / single-device table / sharded
multi-device table), identical usage/peak/grant results.  Also covers
the control-file surface, the intent channel's lease lifecycle
(residual transfer on rmdir), freeze->thaw re-charge parity, and the
sharded backend's tenant-to-shard placement on 8 fake devices
(subprocess).
"""
import os
import subprocess
import sys

import pytest

from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, ChargeTicket, DeviceTableBackend,
                               DomainSpec, HostTreeBackend, ancestor_paths,
                               parent_path)
from repro.core.controller import ControllerConfig
from repro.core.intent import Hint
from repro.core.sharded import ShardedTableBackend

NO_THROTTLE = ControllerConfig(base_delay_ms=0.0, max_delay_ms=0.0)
BACKENDS = ["host", "device", "sharded"]


def mk_cg(kind: str, cap: int = 500) -> AgentCgroup:
    # all three backends run the zero-delay program here so grant/deny
    # parity is independent of op timing; throttling parity (windows,
    # delays) is covered program-by-program in tests/test_progs.py
    if kind == "host":
        from repro.core.progs import GraduatedThrottleProgram
        return AgentCgroup(HostTreeBackend(
            cap, prog=GraduatedThrottleProgram(base_delay_ms=0.0,
                                               max_delay_ms=0.0)))
    if kind == "sharded":
        return AgentCgroup(ShardedTableBackend(cap, n_domains=16,
                                               cfg=NO_THROTTLE))
    return AgentCgroup(DeviceTableBackend(cap, n_domains=16,
                                          cfg=NO_THROTTLE))


def std_tree(cg: AgentCgroup) -> AgentCgroup:
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=120))
    cg.mkdir("/t/b", DomainSpec(max=200, priority=D.LOW))
    cg.mkdir("/t/a/tool", DomainSpec(high=40))
    return cg


# one op sequence exercising charge/deny, uncharge, freeze/thaw,
# rmdir-with-residual, and unchecked lifecycle charges
OPS = [
    ("charge", "/t/a/tool", 60),      # grant; over tool high
    ("charge", "/t/b", 150),          # grant
    ("charge", "/t/b", 100),          # deny: /t/b max=200
    ("uncharge", "/t/b", 50),
    ("charge", "/t/b", 100),          # grant now
    ("freeze", "/t/a", 0),
    ("charge", "/t/a/tool", 5),       # deny: frozen ancestor
    ("thaw", "/t/a", 0),
    ("charge", "/t/a/tool", 5),       # grant again
    ("rmdir", "/t/a/tool", 0),        # residual 65 transfers to /t/a
    ("unchecked", "/t/a", 20),        # lifecycle bookkeeping charge
    ("uncharge", "/t/a", 30),
    ("charge", "/t/a", 400),          # deny: root capacity 500
]

# expected state after OPS — identical for BOTH backends by construction
EXPECTED_GRANTS = [True, True, False, True, False, True, False]
EXPECTED = {"/": 255, "/t": 255, "/t/a": 55, "/t/b": 200}
EXPECTED_PEAK = {"/": 285, "/t": 285, "/t/a": 85, "/t/b": 200}


def run_ops(cg: AgentCgroup):
    grants = []
    for step, (op, path, amt) in enumerate(OPS):
        if op == "charge":
            grants.append(cg.try_charge(path, amt, step=step).granted)
        elif op == "uncharge":
            cg.uncharge(path, amt)
        elif op == "unchecked":
            cg.charge_unchecked(path, amt)
        elif op == "freeze":
            cg.freeze(path)
        elif op == "thaw":
            cg.thaw(path)
        elif op == "rmdir":
            cg.rmdir(path)
    return grants


@pytest.mark.parametrize("kind", BACKENDS)
def test_same_op_sequence_same_results(kind):
    """THE acceptance loop: one op sequence via AgentCgroup against each
    backend; grants, usage, and peak must all match the shared golden
    values (hence each other)."""
    cg = std_tree(mk_cg(kind))
    assert run_ops(cg) == EXPECTED_GRANTS
    for path, want in EXPECTED.items():
        assert cg.usage(path) == want, (kind, path)
    for path, want in EXPECTED_PEAK.items():
        assert cg.peak(path) == want, (kind, path)


def test_backends_agree_directly():
    cgs = [std_tree(mk_cg(kind)) for kind in BACKENDS]
    grants = [run_ops(cg) for cg in cgs]
    assert grants[0] == grants[1] == grants[2]
    for path in ["/", "/t", "/t/a", "/t/b"]:
        assert len({cg.usage(path) for cg in cgs}) == 1, path
        assert len({cg.peak(path) for cg in cgs}) == 1, path


# ------------------------------------------------------- lifecycle parity


@pytest.mark.parametrize("kind", BACKENDS)
def test_rmdir_residual_transfers_to_ancestors(kind):
    """Closing a non-empty tool domain keeps its retained pages
    accounted to the session chain (the residual-transfer rule)."""
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/tool", DomainSpec(high=40))
    assert cg.try_charge("/s/tool", 30).granted
    residual = cg.rmdir("/s/tool")
    assert residual == 30
    assert not cg.exists("/s/tool")
    assert cg.usage("/s") == 30 and cg.usage("/") == 30


@pytest.mark.parametrize("kind", BACKENDS)
def test_rmdir_without_transfer_releases(kind):
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/tool")
    cg.try_charge("/s/tool", 30)
    cg.rmdir("/s/tool", transfer_residual=False)
    assert cg.usage("/s") == 0 and cg.usage("/") == 0


@pytest.mark.parametrize("kind", BACKENDS)
def test_freeze_thaw_recharge_parity(kind):
    """The engine's freeze path: offload (uncharge) + freeze, then thaw
    + unchecked re-charge; ancestor usage must round-trip exactly."""
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/sess")
    assert cg.try_charge("/s/sess", 80).granted
    before = {p: cg.usage(p) for p in ["/", "/s", "/s/sess"]}
    pages = cg.usage("/s/sess")
    cg.uncharge("/s/sess", pages)
    cg.freeze("/s/sess")
    assert not cg.try_charge("/s/sess", 1).granted
    assert cg.usage("/") == 0
    cg.thaw("/s/sess")
    cg.charge_unchecked("/s/sess", pages)
    after = {p: cg.usage(p) for p in ["/", "/s", "/s/sess"]}
    assert after == before


@pytest.mark.parametrize("kind", BACKENDS)
def test_kill_releases_subtree(kind):
    cg = mk_cg(kind)
    cg.mkdir("/s")
    cg.mkdir("/s/a")
    cg.try_charge("/s/a", 40)
    cg.try_charge("/s", 10)
    freed = cg.kill("/s")
    assert freed == 50
    assert cg.usage("/") == 0
    # killed domains stay registered and deny further charges — on
    # both backends
    assert cg.exists("/s") and cg.exists("/s/a")
    assert not cg.try_charge("/s", 5).granted
    assert not cg.try_charge("/s/a", 5).granted


def test_host_driven_throttle_expires_with_facade_clock():
    """A device-backend charge with no explicit step uses the facade
    clock, so an over-``high`` throttle expires instead of pinning all
    later host-driven charges at step 0."""
    cg = AgentCgroup(DeviceTableBackend(500, n_domains=8,
                                        cfg=ControllerConfig()))
    cg.mkdir("/s", DomainSpec(high=10))
    assert cg.try_charge("/s", 20).granted       # over high -> throttled
    assert not cg.try_charge("/s", 1).granted    # still step 0: denied
    cg.set_time(10_000)
    assert cg.try_charge("/s", 1).granted        # throttle expired


# ------------------------------------------------------------ control files


@pytest.mark.parametrize("kind", BACKENDS)
def test_read_write_files(kind):
    cg = mk_cg(kind)
    cg.mkdir("/s", DomainSpec(high=100, max=200, low=10, priority=D.HIGH))
    assert cg.read("/s", "memory.high") == 100
    assert cg.read("/s", "memory.max") == 200
    assert cg.read("/s", "memory.low") == 10
    assert cg.read("/s", "memory.priority") == D.HIGH
    cg.write("/s", "memory.high", 50)
    assert cg.read("/s", "memory.high") == 50
    cg.write("/s", "cgroup.freeze", 1)
    assert cg.read("/s", "cgroup.freeze") == 1
    assert not cg.try_charge("/s", 1).granted
    cg.write("/s", "cgroup.freeze", 0)
    assert cg.try_charge("/s", 1).granted
    with pytest.raises(AssertionError):
        cg.read("/s", "not.a.file")
    with pytest.raises(AssertionError):
        cg.write("/s", "memory.current", 3)      # read-only


def test_host_event_counters():
    cg = mk_cg("host")
    cg.mkdir("/s", DomainSpec(high=10, max=50))
    cg.try_charge("/s", 20)                      # high breach
    cg.try_charge("/s", 100)                     # max breach
    ev = cg.read("/s", "memory.events")
    assert ev["high"] == 1 and ev["max"] == 1


@pytest.mark.parametrize("kind", BACKENDS)
def test_mkdir_requires_parent(kind):
    cg = mk_cg(kind)
    with pytest.raises(FileNotFoundError):
        cg.mkdir("/nope/child")


# ------------------------------------------------------------ intent channel


@pytest.mark.parametrize("kind", BACKENDS)
def test_intent_lease_lifecycle(kind):
    cg = mk_cg(kind)
    cg.mkdir("/sess")
    lease = cg.intent.declare("tool_1", Hint.LOW, parent="/sess")
    assert cg.exists("/sess/tool_1")
    # hint mapped to a memory.high on the tool domain
    assert cg.read(lease.path, "memory.high") < D.UNLIMITED
    cg.try_charge(lease.path, 25)
    fb = lease.feedback("throttled")
    assert fb.reason == "throttled" and fb.peak_pages == 25
    resid = lease.close()
    assert resid == 25 and not cg.exists(lease.path)
    assert cg.usage("/sess") == 25               # residual moved up
    assert lease.close() == 0                    # idempotent
    assert cg.intent.n_declared == 1 and cg.intent.n_feedbacks == 1


def test_path_helpers():
    assert parent_path("/") is None
    assert parent_path("/a") == "/"
    assert parent_path("/a/b/c") == "/a/b"
    assert ancestor_paths("/a/b") == ["/a/b", "/a", "/"]


# ------------------------------------------------------- sharded backend


def test_sharded_tenant_placement_round_robin():
    """Each tenant subtree lands on its own shard; descendants (sessions,
    tool leases) inherit it — the device-group placement rule."""
    cg = mk_cg("sharded")
    be = cg.backend
    for t in range(3):
        cg.mkdir(f"/t{t}")
        cg.mkdir(f"/t{t}/sess")
        lease = cg.intent.declare("tool", Hint.LOW, parent=f"/t{t}/sess")
        shard = be.index[f"/t{t}"][0]
        assert be.index[f"/t{t}/sess"][0] == shard
        assert be.index[lease.path][0] == shard
        lease.close()
    # with one local device everything collapses to shard 0; the true
    # round-robin spread is asserted in the 8-fake-device subprocess test
    assert set(be.placement()) == {"/t0", "/t1", "/t2"}


def test_sharded_device_view_global_handles():
    """The in-step view takes global handles and routes each request to
    the owning shard's table, flat results back."""
    import jax.numpy as jnp
    import numpy as np
    cg = mk_cg("sharded", cap=100)
    cg.mkdir("/t0")
    h = cg.mkdir("/t0/s", DomainSpec(max=30))
    view = cg.device_view()
    dom = jnp.array([h, -1], jnp.int32)
    st, granted, stalled = view.charge(view.state, dom,
                                       jnp.array([10, 5], jnp.int32), 0)
    view.commit(st)
    assert list(np.asarray(granted)) == [True, False]
    assert cg.usage("/t0/s") == 10 and cg.usage("/") == 10
    st, granted, _ = view.charge(view.state, dom,
                                 jnp.array([25, 0], jnp.int32), 1)
    view.commit(st)
    assert list(np.asarray(granted)) == [False, False]    # max=30 wall
    assert list(np.asarray(view.gate(view.state, dom, 2))) == [True, False]
    view.commit(view.uncharge(view.state, dom, jnp.array([10, 0], jnp.int32)))
    assert cg.usage("/") == 0


_SHARDED_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from tests.test_cgroup import (BACKENDS, EXPECTED, EXPECTED_GRANTS,
                               EXPECTED_PEAK, mk_cg, run_ops, std_tree)

assert len(jax.devices()) == 8

# 1) canonical op-sequence parity, sharded vs host, on a real 8-shard mesh
host, shd = std_tree(mk_cg("host")), std_tree(mk_cg("sharded"))
assert shd.backend.n_shards == 8
assert run_ops(host) == run_ops(shd) == EXPECTED_GRANTS
for path, want in EXPECTED.items():
    assert host.usage(path) == shd.usage(path) == want, path
for path, want in EXPECTED_PEAK.items():
    assert host.peak(path) == shd.peak(path) == want, path

# 2) tenants spread round-robin over distinct shards; root reconciles
cg = mk_cg("sharded", cap=800)
for t in range(8):
    cg.mkdir(f"/t{t}")
    assert cg.try_charge(f"/t{t}", 10 * (t + 1)).granted
assert sorted(cg.backend.placement().values()) == list(range(8))
assert cg.usage("/") == sum(10 * (t + 1) for t in range(8))

# 3) global root capacity enforced across shards host-side
assert not cg.try_charge("/t0", 800).granted

# 4) attached PolicyProgram parity on a real 8-shard mesh: the token
# bucket rate-limits identically on host and sharded backends, even for
# a tenant placed on shard > 0
from repro.core.progs import TokenBucketProgram
def mk_tb(kind):
    cg = mk_cg(kind, cap=10_000)
    cg.attach("/", TokenBucketProgram(bucket_capacity=16,
                                      refill=(1.0, 2.0, 4.0)))
    for t in range(3):
        cg.mkdir(f"/t{t}")
    return cg
h, s = mk_tb("host"), mk_tb("sharded")
assert s.backend.index["/t2"][0] == 2          # placed off shard 0
for i, (path, amt) in enumerate([("/t2", 16), ("/t2", 8), ("/t2", 4),
                                 ("/t2", 2), ("/t0", 16), ("/t2", 30)]):
    hw, sw = h.try_charge(path, amt, step=i), s.try_charge(path, amt, step=i)
    assert (hw.granted, hw.stalled) == (sw.granted, sw.stalled), (i, path)
assert h.usage("/") == s.usage("/")
print("SHARDED8 OK")
"""


def test_sharded_parity_on_8_fake_devices():
    env = dict(os.environ)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root])
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", _SHARDED_8DEV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "SHARDED8 OK" in out.stdout, \
        out.stderr[-3000:]

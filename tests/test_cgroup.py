"""The unified cgroupfs-style control plane (core/cgroup.py).

Backend parity is the point of the facade — and since PR 5 the parity
machinery lives in ``repro.testing.conformance``: one declarative
scenario set replayed against every ``Backend`` (host tree /
single-device table / sharded multi-device table / async lifecycle
daemon over each) and diffed against the reference host semantics.
This module certifies all standard backend kinds through that kit,
pins the canonical scenario to absolute golden values (so reference
and backends cannot drift together), and keeps the backend-specific
extras: facade-clock throttle expiry, sharded tenant placement, and
the 8-fake-device subprocess run.
"""
import os
import subprocess
import sys

import pytest

from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend, ancestor_paths, parent_path)
from repro.core.controller import ControllerConfig
from repro.testing.conformance import (BACKEND_KINDS, ConformanceSuite,
                                       OpRecorder, backend_features,
                                       get_scenario, replay,
                                       standard_backend_factory)

# one suite for the whole module: reference observations are computed
# once per scenario and reused across every parametrized backend kind
SUITE = ConformanceSuite()


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_backend_conformance(kind):
    """THE acceptance loop: every backend kind — including the async
    daemon over each inner backend — certifies itself against the full
    standard scenario set, bit-identically to the reference."""
    report = SUITE.run(standard_backend_factory(kind),
                       features=backend_features(kind))
    assert report.ok, report.summary()


def test_lifecycle_scenario_absolute_goldens():
    """Pin the canonical op sequence to absolute values (kit runs are
    relative to the reference; this guards against co-drift)."""
    sc = get_scenario("lifecycle")
    obs = replay(AgentCgroup(standard_backend_factory("host")(
        sc.capacity, sc.n_domains)), sc)
    grants = [v[0] for _, n, v in obs if n == "charge"]
    assert grants == [True, True, False, True, False, True, False]
    residual = [v for _, n, v in obs if n == "rmdir"]
    assert residual == [65]
    usage = {p: u for _, n, (p, u) in
             ((i, n, v) for i, n, v in obs if n == "usage")}
    assert usage == {"/": 255, "/t": 255, "/t/a": 55, "/t/b": 200}
    peak = {p: u for _, n, (p, u) in
            ((i, n, v) for i, n, v in obs if n == "peak")}
    assert peak == {"/": 285, "/t": 285, "/t/a": 85, "/t/b": 200}


def test_memcg_events_scenario_absolute_goldens():
    """The events scenario is host-vs-host for the 'host' kind, so pin
    the counters to absolute values here (a DomainTree accounting
    regression must not pass as trivial self-parity)."""
    sc = get_scenario("memcg_events")
    obs = replay(AgentCgroup(standard_backend_factory("host")(
        sc.capacity, sc.n_domains)), sc)
    events = [v[2] for _, n, v in obs if n == "read"]
    assert events == [{"high": 1, "max": 1, "throttle": 1, "oom_kill": 0}]
    charges = [v for _, n, v in obs if n == "charge"]
    assert charges == [(True, False, 110.0),     # over-high: 10*(1+10*1.0)
                       (False, True, 100.0)]     # max wall inside window


def test_recorder_roundtrips_to_replayable_scenario():
    """Drive a live cg through the recorder; the recorded scenario
    replays to identical observations on a fresh backend."""
    rec = OpRecorder(AgentCgroup(HostTreeBackend(500)))
    rec.mkdir("/s")
    rec.mkdir("/s/tool", high=40)
    rec.try_charge("/s/tool", 30, step=0)
    rec.write("/s/tool", "memory.high", 20)
    rec.try_charge("/s/tool", 5, step=1)
    rec.rmdir("/s/tool")
    rec.read("/s", "memory.current")
    sc = rec.to_scenario("recorded")
    a = replay(AgentCgroup(HostTreeBackend(500)), sc)
    b = replay(AgentCgroup(DeviceTableBackend(500, n_domains=8)), sc)
    # the full event stream includes host-only breach/throttle kinds;
    # everything else (including the portable lifecycle stream) matches
    a = [r for r in a if r[1] != "events_all"]
    b = [r for r in b if r[1] != "events_all"]
    assert a == b


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_mkdir_requires_parent(kind):
    cg = AgentCgroup(standard_backend_factory(kind)(500, 16))
    with pytest.raises(FileNotFoundError):
        cg.mkdir("/nope/child")


def test_read_write_file_validation():
    cg = AgentCgroup(HostTreeBackend(500))
    cg.mkdir("/s")
    with pytest.raises(AssertionError):
        cg.read("/s", "not.a.file")
    with pytest.raises(AssertionError):
        cg.write("/s", "memory.current", 3)      # read-only


def test_host_driven_throttle_expires_with_facade_clock():
    """A device-backend charge with no explicit step uses the facade
    clock, so an over-``high`` throttle expires instead of pinning all
    later host-driven charges at step 0."""
    cg = AgentCgroup(DeviceTableBackend(500, n_domains=8,
                                        cfg=ControllerConfig()))
    cg.mkdir("/s", DomainSpec(high=10))
    assert cg.try_charge("/s", 20).granted       # over high -> throttled
    assert not cg.try_charge("/s", 1).granted    # still step 0: denied
    cg.set_time(10_000)
    assert cg.try_charge("/s", 1).granted        # throttle expired


def test_path_helpers():
    assert parent_path("/") is None
    assert parent_path("/a") == "/"
    assert parent_path("/a/b/c") == "/a/b"
    assert ancestor_paths("/a/b") == ["/a/b", "/a", "/"]


# ------------------------------------------------------- sharded backend


def mk_sharded(cap: int = 500) -> AgentCgroup:
    return AgentCgroup(standard_backend_factory("sharded")(cap, 16))


def test_sharded_tenant_placement_round_robin():
    """Each tenant subtree lands on its own shard; descendants (sessions,
    tool leases) inherit it — the device-group placement rule."""
    from repro.core.intent import Hint
    cg = mk_sharded()
    be = cg.backend
    for t in range(3):
        cg.mkdir(f"/t{t}")
        cg.mkdir(f"/t{t}/sess")
        lease = cg.intent.declare("tool", Hint.LOW, parent=f"/t{t}/sess")
        shard = be.index[f"/t{t}"][0]
        assert be.index[f"/t{t}/sess"][0] == shard
        assert be.index[lease.path][0] == shard
        lease.close()
    # with one local device everything collapses to shard 0; the true
    # round-robin spread is asserted in the 8-fake-device subprocess test
    assert set(be.placement()) == {"/t0", "/t1", "/t2"}


def test_sharded_device_view_global_handles():
    """The in-step view takes global handles and routes each request to
    the owning shard's table, flat results back."""
    import jax.numpy as jnp
    import numpy as np
    cg = mk_sharded(cap=100)
    cg.mkdir("/t0")
    h = cg.mkdir("/t0/s", DomainSpec(max=30))
    view = cg.device_view()
    dom = jnp.array([h, -1], jnp.int32)
    st, granted, stalled = view.charge(view.state, dom,
                                       jnp.array([10, 5], jnp.int32), 0)
    view.commit(st)
    assert list(np.asarray(granted)) == [True, False]
    assert cg.usage("/t0/s") == 10 and cg.usage("/") == 10
    st, granted, _ = view.charge(view.state, dom,
                                 jnp.array([25, 0], jnp.int32), 1)
    view.commit(st)
    assert list(np.asarray(granted)) == [False, False]    # max=30 wall
    assert list(np.asarray(view.gate(view.state, dom, 2))) == [True, False]
    view.commit(view.uncharge(view.state, dom, jnp.array([10, 0], jnp.int32)))
    assert cg.usage("/") == 0


_SHARDED_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core.cgroup import AgentCgroup
from repro.testing.conformance import (ConformanceSuite, backend_features,
                                       standard_backend_factory)

assert len(jax.devices()) == 8

# 1) the full conformance set on a real 8-shard mesh — including the
# async daemon over the sharded backend, and the token-bucket scenario
# whose tenants land on shards > 0
suite = ConformanceSuite()
for kind in ("sharded", "async-sharded"):
    report = suite.run(standard_backend_factory(kind),
                       features=backend_features(kind))
    assert report.ok, report.summary()

# 2) tenants spread round-robin over distinct shards; root reconciles
cg = AgentCgroup(standard_backend_factory("sharded")(800, 16))
assert cg.backend.n_shards == 8
for t in range(8):
    cg.mkdir(f"/t{t}")
    assert cg.try_charge(f"/t{t}", 10 * (t + 1)).granted
assert sorted(cg.backend.placement().values()) == list(range(8))
assert cg.usage("/") == sum(10 * (t + 1) for t in range(8))

# 3) global root capacity enforced across shards host-side
assert not cg.try_charge("/t0", 800).granted
print("SHARDED8 OK")
"""


def test_sharded_parity_on_8_fake_devices():
    env = dict(os.environ)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root])
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", _SHARDED_8DEV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "SHARDED8 OK" in out.stdout, \
        out.stderr[-3000:]

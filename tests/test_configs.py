"""Assignment conformance: every arch config matches the assigned
numbers; the 40-cell applicability matrix is exactly as designed."""
import pytest

from repro.configs import (ARCH_IDS, SHAPES, all_configs, cell_applicability,
                           get_config, iter_cells, reduced)

ASSIGNED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_numbers(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = ASSIGNED[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v


def test_moe_configs():
    j = get_config("jamba-v0.1-52b").moe
    assert (j.n_experts, j.top_k) == (16, 2)
    ds = get_config("deepseek-v2-236b")
    assert (ds.moe.n_experts, ds.moe.top_k, ds.moe.n_shared) == (160, 6, 2)
    assert ds.mla.kv_lora_rank == 512
    l4 = get_config("llama4-maverick-400b-a17b").moe
    assert (l4.n_experts, l4.top_k) == (128, 1)


def test_param_counts_in_band():
    """Analytic totals should land near the advertised sizes."""
    bands = {
        "llama3.2-3b": (2.5e9, 4.5e9),
        "phi3-medium-14b": (11e9, 16e9),
        "minicpm-2b": (2e9, 3.6e9),
        "internlm2-20b": (17e9, 23e9),
        "pixtral-12b": (10e9, 14.5e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "llama4-maverick-400b-a17b": (320e9, 440e9),
        "jamba-v0.1-52b": (44e9, 60e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
        "xlstm-350m": (0.25e9, 0.55e9),
    }
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_active_params_moe():
    ds = get_config("deepseek-v2-236b")
    active = ds.param_count(active_only=True)
    total = ds.param_count()
    assert active < total * 0.2, (active, total)


def test_cell_matrix():
    cells = list(iter_cells())
    assert len(cells) == 40
    skips = [(a, s.name, r) for a, s, ok, r in cells if not ok]
    # hubert: decode_32k + long_500k; 7 full-attention archs: long_500k
    assert len(skips) == 9, skips
    assert sum(1 for a, s, _ in skips if a == "hubert-xlarge") == 2
    long_runners = [a for a, s, ok, _ in cells
                    if s.name == "long_500k" and ok]
    assert sorted(long_runners) == ["jamba-v0.1-52b", "xlstm-350m"]


def test_reduced_same_family():
    for arch in ARCH_IDS:
        full, red = get_config(arch), reduced(get_config(arch))
        assert red.family == full.family
        assert red.layer_kinds() == full.layer_kinds()[:red.group_size]
        assert (red.moe is None) == (full.moe is None)
        assert red.n_layers <= 8 and red.d_model <= 128


def test_group_pattern_jamba():
    cfg = get_config("jamba-v0.1-52b")
    kinds = cfg.layer_kinds()
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert kinds[4] == "attn"
    ffns = cfg.ffn_kinds()
    assert ffns == ["dense", "moe"] * 4

"""HLO cost parser: validated against cost_analysis on scan-free graphs
and against analytic counts on scanned graphs (trip-count awareness)."""
import os
import subprocess
import sys

import numpy as np


def _run(code: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_parser_matches_analytic_scan_flops():
    out = _run(r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo import analyze
from repro.compat import make_auto_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
D = 128
def body(x, w):
    return jax.nn.relu(jnp.einsum("bd,df->bf", x, w)), None
def stacked(ws, x):
    return jax.lax.scan(body, x, ws)[0].sum()
ws = jax.ShapeDtypeStruct((6, D, D), jnp.float32,
                          sharding=NamedSharding(mesh, P(None, None, "model")))
xs = jax.ShapeDtypeStruct((8, D), jnp.float32,
                          sharding=NamedSharding(mesh, P("data", None)))
with mesh:
    compiled = jax.jit(stacked).lower(ws, xs).compile()
r = analyze(compiled.as_text(), pod_size=4)
analytic = 6 * 2 * 4 * 128 * 32       # per-device: 6 iters, B_loc=4, f_loc=32
assert abs(r["flops"] - analytic) / analytic < 0.01, (r["flops"], analytic)
assert r["coll_bytes_total"] > 0
print("OK", r["flops"])
""")
    assert "OK" in out


def test_parser_matches_cost_analysis_no_scan():
    out = _run(r"""
import jax, jax.numpy as jnp
from repro.analysis.hlo import analyze
from repro.compat import cost_analysis
def f(a, b):
    return (a @ b).sum()
a = jnp.ones((64, 128)); b = jnp.ones((128, 32))
compiled = jax.jit(f).lower(a, b).compile()
ca = cost_analysis(compiled)
r = analyze(compiled.as_text())
# dot flops identical when there is no while loop
assert abs(r["flops"] - 2 * 64 * 128 * 32) < 1e3, r["flops"]
assert abs(ca["flops"] - r["flops"]) / max(ca["flops"], 1) < 0.05
print("OK")
""")
    assert "OK" in out


def test_collective_classification_dcn():
    out = _run(r"""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.analysis.hlo import analyze
from repro.compat import make_auto_mesh
mesh = make_auto_mesh((2, 4), ("pod", "data"))
x = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P(("pod", "data"), None)))
def f(t):
    return t.sum()                      # all-reduce across all 8 devices
with mesh:
    compiled = jax.jit(f).lower(x).compile()
r = analyze(compiled.as_text(), pod_size=4)
# the reduction spans the pod boundary -> classified as DCN traffic
assert r["coll_bytes_total"] > 0
assert r["coll_dcn_bytes"] > 0, r
print("OK")
""")
    assert "OK" in out


def test_roofline_terms():
    from repro.analysis.roofline import model_flops, roofline_from_costs
    from repro.configs import SHAPES, get_config
    cfg = get_config("llama3.2-3b")
    parsed = {"flops": 1e13, "bytes": 1e12, "coll_bytes_total": 5e10,
              "coll_dcn_bytes": 1e10}
    r = roofline_from_costs(cfg, SHAPES["train_4k"], parsed, n_chips=256)
    assert r["compute_s"] == 1e13 / 197e12
    assert r["memory_s"] == 1e12 / 819e9
    assert abs(r["collective_s"] - (4e10 / 50e9 + 1e10 / 25e9)) < 1e-9
    assert r["dominant"] == "memory_s"
    assert 0 < r["useful_flop_ratio"]
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    mf_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert mf_train / mf_dec == (3 * 4096 * 256) / 128

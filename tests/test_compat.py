"""repro.compat under the *installed* JAX: every shimmed symbol must
resolve and produce a usable object — this is the regression canary for
the API drift that once broke 26 tests (pltpu.CompilerParams rename,
jax.sharding.AxisType / make_mesh axis_types, jax.shard_map move,
cost_analysis list-vs-dict)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat


def test_version_tuple():
    assert len(compat.JAX_VERSION) == 3
    assert all(isinstance(x, int) for x in compat.JAX_VERSION)


def test_tpu_compiler_params_resolves():
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert params is not None
    # whichever class the installed pallas exposes, the kwarg landed
    assert tuple(getattr(params, "dimension_semantics", ())) == \
        ("parallel", "arbitrary") or isinstance(params, dict)


def test_tpu_compiler_params_accepted_by_pallas_call():
    """The shimmed params must pass through a real (interpret-mode)
    pallas_call on the installed version."""
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, 8), jnp.float32),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=True,
    )(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 2.0)


def test_make_auto_mesh_resolves():
    mesh = compat.make_auto_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    sh = NamedSharding(mesh, P("data"))
    y = jax.device_put(jnp.zeros((4, 2)), sh)
    assert y.shape == (4, 2)


def test_shard_map_resolves_and_runs():
    mesh = compat.make_auto_mesh((1,), ("s",))
    fn = compat.shard_map(lambda x: x + 1, mesh=mesh,
                          in_specs=(P("s"),), out_specs=P("s"))
    out = fn(jnp.zeros((1, 3)))
    np.testing.assert_allclose(np.asarray(out), np.ones((1, 3)))


def test_cost_analysis_normalized_to_dict():
    compiled = jax.jit(lambda a: (a @ a).sum()).lower(
        jnp.ones((8, 8))).compile()
    ca = compat.cost_analysis(compiled)
    assert isinstance(ca, dict)
    assert ca.get("flops", 0) > 0


def test_pallas_interpret_resolution():
    assert compat.pallas_interpret(True) is True
    assert compat.pallas_interpret(False) is False
    if not compat.on_tpu():
        assert compat.pallas_interpret(None) is True

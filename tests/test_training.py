"""Training substrate: convergence, microbatch equivalence, gradient
compression with error feedback, schedules, optimizer math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, reduced
from repro.data.pipeline import DataIterator
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.training import compression
from repro.training.optimizer import (OptConfig, adamw_update,
                                      init_opt_state, make_schedule)
from repro.training.train_step import init_train_state, make_train_step

BASE_PERF = perf_replace(DEFAULT_PERF, scan_chunk=32, remat="none")


def setup(arch="minicpm-2b", batch=4, seq=64, perf=BASE_PERF, steps=30):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=seq,
                                global_batch=batch)
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    opt_cfg = OptConfig(schedule=cfg.schedule, warmup_steps=3,
                        total_steps=steps, lr=1e-3)
    step = jax.jit(make_train_step(cfg, perf, opt_cfg))
    data = DataIterator(cfg, shape, seed=0, batch=batch, seq=seq)
    return cfg, params, init_train_state(cfg, params, perf), step, data


def run_steps(params, opt, step_fn, data, n):
    losses = []
    for i in range(n):
        params, opt, m = step_fn(params, opt, data.at(i), i)
        losses.append(float(m["loss"]))
    return params, losses


def test_convergence_on_learnable_data():
    cfg, params, opt, step, data = setup(steps=30)
    _, losses = run_steps(params, opt, step, data, 30)
    assert losses[0] > 5.5                    # ~ln(512) at init
    assert losses[-1] < losses[0] - 1.0       # clearly learning


def test_microbatch_grads_match_full_batch():
    cfg, params, opt, _, data = setup()
    batch = data.at(0)
    from repro.models.model import loss_fn
    g_full = jax.grad(lambda p: loss_fn(cfg, p, batch, perf=BASE_PERF)[0])(
        params)
    perf_mb = perf_replace(BASE_PERF, microbatches=2)
    step_mb = make_train_step(cfg, perf_mb, OptConfig(lr=0.0,
                                                      weight_decay=0.0,
                                                      grad_clip=1e9))
    # lr=0: params unchanged; compare the computed grad via opt moments
    opt0 = init_train_state(cfg, params, perf_mb)
    _, opt1, m = jax.jit(step_mb)(params, opt0, batch, 0)
    # m1 = (1-b1) * grad after one step
    g_mb = jax.tree.map(lambda x: x / 0.1, opt1["m"])
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_mb)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_grad_compression_tracks_fp32():
    cfg, p0, o0, step0, data = setup(steps=25)
    _, base_losses = run_steps(p0, o0, step0, data, 25)
    perf_c = perf_replace(BASE_PERF, grad_compress=True)
    cfg2, p1, o1, step1, data1 = setup(perf=perf_c, steps=25)
    _, comp_losses = run_steps(p1, o1, step1, data1, 25)
    # error feedback keeps compressed training within a small gap
    assert abs(comp_losses[-1] - base_losses[-1]) < 0.35


def test_error_feedback_reduces_bias():
    k = jax.random.PRNGKey(3)
    g = jax.random.normal(k, (256,)) * 1e-3
    err = jnp.zeros_like(g)
    acc_plain = jnp.zeros_like(g)
    acc_fb = jnp.zeros_like(g)
    err_acc = jnp.zeros_like(g)
    for i in range(20):
        gh, _ = compression.quantize_leaf(g, jnp.zeros_like(g))
        acc_plain += gh
        gh2, err_acc = compression.quantize_leaf(g, err_acc)
        acc_fb += gh2
    true = g * 20
    assert (jnp.abs(acc_fb - true).max()
            <= jnp.abs(acc_plain - true).max() + 1e-7)


def test_schedules():
    cos = make_schedule(OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                  schedule="cosine"))
    wsd = make_schedule(OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                  schedule="wsd"))
    assert float(cos(0)) == 0.0
    assert abs(float(cos(10)) - 1.0) < 1e-6
    assert float(cos(100)) <= 0.11
    # WSD: flat at peak through the stable phase, then fast decay
    assert abs(float(wsd(11)) - 1.0) < 1e-5
    assert abs(float(wsd(80)) - 1.0) < 1e-5   # still stable at 80%
    assert float(wsd(100)) <= 0.11


def test_adamw_step_direction():
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.ones((4,), jnp.float32)}
    st = init_opt_state(p)
    cfg = OptConfig(lr=0.1, weight_decay=0.0)
    p2, st2, gn = adamw_update(g, st, p, 0.1, cfg)
    assert float(p2["w"][0]) < 1.0            # moved against the gradient
    assert float(gn) == pytest.approx(2.0)


def test_compressed_psum_multidevice():
    """int8 all-gather all-reduce == fp32 psum (separate process with 8
    fake devices)."""
    import subprocess, sys, os
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.training.compression import compressed_psum
from repro.compat import make_auto_mesh
mesh = make_auto_mesh((8,), ("data",))
x = jnp.linspace(-1.0, 1.0, 64).reshape(8, 8)
with mesh:
    got = jax.jit(lambda t: compressed_psum(t, mesh, "data"))(x)
want = x * 8.0
err = float(jnp.max(jnp.abs(got - want)))
assert err < 8 * 2.0 / 127, err
txt = jax.jit(lambda t: compressed_psum(t, mesh, "data")).lower(x).compile().as_text()
assert "all-gather" in txt and "s8[" in txt, "int8 payload not on the wire"
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]

"""MoE dispatch equivalence: the three implementations (dense masked,
capacity-gather, shard_map all-to-all) must agree numerically when
capacity is generous (no drops) — dense is the oracle.  The a2a test
runs on a real (2,4) device mesh in a subprocess."""
import os
import subprocess
import sys

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace


def _setup():
    cfg = dataclasses.replace(reduced(get_config("jamba-v0.1-52b")),
                              dtype="float32")
    p = init_params(moe_mod.moe_schema(cfg), jax.random.PRNGKey(0),
                    cfg.dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_gather_matches_dense_no_drops():
    cfg, p, x = _setup()
    y_dense, aux_d = moe_mod.moe_forward(
        cfg, p, x, perf=perf_replace(DEFAULT_PERF, moe_impl="dense"))
    y_gather, aux_g = moe_mod.moe_forward(
        cfg, p, x, perf=perf_replace(DEFAULT_PERF, moe_impl="gather",
                                     capacity_factor=8.0))
    np.testing.assert_allclose(np.asarray(y_gather), np.asarray(y_dense),
                               atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_d), rtol=1e-5)


def test_gather_grads_match_dense():
    cfg, p, x = _setup()

    def loss(impl):
        def f(params):
            y, aux = moe_mod.moe_forward(
                cfg, params, x,
                perf=perf_replace(DEFAULT_PERF, moe_impl=impl,
                                  capacity_factor=8.0))
            return jnp.sum(y ** 2) + aux
        return jax.grad(f)(p)

    gd, gg = loss("dense"), loss("gather")
    for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gg)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   atol=5e-4, rtol=5e-3)


def test_a2a_matches_dense_multidevice():
    """a2a == dense on a (2,4) mesh (subprocess with 8 fake devices)."""
    code = r"""
import dataclasses, os
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.models.schema import init_params, shardings
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.sharding_ctx import activation_rules

from repro.compat import make_auto_mesh
mesh = make_auto_mesh((2, 4), ("data", "model"))
rules = {"tp": "model", "fsdp": "data", "ep": "model", "ep2": "data",
         "act_batch": "data", "act_seq": "model", "layers": None}
cfg = dataclasses.replace(reduced(get_config("jamba-v0.1-52b")),
                          dtype="float32")
p = init_params(moe_mod.moe_schema(cfg), jax.random.PRNGKey(0), cfg.dtype)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                      jnp.float32)
y_dense, aux_d = moe_mod.moe_forward(
    cfg, p, x, perf=perf_replace(DEFAULT_PERF, moe_impl="dense"))

sh = shardings(moe_mod.moe_schema(cfg), mesh, rules)
p_sh = jax.tree.map(jax.device_put, p, sh)
from jax.sharding import NamedSharding, PartitionSpec as P
x_sh = jax.device_put(x, NamedSharding(mesh, P("data", "model", None)))
perf = perf_replace(DEFAULT_PERF, moe_impl="a2a", capacity_factor=8.0)
with mesh:
    with activation_rules(rules, mesh=mesh):
        y_a2a, aux_a = jax.jit(
            lambda pp, xx: moe_mod.moe_forward(cfg, pp, xx, perf=perf))(
            p_sh, x_sh)
np.testing.assert_allclose(np.asarray(y_a2a), np.asarray(y_dense),
                           atol=2e-4, rtol=1e-3)
# aux differs slightly by construction: a2a averages SHARD-LOCAL
# load-balance statistics (f_e, P_e per device) while dense computes
# them globally — standard per-microbatch aux behaviour
np.testing.assert_allclose(float(aux_a), float(aux_d), rtol=0.15)
print("A2A OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "A2A OK" in out.stdout, out.stderr[-3000:]

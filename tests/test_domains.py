"""Property tests (hypothesis) for the hierarchical resource domains —
the system's core invariants, mirroring the memcg contract."""
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import domains as D


def mk_tree(cap=1000):
    t = D.DomainTree(cap)
    t.create("/a", high=400, priority=D.HIGH)
    t.create("/b", max=300, priority=D.LOW)
    t.create("/a/s1")
    t.create("/a/s1/tool", high=50)
    t.create("/b/s2")
    return t


LEAVES = ["/a/s1/tool", "/a/s1", "/b/s2", "/a", "/b"]

ops = st.lists(
    st.tuples(st.sampled_from(["charge", "uncharge", "kill", "freeze",
                               "thaw"]),
              st.sampled_from(LEAVES),
              st.integers(min_value=1, max_value=200)),
    min_size=1, max_size=60)


@given(ops)
@settings(max_examples=200, deadline=None)
def test_invariants_random_ops(op_list):
    t = mk_tree()
    charged = {p: 0 for p in LEAVES}       # net direct charges per domain
    for op, path, amt in op_list:
        if op == "charge":
            d = t.get(path)
            before = {n.name: n.usage for n in d.ancestors()}
            res = t.try_charge(path, amt)
            if not res.ok:
                # atomicity: a failed charge changes nothing
                for n in d.ancestors():
                    assert n.usage == before[n.name]
            else:
                charged[path] += amt
        elif op == "uncharge":
            take = min(amt, t.get(path).usage, charged[path])
            if take > 0:
                t.uncharge(path, take)
                charged[path] -= take
        elif op == "kill":
            t.kill(path)
            for sub in t.subtree(path):
                for p in charged:
                    if p == sub.name or p.startswith(sub.name + "/"):
                        charged[p] = 0
        elif op == "freeze":
            t.freeze(path)
        else:
            t.thaw(path)

        # ---- invariants after every op ----
        # no domain exceeds its hard limit
        for n in t.subtree("/"):
            assert n.usage <= n.max
            assert n.usage >= 0
            assert n.peak >= n.usage
        # hierarchical accounting: parent usage >= sum of children
        for n in t.subtree("/"):
            s = sum(c.usage for c in n.children.values())
            assert n.usage >= s


@given(st.integers(1, 500), st.integers(1, 500))
@settings(max_examples=100, deadline=None)
def test_charge_uncharge_roundtrip(a, b):
    t = mk_tree(cap=2000)
    r1 = t.try_charge("/a/s1", a)
    r2 = t.try_charge("/b/s2", b)
    if r1.ok:
        t.uncharge("/a/s1", a)
    if r2.ok:
        t.uncharge("/b/s2", b)
    assert t.root.usage == 0
    assert t.get("/a").usage == 0 and t.get("/b").usage == 0


def test_frozen_domain_denies_charge():
    t = mk_tree()
    t.freeze("/b")
    assert not t.try_charge("/b/s2", 1).ok
    t.thaw("/b")
    assert t.try_charge("/b/s2", 1).ok


def test_hard_limit_blocks_at_correct_ancestor():
    t = mk_tree()
    assert t.try_charge("/b/s2", 300).ok
    res = t.try_charge("/b/s2", 1)
    assert not res.ok and res.blocked_by == "/b"


def test_soft_limit_reports_breach_and_throttles():
    t = mk_tree()
    res = t.try_charge("/a/s1/tool", 60)
    assert res.ok and "/a/s1/tool" in res.over_high
    d = t.throttle_delay_ms("/a/s1/tool")
    assert d > 0
    # HIGH-priority domains get the latency discount
    t2 = mk_tree()
    t2.try_charge("/a", 450)                 # over /a's high=400
    d_high = t2.throttle_delay_ms("/a")
    t2b = mk_tree()
    t2b.get("/b").high = 400
    t2b.try_charge("/b", 290)
    assert d_high < 10.0                     # 0.1x discount applied


def test_oom_group_atomic_kill():
    t = mk_tree()
    t.try_charge("/a/s1/tool", 40)
    t.try_charge("/a/s1", 30)
    before_root = t.root.usage
    freed = t.kill("/a/s1")
    assert freed == 70
    assert t.root.usage == before_root - 70
    assert t.get("/a/s1").killed and t.get("/a/s1/tool").killed


def test_below_low_protection():
    t = D.DomainTree(1000)
    t.create("/p", high=100, low=200)
    t.try_charge("/p", 150)                  # over high but under low
    assert t.throttle_delay_ms("/p") == 0.0  # protected

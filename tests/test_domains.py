"""Deterministic tests for the hierarchical resource domains — the
memcg contract's directed cases.  (The randomized invariant sweeps
live in ``test_properties.py`` and need ``hypothesis``.)"""
from repro.core import domains as D


def mk_tree(cap=1000):
    t = D.DomainTree(cap)
    t.create("/a", high=400, priority=D.HIGH)
    t.create("/b", max=300, priority=D.LOW)
    t.create("/a/s1")
    t.create("/a/s1/tool", high=50)
    t.create("/b/s2")
    return t


def test_frozen_domain_denies_charge():
    t = mk_tree()
    t.freeze("/b")
    assert not t.try_charge("/b/s2", 1).ok
    t.thaw("/b")
    assert t.try_charge("/b/s2", 1).ok


def test_hard_limit_blocks_at_correct_ancestor():
    t = mk_tree()
    assert t.try_charge("/b/s2", 300).ok
    res = t.try_charge("/b/s2", 1)
    assert not res.ok and res.blocked_by == "/b"


def test_soft_limit_reports_breach_and_throttles():
    t = mk_tree()
    res = t.try_charge("/a/s1/tool", 60)
    assert res.ok and "/a/s1/tool" in res.over_high
    d = t.throttle_delay_ms("/a/s1/tool")
    assert d > 0
    # HIGH-priority domains get the latency discount
    t2 = mk_tree()
    t2.try_charge("/a", 450)                 # over /a's high=400
    d_high = t2.throttle_delay_ms("/a")
    t2b = mk_tree()
    t2b.get("/b").high = 400
    t2b.try_charge("/b", 290)
    assert d_high < 10.0                     # 0.1x discount applied


def test_oom_group_atomic_kill():
    t = mk_tree()
    t.try_charge("/a/s1/tool", 40)
    t.try_charge("/a/s1", 30)
    before_root = t.root.usage
    freed = t.kill("/a/s1")
    assert freed == 70
    assert t.root.usage == before_root - 70
    assert t.get("/a/s1").killed and t.get("/a/s1/tool").killed


def test_below_low_protection():
    t = D.DomainTree(1000)
    t.create("/p", high=100, low=200)
    t.try_charge("/p", 150)                  # over high but under low
    assert t.throttle_delay_ms("/p") == 0.0  # protected

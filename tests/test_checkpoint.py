"""Checkpointing: atomicity, keep-k GC, bit-exact resume, crash-restart
via the real training driver (failure injection)."""
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.checkpoint.manager import CheckpointManager


def tree():
    return {"a": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "b": [jnp.ones((2,), jnp.bfloat16), jnp.zeros((), jnp.int32)]}


def test_save_load_roundtrip(tmp_path):
    t = tree()
    p = str(tmp_path / "c.npz")
    ckpt.save(p, 7, t)
    step, t2 = ckpt.load(p, t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


import jax  # noqa: E402  (used above in tree comparisons)


def test_atomic_no_partial_file(tmp_path):
    p = str(tmp_path / "c.npz")
    ckpt.save(p, 1, tree())
    # a tmp file from a 'crashed' write must not confuse the manager
    with open(str(tmp_path / "ckpt_00000009.npz.tmp.999"), "wb") as f:
        f.write(b"garbage")
    mgr = CheckpointManager(str(tmp_path), keep=3)
    assert mgr.steps() == []


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1,
                            async_write=False)
    for s in range(1, 6):
        mgr.maybe_save(s, tree())
    assert mgr.steps() == [4, 5]


def test_async_writer_overlap(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5, every=1)
    for s in range(1, 4):
        mgr.maybe_save(s, tree())
    mgr.finalize()
    assert mgr.steps() == [1, 2, 3]


def _run_driver(tmp_path, extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", "llama3.2-3b", "--reduced", "--steps", "16",
           "--batch", "2", "--seq", "32", "--ckpt-every", "5",
           "--sync-ckpt",
           "--ckpt-dir", str(tmp_path / "ck"), "--log-every", "100"] + extra
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=600)


@pytest.mark.slow
def test_crash_and_restart_bit_exact(tmp_path):
    """Kill the driver mid-run; restart must resume from the last
    checkpoint and finish with the same final loss as an uninterrupted
    run (data is a pure function of step)."""
    r1 = _run_driver(tmp_path, ["--crash-at", "8"])
    assert r1.returncode == 42, r1.stderr[-1500:]
    r2 = _run_driver(tmp_path, [])
    assert r2.returncode == 0, r2.stderr[-1500:]
    rep2 = json.loads(r2.stdout.strip().splitlines()[-1])
    assert rep2["resumed_from"] == 5
    # uninterrupted reference
    r3 = _run_driver(tmp_path.parent / "ref", [])
    rep3 = json.loads(r3.stdout.strip().splitlines()[-1])
    assert abs(rep2["last_loss"] - rep3["last_loss"]) < 1e-5

"""Device-resident controller kernel semantics: batched charge
serialization, slot gating, throttle quantization.  (The randomized
host/device cross-validation lives in ``test_properties.py``; the
deterministic cross-backend parity suite in ``test_cgroup.py``.)"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as D
from repro.core.controller import (ControllerConfig, DeviceDomainTable,
                                   charge_batch, host_charge, slot_gate,
                                   uncharge_batch)

CFG = ControllerConfig(step_ms=10.0)


def mk_pair(cap=500):
    tab = DeviceDomainTable(cap, n_domains=16, cfg=CFG)
    tree = D.DomainTree(cap)
    for path, kw in [("/t", {}), ("/t/a", dict(high=120)),
                     ("/t/b", dict(max=200, priority=D.LOW)),
                     ("/t/a/tool", dict(high=40))]:
        tab.create(path, **kw)
        tree.create(path, **kw)
    return tab, tree


def test_batched_charges_serialize_in_order():
    tab, _ = mk_pair(cap=100)
    doms = jnp.array([tab.index["/t/a"], tab.index["/t/b"],
                      tab.index["/t/a"]], jnp.int32)
    amts = jnp.array([60, 60, 60], jnp.int32)
    st_, granted, stalled = charge_batch(tab.state, doms, amts, 0, CFG)
    # 60 + 60 > 100: first wins, second denied, third denied
    assert list(np.asarray(granted)) == [True, False, False]
    assert int(st_["usage"][0]) == 60


def test_throttle_quantization_and_gate():
    tab, _ = mk_pair()
    idx = tab.index["/t/a/tool"]
    st_, granted, _ = charge_batch(tab.state, jnp.array([idx]),
                                   jnp.array([80], jnp.int32), 0, CFG)
    assert bool(granted[0])
    until = int(st_["throttle_until"][idx])
    assert until > 0
    # expected delay: min(2000, 10*(1+10*(80-40)/40)) = 110ms -> 11 steps
    assert until == 11
    gate = slot_gate(st_, jnp.array([idx]), 5)
    assert not bool(gate[0])
    gate = slot_gate(st_, jnp.array([idx]), 11)
    assert bool(gate[0])


def test_zero_amount_respects_freeze():
    tab, _ = mk_pair()
    tab.set_frozen("/t/b", True)
    idx = tab.index["/t/b"]
    st_, granted, stalled = charge_batch(tab.state, jnp.array([idx]),
                                         jnp.array([0], jnp.int32), 0, CFG)
    assert not bool(granted[0]) and bool(stalled[0])


def test_uncharge_and_host_charge_roundtrip():
    tab, _ = mk_pair()
    idx = tab.index["/t/a"]
    tab.state = host_charge(tab.state, idx, 70)
    assert tab.usage("/t/a") == 70 and tab.usage("/") == 70
    tab.state = uncharge_batch(tab.state, jnp.array([idx]),
                               jnp.array([70], jnp.int32))
    assert tab.usage("/t/a") == 0 and tab.usage("/") == 0


def test_inactive_slot_never_granted():
    tab, _ = mk_pair()
    st_, granted, stalled = charge_batch(tab.state, jnp.array([-1]),
                                         jnp.array([5], jnp.int32), 0, CFG)
    assert not bool(granted[0]) and not bool(stalled[0])

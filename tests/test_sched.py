"""Hierarchical weighted step scheduler (core/sched.py) — scx_flatcg.

Four claims, each load-bearing for the subsystem:

  * FLATTENING — ``cpu.weight`` hierarchies flatten exactly the way
    scx_flatcg flattens them (product of normalized weights along the
    path), recomputed at lifecycle rate, identical on every backend.
  * FAIRNESS — under a step budget, grants track flattened weights via
    vruntime (pinned golden sequences), ``cpu.max`` is a hard
    per-window throttle, and the default program IS the old binary
    slot gate (weight <= 0 bypasses the budget entirely).
  * PARITY — one schedule op sequence runs bit-identically on every
    backend kind through the conformance kit, including the live
    ``cpu.weight`` write and ``sched_boost`` retune, with the host
    reference pinned to absolute goldens so kinds cannot co-drift.
  * ZERO RETRACE — a weight write or ``sched_boost`` retune is a pure
    state write: the jitted scheduling round never recompiles
    (trace counter + jit cache size), new shares on the next step.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend)
from repro.core.sched import (MAX_WEIGHT, MIN_WEIGHT, WeightedFairProgram,
                              check_weight, flat_weights_by_path)
from repro.testing.conformance import (BACKEND_KINDS, ConformanceSuite,
                                       backend_features, get_scenario,
                                       replay, standard_backend_factory)

SCHED_SCENARIOS = ("cpu_weight_fair", "cpu_max_quota", "sched_retune")

SUITE = ConformanceSuite()


def _wfair() -> WeightedFairProgram:
    return WeightedFairProgram(base_delay_ms=0.0, max_delay_ms=0.0)


def mk_cg(kind: str, cap: int = 500) -> AgentCgroup:
    cg = AgentCgroup(standard_backend_factory(kind)(cap, 16))
    cg.attach("/", _wfair())
    cg.mkdir("/a", DomainSpec(weight=300))
    cg.mkdir("/b", DomainSpec(weight=100))
    return cg


# ------------------------------------------------------------- flattening


def test_flat_weights_by_path_flatcg_product():
    f = flat_weights_by_path({"/": 100, "/a": 300, "/b": 100,
                              "/a/x": 100, "/a/y": 300})
    assert f["/"] == 1.0
    assert f["/a"] == 0.75 and f["/b"] == 0.25
    assert f["/a/x"] == pytest.approx(0.75 * 0.25)
    assert f["/a/y"] == pytest.approx(0.75 * 0.75)


def test_single_child_inherits_parent_flat_weight():
    f = flat_weights_by_path({"/": 100, "/t": 37, "/t/only": 9999})
    assert f["/t"] == 1.0 and f["/t/only"] == 1.0


def test_check_weight_bounds():
    assert check_weight(MIN_WEIGHT) == 1
    assert check_weight(MAX_WEIGHT) == 10000
    for bad in (0, -5, 10001):
        with pytest.raises(ValueError):
            check_weight(bad)


@pytest.mark.parametrize("kind", ["host", "device", "sharded"])
def test_cpu_weight_files_and_validation(kind):
    cg = mk_cg(kind)
    assert cg.read("/a", "cpu.weight") == 300
    assert cg.read("/b", "cpu.weight") == 100
    assert cg.read("/a", "cpu.max") == D.UNLIMITED
    with pytest.raises(ValueError):
        cg.write("/a", "cpu.weight", 0)
    with pytest.raises(ValueError):
        cg.write("/a", "cpu.weight", 10001)
    cg.write("/a", "cpu.weight", 10000)
    assert cg.read("/a", "cpu.weight") == 10000


# --------------------------------------------------------------- fairness


def test_weighted_fair_golden_sequence():
    """The worked two-tenant example (README): 300/100 weights under a
    1-slot budget grant exactly 3:1 — the pinned sequence."""
    cg = mk_cg("host")
    seq = [tuple(cg.schedule(["/a", "/b"], [1, 1], s, 1)) for s in range(8)]
    assert seq == [(True, False), (False, True), (True, False),
                   (True, False), (True, False), (False, True),
                   (True, False), (True, False)]
    assert sum(a for a, _ in seq) == 6 and sum(b for _, b in seq) == 2


def test_default_program_is_the_binary_slot_gate():
    """No program attached -> every slot's weight is <= 0 -> every
    runnable slot bypasses the budget: the pre-scheduler behavior."""
    cg = AgentCgroup(standard_backend_factory("host")(500, 16))
    cg.mkdir("/a")
    cg.mkdir("/b")
    for s in range(4):
        assert cg.schedule(["/a", "/b"], [1, 1], s, 0) == [True, True]
    cg.freeze("/a")
    assert cg.schedule(["/a", "/b"], [1, 1], 4, 0) == [False, True]


def test_cpu_max_window_throttle_and_rollover():
    cg = AgentCgroup(standard_backend_factory("host")(500, 16))
    cg.attach("/", _wfair())
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(cpu_max=3))
    adv = [cg.schedule(["/t/a"], [1], s, 8)[0] for s in range(6)]
    assert adv == [True, True, True, False, False, False]
    # next window (sched_window=100): quota restored
    assert cg.schedule(["/t/a"], [1], 100, 8) == [True]


def test_cpu_max_applies_to_descendants():
    """The quota is hierarchical: a child's advance charges the capped
    ancestor's window account."""
    cg = AgentCgroup(standard_backend_factory("host")(500, 16))
    cg.attach("/", _wfair())
    cg.mkdir("/t", DomainSpec(cpu_max=2))
    cg.mkdir("/t/kid")
    adv = [cg.schedule(["/t/kid"], [1], s, 8)[0] for s in range(4)]
    assert adv == [True, True, False, False]


def test_empty_slots_never_advance():
    cg = mk_cg("host")
    assert cg.schedule([], [], 0, 4) == []
    view_seq = cg.schedule(["/a"], [1], 0, 1)
    assert view_seq == [True]


# ----------------------------------------------------------------- parity


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_sched_conformance(kind):
    """The acceptance loop: the scheduler scenarios — weight writes,
    cpu.max quotas, live sched_boost retunes, freeze/thaw — replay
    bit-identically on every backend kind."""
    report = SUITE.run(standard_backend_factory(kind),
                       features=backend_features(kind),
                       scenarios=SCHED_SCENARIOS)
    assert report.ok, report.summary()


def test_sched_scenarios_absolute_goldens():
    """Pin the reference streams to absolute values so the six kinds
    cannot drift together."""
    host = standard_backend_factory("host")

    sc = get_scenario("cpu_weight_fair")
    obs = replay(AgentCgroup(host(sc.capacity, sc.n_domains)), sc)
    sched = [v for _, n, v in obs if n == "schedule"]
    assert sched[:8] == [(True, False), (False, True), (True, False),
                         (True, False), (True, False), (False, True),
                         (True, False), (True, False)]
    # after the live /b cpu.weight 100 -> 300 write: equal shares,
    # vruntime carried over (no reset on reweight)
    assert sched[8:] == [(True, False), (False, True)] * 4
    reads = [v[2] for _, n, v in obs if n == "read"]
    assert reads == [300, 100, D.UNLIMITED, 300]

    sc = get_scenario("cpu_max_quota")
    obs = replay(AgentCgroup(host(sc.capacity, sc.n_domains)), sc)
    sched = [v for _, n, v in obs if n == "schedule"]
    assert sched == [(True, True)] * 3 + [(False, True)] * 3 \
        + [(True, True)] * 2
    assert [v[2] for _, n, v in obs if n == "read"] == [3]

    sc = get_scenario("sched_retune")
    obs = replay(AgentCgroup(host(sc.capacity, sc.n_domains)), sc)
    sched = [v for _, n, v in obs if n == "schedule"]
    # equal weights alternate; sched_boost=2.0 on /a (x4) shifts to 4:1;
    # freeze removes /a from the runnable set; thaw brings it back with
    # lag-clamped vruntime (it does NOT return with unbounded credit)
    assert sched[:4] == [(True, False), (False, True)] * 2
    assert sched[4:14] == [(True, False), (False, True), (True, False),
                           (True, False), (True, False), (True, False),
                           (False, True), (True, False), (True, False),
                           (True, False)]
    assert sched[14:17] == [(False, True)] * 3
    assert sched[17:] == [(True, False)] * 3


def test_device_inkernel_schedule_matches_host():
    """The in-step entry point (DeviceView.schedule, what the engine
    jits) agrees step for step with the host facade path."""
    cg_h = mk_cg("host")
    cg_d = mk_cg("device")
    view = cg_d.device_view()
    dom = jnp.array([cg_d.handle("/a"), cg_d.handle("/b")], jnp.int32)
    cost = jnp.array([1, 1], jnp.int32)
    for s in range(12):
        want = cg_h.schedule(["/a", "/b"], [1, 1], s, 1)
        st, adv = view.schedule(view.state, dom, cost, s, 1)
        view.commit(st)
        assert [bool(x) for x in np.asarray(adv)] == want, s


# ------------------------------------------------------------ zero retrace


def test_weight_and_boost_retune_zero_retrace():
    """The adaptability pillar, scheduler edition: a live cpu.weight
    write and a sched_boost retune are param/state writes — the jitted
    scheduling round is NOT retraced, and the new shares apply from the
    very next step."""
    cg = mk_cg("device")
    view = cg.device_view()
    traces = 0

    def sched(state, dom, cost, step):
        nonlocal traces
        traces += 1
        return view.schedule(state, dom, cost, step, 1)

    jsched = jax.jit(sched)
    dom = jnp.array([cg.handle("/a"), cg.handle("/b")], jnp.int32)
    cost = jnp.array([1, 1], jnp.int32)

    def rounds(steps):
        a = b = 0
        for s in steps:
            st, adv = jsched(view.state, dom, cost, s)
            view.commit(st)
            ga, gb = np.asarray(adv)
            a, b = a + int(ga), b + int(gb)
        return a, b

    assert rounds(range(8)) == (6, 2)            # 300/100 -> 3:1

    cg.write("/a", "cpu.weight", 100)            # live reweight: 1:1
    cg.update_params("/b", sched_boost=2.0)      # live boost: /b x4
    a, b = rounds(range(8, 28))
    assert b > a and b >= 15                     # ~4:1 the other way
    assert traces == 1                           # never retraced
    assert jsched._cache_size() == 1


# ----------------------------------------------------------------- engine


def test_engine_sched_slots_weighted_completion_order():
    """Engine-level acceptance: with ``sched_slots`` set and a 4:1
    cpu.weight split, the heavy tenant's identical workload finishes
    first; both still complete (no starvation — vruntime fairness)."""
    from repro.configs import get_config, reduced
    from repro.models import model as M
    from repro.models.schema import init_params
    from repro.perf import DEFAULT_PERF, replace as perf_replace
    from repro.serving.engine import Engine, EngineConfig
    from repro.serving.session import Phase, Session, SState

    cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                              dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    eng = Engine(cfg, params, perf=perf_replace(DEFAULT_PERF, scan_chunk=32),
                 ecfg=EngineConfig(max_slots=2, s_max=128, pool_pages=64,
                                   page_tokens=16, mode="inkernel",
                                   use_freeze=False, sched_slots=1), seed=0)
    eng.attach_program(_wfair())

    def sess(sid, tenant):
        return Session(sid=sid, tenant=tenant, priority=D.NORMAL,
                       prompt=list(range(2, 10)),
                       phases=[Phase(6, 8, "test"), Phase(6, 0)])

    eng.submit(sess("hi", "ta"))
    eng.submit(sess("lo", "tb"))
    eng.cg.write("/ta", "cpu.weight", 400)
    eng.cg.write("/tb", "cpu.weight", 100)
    eng.run(400)
    hi, lo = eng.sessions["hi"], eng.sessions["lo"]
    assert hi.state is SState.DONE and lo.state is SState.DONE
    assert hi.t_done < lo.t_done
    assert hi.stall_steps < lo.stall_steps


# --------------------------------------------- 8-fake-device subprocess

_SCHED_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core.cgroup import AgentCgroup, DomainSpec
from repro.core.sched import WeightedFairProgram
from repro.testing.conformance import (ConformanceSuite, backend_features,
                                       standard_backend_factory)

assert len(jax.devices()) == 8

# 1) the scheduler scenarios on a real 8-shard mesh — /a and /b land on
# DIFFERENT shards, so the flattened weights and the global vruntime
# ranking must come out identical to the single-tree host reference
suite = ConformanceSuite()
for kind in ("sharded", "async-sharded"):
    report = suite.run(standard_backend_factory(kind),
                       features=backend_features(kind),
                       scenarios=("cpu_weight_fair", "cpu_max_quota",
                                  "sched_retune"))
    assert report.ok, report.summary()

# 2) cross-shard fairness: 8 tenants on 8 shards, weights 100..800,
# shares under a 1-slot budget track the weights (heaviest >= lightest)
cg = AgentCgroup(standard_backend_factory("sharded")(800, 16))
assert cg.backend.n_shards == 8
cg.attach("/", WeightedFairProgram(base_delay_ms=0.0, max_delay_ms=0.0))
paths = []
for t in range(8):
    cg.mkdir(f"/t{t}", DomainSpec(weight=100 * (t + 1)))
    paths.append(f"/t{t}")
grants = [0] * 8
for s in range(72):
    adv = cg.schedule(paths, [1] * 8, s, 1)
    for i, a in enumerate(adv):
        grants[i] += int(a)
assert sum(grants) == 72
assert grants == sorted(grants), grants          # monotone in weight
assert grants[-1] >= 3 * grants[0], grants       # 800 vs 100
print("SCHED8 OK")
"""


def test_sched_parity_on_8_fake_devices():
    env = dict(os.environ)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root])
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", _SCHED_8DEV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "SCHED8 OK" in out.stdout, \
        out.stderr[-3000:]

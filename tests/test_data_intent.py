"""Data pipeline determinism/learnability + intent protocol units +
sampling + schema/PSI utilities."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, reduced
from repro.core.accounting import LatencyStats, PSITracker
from repro.core.intent import (AdaptiveAgentModel, Hint, CATEGORY_HINT,
                               hint_to_high, make_feedback, parse_hint)
from repro.data.pipeline import make_batch
from repro.serving.sampling import sample


def _cfg(arch="llama3.2-3b"):
    return dataclasses.replace(reduced(get_config(arch)), dtype="float32")


def test_batch_determinism():
    cfg = _cfg()
    shape = SHAPES["train_4k"]
    b1 = make_batch(cfg, shape, seed=1, step=5, batch=4, seq=32)
    b2 = make_batch(cfg, shape, seed=1, step=5, batch=4, seq=32)
    b3 = make_batch(cfg, shape, seed=1, step=6, batch=4, seq=32)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_batch_learnable_structure():
    """labels follow the seed-fixed bigram permutation ~90% of the time."""
    cfg = _cfg()
    b = make_batch(cfg, SHAPES["train_4k"], seed=2, step=0, batch=8, seq=256)
    V = cfg.vocab - 2
    perm = np.random.default_rng(2 ^ 0x5EED).permutation(V)
    t = b["tokens"] - 2
    nxt = b["labels"] - 2
    valid = (b["weights"] > 0) & (nxt >= 0) & (t >= 0)
    match = (perm[np.clip(t, 0, V - 1)] == nxt) & valid
    assert match.sum() / max(valid.sum(), 1) > 0.8


def test_vlm_and_audio_batches():
    vcfg = _cfg("pixtral-12b")
    b = make_batch(vcfg, SHAPES["train_4k"], seed=0, step=0, batch=2, seq=32)
    assert b["patches"].shape == (2, vcfg.n_frontend_tokens, vcfg.d_model)
    assert (b["weights"][:, :16] == 0).all()    # no LM loss on patches
    acfg = _cfg("hubert-xlarge")
    b = make_batch(acfg, SHAPES["train_4k"], seed=0, step=0, batch=2, seq=32)
    assert b["frames"].shape == (2, 32, acfg.d_model)
    assert (b["weights"] == b["mask"].astype(np.float32)).all()


def test_intent_protocol():
    assert parse_hint("memory:high") is Hint.HIGH
    assert parse_hint("bogus") is None
    assert hint_to_high(Hint.LOW) < hint_to_high(Hint.HIGH)
    assert CATEGORY_HINT["test"] is Hint.HIGH
    fb = make_feedback("/t/s/tool_1", "oom", 700, 512)
    assert "700" in fb.render() and "reduce" in fb.suggestion.lower()


def test_adaptive_agent_learns_hints():
    agent = AdaptiveAgentModel()
    fb = make_feedback("x", "oom", 700, 512)
    adj = agent.on_feedback("python", fb)
    assert adj["scale"] == 0.5
    assert agent.hint_for("python", Hint.MEDIUM) is Hint.HIGH


def test_sampling():
    logits = jnp.array([[0.0, 5.0, 1.0], [3.0, 0.0, 0.0]], jnp.float32)
    greedy = sample(logits, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(greedy), [1, 0])
    k = jax.random.PRNGKey(1)
    topk = sample(jnp.tile(logits, (50, 1)), k, temperature=1.0, top_k=1)
    assert set(np.asarray(topk[::2])) == {1}


def test_psi_window():
    psi = PSITracker(window_ms=100.0)
    psi.record_stall(950.0, 50.0)
    assert abs(psi.pressure(1000.0) - 0.5) < 1e-6
    assert psi.pressure(1200.0) == 0.0


def test_latency_percentiles():
    ls = LatencyStats()
    for v in range(1, 101):
        ls.add(float(v))
    assert abs(ls.p50 - 50.5) < 1.0
    assert abs(ls.p95 - 95.05) < 1.0

"""Per-tenant concurrent policy programs + the fused enforcement kernel.

Four claims from the registry/fusion PR, each with its own failure
mode the older single-program control plane could not express:

  * MIXED PARITY — two tenants running *different* programs
    (graduated throttle vs token bucket) in one hierarchy replay
    bit-identically on every backend kind, including the real 8-shard
    mesh (subprocess, like the sharded parity test in test_cgroup).
  * SLOT RETUNE — ``update_params`` on a mixed registry resolves each
    path through its own program's parameter columns and stays a pure
    state write: zero retraces across retunes of *both* slots.
  * FUSED PATH — the Pallas kernel (``kernels/enforcement.py``) is
    certified against the lax reference through the conformance kit
    under ``REPRO_FORCE_PALLAS_INTERPRET=1`` (subprocess: the knob must
    be set before jax configures itself), on every backend kind.
  * SATURATION — the PSI stall accumulators saturate at INT32_MAX
    instead of wrapping negative, on the device path, the gathered
    scheduler path, and the host tree (the satellite bugfix).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend)
from repro.core.pressure import INT32_MAX, saturating_count
from repro.core.progs import GraduatedThrottleProgram, TokenBucketProgram
from repro.core.sched import schedule_decision
from repro.testing.conformance import (BACKEND_KINDS, get_scenario, replay,
                                       standard_backend_factory)

# ------------------------------------------------------------ mixed parity

# reference observations for the mixed-program golden, computed once
_REF = {}


def _mixed_obs(kind: str) -> list:
    sc = get_scenario("multi_program")
    cg = AgentCgroup(standard_backend_factory(kind)(sc.capacity,
                                                    sc.n_domains))
    return [o for o in replay(cg, sc) if o[1] != "events_all"]


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_mixed_programs_bit_parity_all_kinds(kind):
    """Two tenants on different programs (graduated vs token bucket),
    attach composed at runtime, children inheriting the parent's
    registry slot: bit-identical on every backend kind."""
    if "ref" not in _REF:
        _REF["ref"] = _mixed_obs("host")
    assert _mixed_obs(kind) == _REF["ref"]


def test_mixed_programs_absolute_goldens():
    """Pin the mixed-program scenario to absolute values (kit runs are
    relative to the reference; this guards against co-drift): the
    bucket tenant rate-limits, the graduated tenant throttles, and
    each per-slot retune lands only on its own tenant."""
    obs = _REF.get("ref") or _mixed_obs("host")
    charges = [v for _, n, v in obs if n == "charge"]
    assert charges == [
        (False, True, 0.0),      # /bkt/s 6@0: bucket holds only 4
        (True, False, 0.0),      # /bkt/s 3@0: within the bucket
        (True, False, 110.0),    # /grad/s 20@0: over 1.0 -> 10*(1+10)
        (False, True, 100.0),    # /grad/s 1@1: inside the window
        (True, False, 0.0),      # /bkt/s 30@5: retuned bucket holds 50
        (True, False, 0.0),      # /grad/s 1@200: delays retuned off
    ]
    usage = {p: u for _, n, (p, u) in
             ((i, n, v) for i, n, v in obs if n == "usage")}
    assert usage == {"/": 54, "/grad": 21, "/bkt": 33}


_MIXED_8DEV = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
assert len(jax.devices()) == 8
from repro.core.cgroup import AgentCgroup
from repro.testing.conformance import (get_scenario, replay,
                                       standard_backend_factory)

# the mixed-program golden on a real 8-shard mesh, vs the host reference
sc = get_scenario("multi_program")
ref = replay(AgentCgroup(standard_backend_factory("host")(
    sc.capacity, sc.n_domains)), sc)
got = replay(AgentCgroup(standard_backend_factory("sharded")(
    sc.capacity, sc.n_domains)), sc)
drop = lambda obs: [o for o in obs if o[1] != "events_all"]
assert drop(got) == drop(ref)

# the two tenants really live on different shards (round-robin), so the
# registry dispatch crosses shard boundaries, not just table rows
cg = AgentCgroup(standard_backend_factory("sharded")(
    sc.capacity, sc.n_domains))
cg.attach("/", __import__("repro.core.progs", fromlist=["x"])
          .GraduatedThrottleProgram())
cg.mkdir("/grad"); cg.mkdir("/bkt")
place = cg.backend.placement()
assert place["/grad"] != place["/bkt"], place
print("MIXED8 OK")
"""


def test_mixed_programs_on_8_fake_devices():
    env = dict(os.environ)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(root, "src"), root])
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    out = subprocess.run([sys.executable, "-c", _MIXED_8DEV], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "MIXED8 OK" in out.stdout, \
        out.stderr[-3000:]


# ------------------------------------------------------- per-slot retune


def test_update_params_zero_retrace_per_program_slot():
    """Retuning either slot of a mixed registry is a pure param-table
    write: the jitted charge function compiles once (lax.switch over
    both programs) and is never retraced."""
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.attach("/", GraduatedThrottleProgram())
    cg.mkdir("/grad", DomainSpec(high=10))
    cg.mkdir("/bkt")
    cg.attach("/bkt", TokenBucketProgram(bucket_capacity=4,
                                         refill=(1.0, 1.0, 1.0)))
    assert len(cg.programs) == 2
    view = cg.device_view()
    traces = 0

    def charge(state, dom, amt, step):
        nonlocal traces
        traces += 1
        return view.charge(state, dom, amt, step)

    jcharge = jax.jit(charge)
    dom = jnp.array([cg.handle("/grad"), cg.handle("/bkt")], jnp.int32)
    st, g, _ = jcharge(view.state, dom, jnp.array([20, 6], jnp.int32), 0)
    view.commit(st)
    assert bool(g[0]) and not bool(g[1])       # bucket holds only 4

    # slot 1 retune: only the bucket tenant sees the new capacity
    cg.update_params("/bkt", bucket_capacity=50.0, bucket_level=50.0)
    st, g, _ = jcharge(view.state, dom, jnp.array([0, 30], jnp.int32), 50)
    view.commit(st)
    assert bool(g[1])

    # slot 0 retune: only the graduated tenant sees the flat curve
    cg.update_params("/grad", base_delay_ms=0.0, max_delay_ms=0.0)
    st, g, _ = jcharge(view.state, dom, jnp.array([1, 0], jnp.int32), 200)
    view.commit(st)
    assert bool(g[0])

    assert traces == 1                         # never retraced
    assert jcharge._cache_size() == 1


# ---------------------------------------------------------- fused kernel

# charge-heavy scenario subset: the fused kernel serves charge + gate
# (scheduling rounds stay on the lax scheduler), so certify the kinds
# on the scenarios that exercise the fused path
_FUSED_SCENARIOS = ("lifecycle", "token_bucket", "attach_scope",
                    "multi_program", "control_files")

_FUSED_INTERP = r"""
import os
os.environ["REPRO_FORCE_PALLAS_INTERPRET"] = "1"
from repro import compat
assert compat.force_interpret()
from repro.core.controller import _fused_charge_or_none, _fused_gate_or_none
assert _fused_charge_or_none() is not None    # the dispatch seam is live
assert _fused_gate_or_none() is not None
from repro.testing.conformance import (BACKEND_KINDS, ConformanceSuite,
                                       backend_features,
                                       standard_backend_factory)

suite = ConformanceSuite()
for kind in BACKEND_KINDS:
    report = suite.run(standard_backend_factory(kind),
                       features=backend_features(kind),
                       scenarios=%r)
    assert report.ok, report.summary()
    print("FUSED", kind, "OK")
print("FUSED-INTERP OK")
""" % (_FUSED_SCENARIOS,)


def test_fused_kernel_conformance_under_forced_interpret():
    """Certify the Pallas enforcement kernel against the lax/host
    reference on every backend kind.  ``REPRO_FORCE_PALLAS_INTERPRET``
    must be set before jax is imported, hence the subprocess."""
    env = dict(os.environ)
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(root, "src"), root])
    env["REPRO_FORCE_PALLAS_INTERPRET"] = "1"
    out = subprocess.run([sys.executable, "-c", _FUSED_INTERP], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0 and "FUSED-INTERP OK" in out.stdout, \
        out.stderr[-3000:]


# ------------------------------------------------------------- saturation


def test_saturating_count_boundary():
    """The traced helper itself: at the boundary the counter pins to
    INT32_MAX instead of wrapping negative (i32 overflow is UB-shaped
    on device: silent wrap)."""
    c = saturating_count(jnp.int32(INT32_MAX - 1), jnp.int32(1))
    assert int(c) == INT32_MAX
    c = saturating_count(c, jnp.int32(1))
    assert int(c) == INT32_MAX
    c = saturating_count(jnp.int32(INT32_MAX), jnp.int32(INT32_MAX))
    assert int(c) == INT32_MAX
    assert int(saturating_count(jnp.int32(5), jnp.int32(0))) == 5


def test_mem_stall_saturates_on_device_path():
    """Regression for the wrap bug: a domain one event below INT32_MAX
    takes two more denials and stays pinned (the unpatched accumulator
    went negative on the second)."""
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.mkdir("/s", DomainSpec(max=10))
    view = cg.device_view()
    idx = cg.handle("/s")
    st = dict(view.state)
    st["mem_stall"] = st["mem_stall"].at[idx].set(INT32_MAX - 1)
    dom = jnp.array([idx], jnp.int32)
    for step in (0, 1):
        st, g, stalled = view.charge(st, dom,
                                     jnp.array([100], jnp.int32), step)
        assert not bool(g[0]) and bool(stalled[0])
        assert int(st["mem_stall"][idx]) == INT32_MAX


def test_cpu_stall_saturates_with_gathered_slots():
    """The scheduler gathers per-round increments before saturating:
    two frozen slots on ONE domain in one round is +2 on that row —
    exactly the case a per-slot clamp would still wrap."""
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8))
    cg.mkdir("/s")
    cg.freeze("/s")
    view = cg.device_view()
    idx = cg.handle("/s")
    st = dict(view.state)
    st["cpu_stall"] = st["cpu_stall"].at[idx].set(INT32_MAX - 1)
    dom = jnp.array([idx, idx], jnp.int32)
    new, adv = schedule_decision(cg.programs, st, dom,
                                 jnp.array([1, 1], jnp.int32), 0, 8)
    assert not bool(np.asarray(adv).any())     # frozen: nobody advances
    assert int(new["cpu_stall"][idx]) == INT32_MAX


def test_mem_stall_saturates_on_host_tree():
    """The host reference applies the same clamp (one decision path,
    three substrates — the clamped counter must not diverge)."""
    cg = AgentCgroup(HostTreeBackend(10_000))
    cg.mkdir("/s", DomainSpec(max=10))
    cg.backend.tree.get("/s").mem_stall = INT32_MAX - 1
    for step in (0, 1):
        t = cg.try_charge("/s", 100, step=step)
        assert not t.granted
        assert cg.read("/s", "memory.stall") == INT32_MAX

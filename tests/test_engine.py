"""Serving-engine integration: the three controller modes on a real
(reduced) model — survival, in-step hard guarantee, freeze context
preservation, feedback adaptation, intent hints."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import domains as D
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import Phase, Session, SState

PERF = perf_replace(DEFAULT_PERF, scan_chunk=32)


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                              dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    return cfg, params


def sessions():
    hi = Session(sid="hi", tenant="t", priority=D.HIGH,
                 prompt=list(range(2, 34)),
                 phases=[Phase(8, 96, "test"), Phase(8, 64, "git"),
                         Phase(12, 0)])
    lo1 = Session(sid="lo1", tenant="t", priority=D.LOW,
                  prompt=list(range(2, 26)),
                  phases=[Phase(8, 160, "test"), Phase(8, 96, "test"),
                          Phase(8, 0)])
    lo2 = Session(sid="lo2", tenant="t", priority=D.LOW,
                  prompt=list(range(2, 26)),
                  phases=[Phase(8, 160, "test"), Phase(8, 96, "test"),
                          Phase(8, 0)])
    return [hi, lo1, lo2]


COMMON = dict(max_slots=4, s_max=384, pool_pages=40, page_tokens=16)


def run_mode(model, mode, **kw):
    cfg, params = model
    ecfg = EngineConfig(**COMMON, mode=mode, **kw)
    eng = Engine(cfg, params, perf=PERF, ecfg=ecfg, seed=0)
    for s in sessions():
        eng.submit(s)
    eng.run(6000)
    return eng


def test_inkernel_full_survival_and_hard_guarantee(model):
    eng = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    r = eng.report()
    assert r["survival"] == 1.0
    assert r["overshoot_pages"] == 0          # in-step charge cannot overshoot
    assert r["throttle_triggers"] > 0


def test_userspace_lags(model):
    base = run_mode(model, "userspace", use_freeze=False,
                    use_tool_domains=False, use_intent=False,
                    session_high={"lo1": 12, "lo2": 12})
    ink = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    # the stale-gate path throttles strictly later/less than in-step
    assert base.report()["throttle_triggers"] < ink.report()["throttle_triggers"]


def test_nolimit_overshoots_pool(model):
    eng = run_mode(model, "nolimit", use_freeze=False,
                   use_tool_domains=False, use_intent=False)
    assert eng.report()["overshoot_pages"] > 0


def test_freeze_preserves_context(model):
    eng = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    frozen = [s for s in eng.sessions.values() if s.n_freezes > 0]
    assert eng.metrics.n_freezes >= 1 and frozen
    for s in frozen:                          # full context length reached
        assert s.state is SState.DONE
        want = len(s.prompt) + sum(p.gen_tokens + p.append_tokens
                                   for p in s.phases)
        assert s.length == want


def test_session_completion_lengths(model):
    eng = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    for s in eng.sessions.values():
        want = len(s.prompt) + sum(p.gen_tokens + p.append_tokens
                                   for p in s.phases)
        assert s.length == want, (s.sid, s.length, want)


def test_freezer_records_deterministic(model, monkeypatch):
    """Two identical runs produce identical freezer records — the
    TL003 regression: offload records carry the step clock, never wall
    time, so freeze/thaw state is replay-deterministic."""
    from repro.core.freezer import FrozenStore

    def capture(records):
        orig = FrozenStore.freeze

        def freeze(self, sid, tree, *, pages, meta=None, now=0.0):
            records.append((sid, pages, dict(meta or {}), float(now)))
            return orig(self, sid, tree, pages=pages, meta=meta, now=now)

        return freeze

    runs = []
    for _ in range(2):
        records = []
        monkeypatch.setattr(FrozenStore, "freeze", capture(records))
        run_mode(model, "inkernel", use_freeze=True,
                 session_high={"lo1": 12, "lo2": 12})
        monkeypatch.undo()
        runs.append(records)
    assert runs[0], "scenario no longer freezes anything"
    assert runs[0] == runs[1]
    for _sid, _pages, _meta, now in runs[0]:
        assert now == int(now) >= 0      # a step number, not an epoch time


def test_feedback_shrinks_append(model):
    """Against a tiny pool, sessions reconstruct strategy (shorter tool
    results) after feedback instead of being evicted."""
    cfg, params = model
    # pool of 20 pages = 320 tokens: the full workload (424 tokens) does
    # NOT fit, but a feedback-shrunk one does — eviction would be a bug
    ecfg = EngineConfig(max_slots=2, s_max=384, pool_pages=20,
                        page_tokens=16, mode="inkernel", use_freeze=False,
                        feedback_patience_steps=20,
                        evict_patience_steps=2000)
    eng = Engine(cfg, params, perf=PERF, ecfg=ecfg, seed=0)
    big = Session(sid="big", tenant="t", priority=D.NORMAL,
                  prompt=list(range(2, 18)),
                  phases=[Phase(4, 400, "test"), Phase(4, 0)])
    eng.submit(big)
    eng.run(6000)
    assert big.state is SState.DONE
    assert len(big.feedbacks) >= 1
    want_full = 16 + 4 + 400 + 4
    assert big.length < want_full             # scope was reduced


def test_domain_accounting_clean_at_end(model):
    eng = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    assert eng.cg.usage("/") == 0


def test_async_backend_bitexact_with_device(model):
    """The async lifecycle daemon's acceptance claim: wrapping the
    device backend and deferring all lifecycle ops to step-boundary
    epochs reproduces the synchronous run bit-exactly — every metric in
    the report, same seed, same workload — while the jitted enforcement
    path never blocks on lifecycle work."""
    dev = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    asy = run_mode(model, "inkernel", backend="async", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    assert asy.report() == dev.report()
    assert asy.report()["survival"] == 1.0
    assert asy.cg.usage("/") == 0
    from repro.core.daemon import AsyncDaemonBackend
    assert isinstance(asy.cg.backend, AsyncDaemonBackend)
    assert asy.cg.backend.epoch > 0       # lifecycle really ran in epochs
    asy.close()
    assert not asy.cg.backend._thread.is_alive()


def test_engine_survives_poisoned_daemon(model):
    """Robustness: when the async lifecycle daemon is poisoned mid-run
    (wedge/timeout), the next step rebuilds the backend from the last
    step-boundary snapshot and the run completes — same workload, full
    survival, clean accounting."""
    cfg, params = model
    ecfg = EngineConfig(**COMMON, mode="inkernel", backend="async",
                        use_freeze=True,
                        session_high={"lo1": 12, "lo2": 12})
    eng = Engine(cfg, params, perf=PERF, ecfg=ecfg, seed=0)
    for s in sessions():
        eng.submit(s)
    for _ in range(40):
        eng.step()
    eng.cg.backend._wedged = True            # poison between steps
    eng.run(6000)
    r = eng.report()
    assert eng.metrics.n_rebuilds == 1
    assert r["survival"] == 1.0
    assert r["overshoot_pages"] == 0
    assert eng.cg.usage("/") == 0
    for s in eng.sessions.values():
        want = len(s.prompt) + sum(p.gen_tokens + p.append_tokens
                                   for p in s.phases)
        assert s.length == want, (s.sid, s.length, want)
    eng.close()


def test_sharded_backend_serves_multitenant(model):
    """Same workload on the ShardedTableBackend: in-step enforcement now
    runs per device group under shard_map, but the guarantees (survival,
    zero pool overshoot, clean accounting) are backend-invariant."""
    eng = run_mode(model, "inkernel", backend="sharded", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12})
    r = eng.report()
    assert r["survival"] == 1.0
    assert r["overshoot_pages"] == 0
    assert r["throttle_triggers"] > 0
    assert eng.cg.usage("/") == 0
    # every tenant subtree was placed on a device group
    assert "/t" in eng.cg.backend.placement()


def test_adaptive_observation_is_non_perturbing(model):
    """``EngineConfig(adaptive=...)`` with thresholds the run can never
    cross (avg10 <= 1.0 < high_frac) polls pressure every step but takes
    no action — and reading pressure must not perturb a single decision:
    the report is bit-identical to the ``adaptive=None`` run."""
    from repro.core.adaptive import AdaptiveConfig
    base = run_mode(model, "inkernel", use_freeze=True,
                    session_high={"lo1": 12, "lo2": 12})
    watched = run_mode(model, "inkernel", use_freeze=True,
                       session_high={"lo1": 12, "lo2": 12},
                       adaptive=AdaptiveConfig(high_frac=2.0))
    assert base._adaptive is None and watched._adaptive is not None
    assert watched._adaptive.events == []
    assert watched.report() == base.report()


def test_adaptive_retuner_relieves_live_engine(model):
    """The closed loop on the live engine: watching the throttled LOW
    session domains with a hair-trigger threshold must produce bump
    events on the engine's step clock, and the run still completes with
    clean accounting."""
    from repro.core.adaptive import AdaptiveConfig
    eng = run_mode(model, "inkernel", use_freeze=True,
                   session_high={"lo1": 12, "lo2": 12},
                   adaptive=AdaptiveConfig(high_frac=0.01, low_frac=0.0,
                                           cooldown_ms=50.0,
                                           watch=("/t/lo1", "/t/lo2")))
    r = eng.report()
    assert r["survival"] == 1.0
    assert eng.cg.usage("/") == 0
    bumps = [e for e in eng._adaptive.events if e.action == "bump_high"]
    assert bumps, "pressure never produced a bump on the live engine"
    for e in bumps:
        assert e.new > e.old
        assert e.t_ms == int(e.t_ms)          # engine step clock, not ms

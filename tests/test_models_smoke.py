"""Per-arch smoke tests: reduced config, one forward/train step + a few
decode steps on CPU; asserts shapes and finiteness (assignment req)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace

PERF = perf_replace(DEFAULT_PERF, scan_chunk=32, remat="none",
                    block_q=64, block_k=64)
B, S = 2, 64


def _build(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0), cfg.dtype)
    return cfg, params


def _batch(cfg, key):
    ks = jax.random.split(key, 5)
    batch = {}
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32)
        batch["mask"] = jax.random.bernoulli(ks[1], 0.3, (B, S))
        batch["weights"] = batch["mask"].astype(jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
        batch["weights"] = jnp.ones((B, S), jnp.float32)
        if cfg.frontend == "vision":
            batch["patches"] = jax.random.normal(
                ks[2], (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    batch["labels"] = jax.random.randint(ks[3], (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_grad(arch):
    cfg, params = _build(arch)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, aux = jax.jit(
        lambda p, b: M.forward(cfg, p, b, perf=PERF))(params, batch)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, metrics = M.loss_fn(cfg, params, batch, perf=PERF)
    assert np.isfinite(float(loss))
    g = jax.jit(jax.grad(
        lambda p: M.loss_fn(cfg, p, batch, perf=PERF)[0]))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_config(a).encoder_only])
def test_decode_steps(arch):
    cfg, params = _build(arch)
    s_max = 32
    state = init_params(M.decode_state_schema(cfg, B, s_max),
                        jax.random.PRNGKey(2), cfg.dtype)
    step = jax.jit(lambda p, s, t, l: M.serve_step(cfg, p, s, t, l,
                                                   perf=PERF))
    tok = jnp.array([3, 5], jnp.int32)
    lengths = jnp.zeros((B,), jnp.int32)
    for i in range(4):
        tok, state = step(params, state, tok, lengths + i)
        assert tok.shape == (B,)
        assert bool(jnp.isfinite(tok.astype(jnp.float32)).all())
        assert int(tok.max()) < cfg.padded_vocab


def test_encoder_only_has_no_decode():
    cfg, params = _build("hubert-xlarge")
    with pytest.raises(ValueError):
        M.decode_step(cfg, params, None, jnp.zeros(2, jnp.int32),
                      jnp.zeros(2, jnp.int32))


def test_decode_matches_forward_prefix():
    """Greedy decode over a fixed prompt must match teacher-forced
    forward logits argmax at each position (cache correctness)."""
    cfg, params = _build("llama3.2-3b")
    prompt = jnp.array([[5, 7, 11, 13, 17, 19, 23, 29]], jnp.int32)
    logits, _ = M.forward(cfg, params, {"tokens": prompt}, perf=PERF)
    want = jnp.argmax(logits[0], -1)
    state = init_params(M.decode_state_schema(cfg, 1, 16),
                        jax.random.PRNGKey(0), cfg.dtype)
    got = []
    for i in range(prompt.shape[1]):
        lg, state = M.decode_step(cfg, params, state, prompt[:, i],
                                  jnp.array([i], jnp.int32), perf=PERF)
        got.append(int(jnp.argmax(lg[0])))
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))

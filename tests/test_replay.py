"""Trace-replay reproduction of the paper's §6 evaluation + the §4
mismatch behaviours of the baseline policies."""
import numpy as np
import pytest

from repro.core import domains as D
from repro.core.events import Ev
from repro.core.policy import (AgentCgroupPolicy, NoIsolationPolicy,
                               PredictiveP95Policy, ReactivePSIPolicy,
                               StaticLimitPolicy)
from repro.traces.generator import generate_task, named_trace
from repro.traces.replay import ReplayConfig, replay


@pytest.fixture(scope="module")
def fig8_traces():
    hi = named_trace("dask/dask#11628", seed=1)
    lo1 = named_trace("sigmavirus24/github3.py#673", seed=2)
    lo2 = named_trace("sigmavirus24/github3.py#673", seed=3)
    return [hi, lo1, lo2], [D.HIGH, D.LOW, D.LOW]


LOWHIGH = {"sigmavirus24/github3.py#673": 400}


def test_named_trace_peaks(fig8_traces):
    traces, _ = fig8_traces
    assert abs(traces[0].peak_mb - 421) < 2
    assert abs(traces[1].peak_mb - 406) < 2


def test_fig8a_tight_memory_survival(fig8_traces):
    """1100 MB pool vs ~1233 MB demand: baseline OOM-kills (66%);
    AgentCgroup completes everything (100%)."""
    traces, prios = fig8_traces
    cfg = ReplayConfig(capacity_mb=1100)
    base = replay(traces, prios, NoIsolationPolicy(), cfg)
    agent = replay(traces, prios, AgentCgroupPolicy(session_high=LOWHIGH),
                   cfg)
    assert base.survival < 1.0
    assert base.log.count(Ev.OOM_KILL) >= 1
    assert agent.survival == 1.0
    assert agent.throttle_count > 0


def test_fig8b_high_priority_latency(fig8_traces):
    """Moderate memory: AgentCgroup reduces HIGH-priority P95 allocation
    latency (paper: -29%) with P50 basically unchanged."""
    traces, prios = fig8_traces
    cfg = ReplayConfig(capacity_mb=1300)
    base = replay(traces, prios, NoIsolationPolicy(), cfg)
    agent = replay(traces, prios, AgentCgroupPolicy(session_high=LOWHIGH),
                   cfg)
    b, a = base.latency_of(D.HIGH), agent.latency_of(D.HIGH)
    assert a.p95 < b.p95 * 0.9            # meaningful P95 reduction
    assert abs(a.p50 - b.p50) < 1.0       # P50 untouched
    assert base.survival == agent.survival == 1.0


def test_static_limit_granularity_mismatch(fig8_traces):
    """memory.max at the average kills bursty tasks; at the peak it
    wastes most of the reservation (paper §4.1)."""
    traces, prios = fig8_traces
    avg = int(np.mean([t.avg_mb for t in traces]))
    cfg = ReplayConfig(capacity_mb=5000)
    killed = replay(traces, prios, StaticLimitPolicy(limit_mb=avg), cfg)
    assert killed.survival < 1.0          # burst hits the average-sized max
    peak_pol = StaticLimitPolicy(limit_mb=int(max(t.peak_mb for t in traces))
                                 + 10)
    ok = replay(traces, prios, peak_pol, cfg)
    assert ok.survival == 1.0
    # waste: peak-sized reservations admit few concurrent tasks
    assert peak_pol.max_concurrency(1100, 0) <= 2


def test_reactive_psi_reacts_too_late(fig8_traces):
    """oomd-style daemon: kills arrive only after pressure is sustained,
    and something dies (kill-as-fallback; paper §4.2/§4.3)."""
    traces, prios = fig8_traces
    cfg = ReplayConfig(capacity_mb=1100)
    r = replay(traces, prios,
               ReactivePSIPolicy(poll_ms=100.0, react_ms=40.0,
                                 pressure_threshold=0.3), cfg)
    assert r.survival < 1.0 or r.log.count(Ev.OOM_KILL) > 0


def test_predictive_p95_defeated_by_nondeterminism():
    """Autopilot-style limits from history mis-size under 1.8x-20x
    run-to-run variance (paper §4.3)."""
    # history from different seeds of the same tasks (non-determinism)
    hist = {}
    traces = []
    for i, scale in enumerate([0.4, 0.5, 0.6]):
        runs = [generate_task(f"task{i}", "glm", seed=s, scale=scale)
                for s in range(3)]
        hist[f"task{i}"] = [r.peak_mb for r in runs]
        # the replayed run is a NEW seed whose peak may exceed history
        traces.append(generate_task(f"task{i}", "glm", seed=99 + i,
                                    scale=scale * 2.5))
    cfg = ReplayConfig(capacity_mb=8000)
    r = replay(traces, [D.NORMAL] * 3,
               PredictiveP95Policy(hist, safety=1.1), cfg)
    assert r.survival < 1.0               # at least one run outgrew its P95


def test_feedback_strategy_reconstruction():
    """Under a hard wall, the agent shrinks its burst scope after
    feedback instead of dying (intent downward channel)."""
    tr = generate_task("burst", "glm", seed=5, scale=2.0)
    cfg = ReplayConfig(capacity_mb=int(tr.peak_mb * 0.7))
    pol = AgentCgroupPolicy(hard_patience_ms=50.0)
    r = replay([tr], [D.NORMAL], pol, cfg)
    assert r.tasks != {} and list(r.tasks.values())[0].completed
    assert r.log.count(Ev.FEEDBACK) > 0


def test_freeze_preserves_completion():
    tr1 = named_trace("dask/dask#11628", seed=10)
    tr2 = named_trace("sigmavirus24/github3.py#673", seed=11)
    cfg = ReplayConfig(capacity_mb=int(tr1.peak_mb + tr2.peak_mb * 0.6))
    r = replay([tr1, tr2], [D.HIGH, D.LOW], AgentCgroupPolicy(), cfg)
    assert r.survival == 1.0

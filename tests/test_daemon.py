"""The async lifecycle daemon backend (core/daemon.py).

Conformance (bit-exactness with the inner backend) is certified in
``tests/test_cgroup.py`` through the kit; this module covers the
daemon-specific semantics: FIFO epochs and deferred batching, work
running on the daemon thread (never the caller's), snapshot epoch
tags, deferred-error surfacing at flush, eager mode, fail-fast on a
wedged/dead daemon, and the residual-transfer-exactly-once regression
for lifecycle ops racing queued charges — on all four backend kinds.
"""
import threading
import time

import pytest

from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend)
from repro.core.daemon import AsyncDaemonBackend, DaemonError
from repro.testing.conformance import BACKEND_KINDS, standard_backend_factory


class SpyInner:
    """Transparent wrapper recording (method, thread-id) per applied op,
    with optional per-method gates that block until released."""

    def __init__(self, inner):
        self._inner = inner
        self.calls = []
        self.gates: dict[str, threading.Event] = {}

    def __getattr__(self, name):
        attr = getattr(self._inner, name)
        if not callable(attr) or name.startswith("_"):
            return attr

        def wrapper(*a, **k):
            gate = self.gates.get(name)
            if gate is not None:
                assert gate.wait(timeout=30.0), f"gate for {name} never set"
            self.calls.append((name, threading.get_ident()))
            return attr(*a, **k)

        return wrapper

    def applied(self, name):
        return [c for c in self.calls if c[0] == name]


def mk_async(eager=False, **kw):
    spy = SpyInner(HostTreeBackend(500))
    be = AsyncDaemonBackend(spy, eager=eager, **kw)
    return AgentCgroup(be), be, spy


# ----------------------------------------------------------- epochs / FIFO


def test_deferred_ops_batch_into_one_epoch_in_order():
    cg, be, spy = mk_async()
    cg.mkdir("/s")                        # result op: applies immediately
    e0 = be.flush()
    cg.write("/s", "memory.high", 50)
    cg.freeze("/s")
    cg.thaw("/s")
    # deferred mode: nothing applied until the epoch boundary
    assert not spy.applied("write") and not spy.applied("freeze")
    e1 = be.flush()
    assert e1 == e0 + 1                   # three ops -> ONE epoch
    names = [n for n, _ in spy.calls]
    i_w, i_f, i_t = (names.index(x) for x in ("write", "freeze", "thaw"))
    assert i_w < i_f < i_t                # FIFO order preserved
    assert cg.read("/s", "memory.high") == 50
    assert cg.read("/s", "cgroup.freeze") == 0
    be.close()


def test_mutations_run_on_daemon_thread_not_caller():
    """All lifecycle mutations apply on the daemon thread; only flushing
    reads execute on the caller."""
    cg, be, spy = mk_async()
    cg.mkdir("/s")
    cg.freeze("/s")
    cg.try_charge("/s", 5)
    be.flush()
    mutating = {"mkdir", "freeze", "try_charge"}
    tids = {t for n, t in spy.calls if n in mutating}
    assert tids == {be._thread.ident}
    assert threading.get_ident() not in tids
    be.close()


def test_fire_and_forget_never_blocks_caller():
    """A lifecycle op whose inner application is blocked still returns
    instantly to the caller — measurably off the critical path."""
    cg, be, spy = mk_async()
    cg.mkdir("/s")
    be.flush()
    spy.gates["freeze"] = threading.Event()          # block the apply
    t0 = time.perf_counter()
    cg.freeze("/s")                                  # enqueue only
    assert time.perf_counter() - t0 < 0.5
    assert not spy.applied("freeze")
    spy.gates["freeze"].set()
    be.flush()
    assert spy.applied("freeze")
    assert cg.read("/s", "cgroup.freeze") == 1
    be.close()


def test_reads_flush_and_snapshot_is_epoch_tagged():
    cg, be, spy = mk_async()
    cg.mkdir("/s")
    cg.write("/s", "memory.high", 70)                # queued
    assert cg.read("/s", "memory.high") == 70        # read forced the epoch
    snap = cg.snapshot()
    assert snap["epoch"] == be.epoch
    assert snap["usage"][snap["index"]["/s"]] == 0
    be.close()


def test_result_ops_match_synchronous_backend():
    sync = AgentCgroup(HostTreeBackend(500))
    cg, be, _ = mk_async()
    for c in (sync, cg):
        c.mkdir("/s")
        c.mkdir("/s/tool", DomainSpec(high=40))
        assert c.try_charge("/s/tool", 30).granted
        c.mkdir("/k")
        c.charge_unchecked("/k", 7)
    assert cg.handle("/s") == sync.handle("/s")
    assert cg.rmdir("/s/tool") == sync.rmdir("/s/tool") == 30
    assert cg.kill("/k") == sync.kill("/k") == 7
    assert cg.usage("/") == sync.usage("/")
    be.close()


# ------------------------------------------------------------------ errors


def test_deferred_error_surfaces_at_next_flush():
    cg, be, _ = mk_async()
    cg.mkdir("/s")
    be.flush()
    be.write("/s", "not.a.file", 1)       # bypass facade validation
    with pytest.raises(DaemonError) as ei:
        be.flush()
    assert isinstance(ei.value.__cause__, KeyError)
    # the daemon survives a bad op: the backend stays usable
    assert cg.try_charge("/s", 5).granted
    be.close()


def test_result_op_error_propagates_directly():
    cg, be, _ = mk_async()
    with pytest.raises(KeyError):
        be.rmdir("/nope", True)
    be.close()


def test_close_stops_daemon_even_when_drain_flush_raises():
    """A pending deferred-op failure surfaces from close()'s drain
    flush, but the daemon thread must still be stopped."""
    cg, be, _ = mk_async()
    cg.mkdir("/s")
    be.write("/s", "not.a.file", 1)       # deferred failure pending
    with pytest.raises(DaemonError):
        be.close()
    assert not be._thread.is_alive()
    with pytest.raises(DaemonError, match="closed"):
        cg.freeze("/s")


def test_submit_after_close_raises():
    cg, be, _ = mk_async()
    be.close()
    with pytest.raises(DaemonError):
        cg.freeze("/")


def test_wedged_daemon_fails_fast_not_hangs():
    """A stuck inner op makes flush raise DaemonError within the
    timeout instead of deadlocking the caller (CI pairs this with
    pytest-timeout for the workflow-level guarantee)."""
    cg, be, spy = mk_async(flush_timeout_s=0.3)
    cg.mkdir("/s")
    be.flush()
    spy.gates["freeze"] = threading.Event()          # never set -> wedged
    cg.freeze("/s")
    t0 = time.perf_counter()
    with pytest.raises(DaemonError, match="timed out"):
        be.flush()
    assert time.perf_counter() - t0 < 5.0
    # the timed-out work may still apply later, so the backend is
    # poisoned: no caller may keep using state it can no longer trust
    with pytest.raises(DaemonError, match="close and rebuild"):
        cg.freeze("/s")
    with pytest.raises(DaemonError, match="close and rebuild"):
        be.flush()
    spy.gates["freeze"].set()                        # unwedge + clean up
    be.close()
    assert not be._thread.is_alive()


def test_wedged_daemon_recovery_from_snapshot():
    """The rebuild contract: a backend rebuilt from the last good
    ``snapshot()`` carries identical control state, and continued ops
    on it bit-match an unpoisoned synchronous twin."""
    spy = SpyInner(HostTreeBackend(500))
    be = AsyncDaemonBackend(spy, flush_timeout_s=0.3)
    cg = AgentCgroup(be)
    twin = AgentCgroup(HostTreeBackend(500))
    for c in (cg, twin):
        c.mkdir("/t", DomainSpec(high=200))
        c.mkdir("/t/s", DomainSpec(high=60, priority=D.HIGH))
        c.try_charge("/t/s", 40, step=0)
        c.write("/t/s", "memory.high", 80)
    snap = cg.snapshot()                     # last known-good state
    spy.gates["freeze"] = threading.Event()  # wedge the daemon
    cg.freeze("/t/s")
    with pytest.raises(DaemonError):
        cg.flush()
    with pytest.raises(DaemonError):
        cg.mkdir("/t/x")                     # poisoned, loudly
    # rebuild: fresh inner restored from the snapshot, re-wrapped
    fresh = HostTreeBackend(500)
    fresh.restore(snap)
    be2 = AsyncDaemonBackend(fresh)
    cg.backend = be2
    snap2 = cg.snapshot()
    for key in ("paths", "usage", "peak", "high", "max", "low",
                "priority", "frozen", "killed"):
        assert list(snap2[key]) == list(snap[key]), key
    # continued ops on the rebuilt backend match the unpoisoned twin
    for c in (cg, twin):
        c.try_charge("/t/s", 30, step=1)
        c.freeze("/t/s")
        c.thaw("/t/s")
        c.uncharge("/t/s", 20)
        c.try_charge("/t/s", 100, step=2)    # over high: same decision
    for path in ("/", "/t", "/t/s"):
        for f in ("memory.current", "memory.peak", "memory.high",
                  "cgroup.freeze"):
            assert cg.read(path, f) == twin.read(path, f), (path, f)
    spy.gates["freeze"].set()                # let the old daemon drain
    be.close(flush=False)
    be2.close()


# -------------------------------------------------------------- eager mode


def test_eager_mode_applies_without_flush():
    cg, be, spy = mk_async(eager=True)
    cg.mkdir("/s")
    cg.write("/s", "memory.high", 99)
    deadline = time.time() + 10.0
    while not spy.applied("write") and time.time() < deadline:
        time.sleep(0.005)
    assert spy.applied("write")                      # no flush needed
    assert be._thread.ident in {t for _, t in spy.calls}
    assert cg.read("/s", "memory.high") == 99
    be.close()


def test_eager_reads_never_observe_mid_batch_state():
    """Reads from another thread while the eager daemon applies a
    stream of lifecycle ops must always see whole epochs — never a
    half-applied batch (e.g. a dict mutating mid-iteration)."""
    cg = AgentCgroup(AsyncDaemonBackend(HostTreeBackend(10_000),
                                        eager=True))
    cg.mkdir("/t")
    stop = threading.Event()
    errors: list[BaseException] = []

    def reader():
        try:
            while not stop.is_set():
                snap = cg.snapshot()
                assert snap["epoch"] <= cg.backend.epoch
                for p in cg.paths():
                    try:
                        cg.read(p, "memory.current")
                    except KeyError:
                        pass             # rmdir'd between reads — fine
        except BaseException as e:           # noqa: BLE001 — surfaced below
            errors.append(e)

    t = threading.Thread(target=reader)
    t.start()
    try:
        for i in range(120):
            cg.mkdir(f"/t/s{i}")
            cg.charge_unchecked(f"/t/s{i}", 3)
            if i % 3 == 0:
                cg.rmdir(f"/t/s{i}")
    finally:
        stop.set()
        t.join(timeout=30.0)
    assert not errors, errors[0]
    cg.backend.close()


def test_context_manager_closes():
    with AsyncDaemonBackend(HostTreeBackend(100)) as be:
        AgentCgroup(be).mkdir("/s")
    assert not be._thread.is_alive()
    with pytest.raises(DaemonError):
        be.flush()


# ------------------------- residual-transfer-exactly-once (regression)


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_rmdir_racing_inflight_charges_transfers_residual_once(kind):
    """``rmdir`` racing an in-flight charge batch (queued, on the async
    backends) must transfer the residual to the parent exactly once —
    no lost charges, no double-uncharge — on all four backend kinds."""
    cg = AgentCgroup(standard_backend_factory(kind)(500, 16))
    cg.mkdir("/s")
    cg.mkdir("/s/tool", DomainSpec(high=40))
    assert cg.try_charge("/s/tool", 30).granted
    cg.flush()
    # in-flight: these are still queued when rmdir is submitted (async);
    # FIFO ordering must serialize them before the removal
    cg.charge_unchecked("/s/tool", 12)
    cg.uncharge("/s/tool", 2)
    residual = cg.rmdir("/s/tool")
    assert residual == 40
    for _ in range(2):                    # re-flushing must not re-apply
        cg.flush()
        assert not cg.exists("/s/tool")
        assert cg.usage("/s") == 40 and cg.usage("/") == 40
    close = getattr(cg.backend, "close", None)
    if close:
        close()


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_kill_racing_inflight_charges_releases_once(kind):
    cg = AgentCgroup(standard_backend_factory(kind)(500, 16))
    cg.mkdir("/k")
    cg.mkdir("/k/a")
    assert cg.try_charge("/k/a", 40).granted
    cg.charge_unchecked("/k/a", 5)        # queued on async backends
    freed = cg.kill("/k")
    assert freed == 45
    for _ in range(2):
        cg.flush()
        assert cg.usage("/") == 0
        assert not cg.try_charge("/k/a", 1).granted   # killed stays denied
    close = getattr(cg.backend, "close", None)
    if close:
        close()


def test_concurrent_flushes_apply_exactly_once():
    """Many threads flushing while fire-and-forget charges are queued:
    every op applies once, in order, and the final rmdir sees them."""
    cg = AgentCgroup(AsyncDaemonBackend(HostTreeBackend(500)))
    cg.mkdir("/s")
    cg.mkdir("/s/tool")
    assert cg.try_charge("/s/tool", 30).granted
    for _ in range(8):
        cg.charge_unchecked("/s/tool", 1)
    threads = [threading.Thread(target=cg.backend.flush) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30.0)
    assert cg.rmdir("/s/tool") == 38
    assert cg.usage("/s") == 38 and cg.usage("/") == 38
    cg.backend.close()

"""PSI-style pressure accounting + the adaptive retuner (PR 9).

Layers under test:

  * the traced stall-event helpers (pure jnp truth tables);
  * host-side roll-up (``subtree_counts_by_path``) incl. partial views;
  * ``PressureMeter`` decay math on the facade clock;
  * the PSI line format round trip;
  * ``AdaptiveController`` knob discipline (hysteresis, cooldown,
    ``memory.max`` cap, bump ceiling, restore) over a scripted facade;
  * live host-backend counters + control files;
  * absolute goldens for the two conformance scenarios (the suite in
    ``test_cgroup.py`` already diffs all six kinds against host — the
    goldens pin host itself);
  * snapshot back-compat: pre-pressure snapshots restore with zeroed
    counters.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import domains as D
from repro.core import pressure as P
from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.cgroup import AgentCgroup, DomainSpec, HostTreeBackend
from repro.testing.conformance import (get_scenario, replay,
                                       standard_backend_factory)

# ------------------------------------------------------ traced helpers


def test_charge_stall_event_truth_table():
    stalled = jnp.asarray([True, False, True, False])
    throttled = jnp.asarray([False, True, True, False])
    got = P.charge_stall_event(stalled, throttled)
    assert got.dtype == jnp.int32
    assert np.asarray(got).tolist() == [1, 1, 1, 0]


def test_sched_stall_events_truth_table():
    dom = jnp.asarray([3, 0, -1, 5])
    advance = jnp.asarray([False, True, False, True])
    got = P.sched_stall_events(dom, advance)
    assert got.dtype == jnp.int32
    # invalid slots (dom < 0) never stall; granted slots never stall
    assert np.asarray(got).tolist() == [1, 0, 0, 0]


# ------------------------------------------------------------- roll-up


def test_subtree_counts_full_tree():
    counts = {"/": 1, "/a": 2, "/a/b": 3, "/c": 4}
    total = P.subtree_counts_by_path(counts)
    assert total == {"/": 10, "/a": 5, "/a/b": 3, "/c": 4}


def test_subtree_counts_partial_view():
    # a sharded table's slice: no root row, one subtree plus a stray
    counts = {"/t/a": 2, "/t/a/x": 3, "/q": 7}
    total = P.subtree_counts_by_path(counts)
    assert total["/t/a"] == 5
    assert total["/t/a/x"] == 3
    assert total["/q"] == 7


# ------------------------------------------------------- format / meter


def test_psi_line_roundtrip():
    line = P.format_psi(0.1234, 0.056789, 42)
    assert line == "some avg10=12.34 avg60=5.68 total=42"
    back = P.parse_psi(line)
    assert back["avg10"] == pytest.approx(0.1234)
    assert back["avg60"] == pytest.approx(0.0568)
    assert back["total"] == 42


def test_meter_seed_then_exact_decay():
    m = P.PressureMeter(step_ms=10.0, windows=(100.0, 500.0))
    row = m.sample("/a", "memory.stall", 5, now=0.0)
    assert row[2] == row[3] == 0.0            # first sample only seeds
    # 10 steps elapsed, 5 new events -> frac 0.5, folded with exp decay
    m.sample("/a", "memory.stall", 10, now=100.0)
    a10, a60 = math.exp(-100.0 / 100.0), math.exp(-100.0 / 500.0)
    assert m.avg10("/a", "memory.stall") == pytest.approx(0.5 * (1 - a10))
    assert m._rows[("/a", "memory.stall")][3] == pytest.approx(
        0.5 * (1 - a60))


def test_meter_frac_clamps_and_monotone_guard():
    m = P.PressureMeter(step_ms=10.0, windows=(100.0, 500.0))
    m.sample("/a", "memory.stall", 0, now=0.0)
    # 500 events in 1 step -> frac clamps to 1.0
    m.sample("/a", "memory.stall", 500, now=10.0)
    assert m.avg10("/a", "memory.stall") == pytest.approx(
        1.0 - math.exp(-0.1))
    # a counter that went BACKWARDS (e.g. a lease closed out of the
    # roll-up) clamps the delta at 0, never negative pressure
    before = m.avg10("/a", "memory.stall")
    m.sample("/a", "memory.stall", 100, now=20.0)
    assert 0.0 <= m.avg10("/a", "memory.stall") < before


def test_meter_same_clock_is_noop_and_forget_drops_subtree():
    m = P.PressureMeter()
    m.sample("/a", "memory.stall", 0, now=0.0)
    m.sample("/a", "memory.stall", 50, now=10.0)
    frozen = m.avg10("/a", "memory.stall")
    m.sample("/a", "memory.stall", 99, now=10.0)      # dt == 0: no fold
    assert m.avg10("/a", "memory.stall") == frozen
    m.sample("/a/b", "memory.stall", 1, now=10.0)
    m.sample("/ab", "memory.stall", 1, now=10.0)
    m.forget("/a")
    assert ("/a", "memory.stall") not in m._rows
    assert ("/a/b", "memory.stall") not in m._rows
    assert ("/ab", "memory.stall") in m._rows         # sibling prefix kept


# ------------------------------------------- adaptive knob discipline


class _Log:
    def __init__(self):
        self.records = []

    def emit(self, *a, **k):
        self.records.append((a, k))


class _ScriptedCg:
    """Minimal facade: pressure values are set directly by the test, so
    each controller branch is reachable on demand."""

    def __init__(self, files):
        self.avg = {}                   # (path, file) -> avg10 fraction
        self.files = dict(files)        # (path, file) -> value
        self.log = _Log()
        self.param_writes = []

    def exists(self, p):
        return any(k[0] == p for k in self.files)

    def paths(self):
        return ["/"] + sorted({k[0] for k in self.files})

    def read(self, p, f):
        if f in P.PRESSURE_FILES:
            return P.format_psi(self.avg.get((p, f), 0.0), 0.0, 0)
        return self.files[(p, f)]

    def write(self, p, f, v):
        self.files[(p, f)] = v

    def update_params(self, p, kv):
        self.param_writes.append((p, dict(kv)))


def _scripted(high=100, maximum=D.UNLIMITED, **cfg):
    cg = _ScriptedCg({("/a", "memory.high"): high,
                      ("/a", "memory.max"): maximum})
    return cg, AdaptiveController(cg, AdaptiveConfig(**cfg))


def test_adaptive_bump_and_restore_cycle():
    cg, ctl = _scripted(high=100, bump_factor=1.5, cooldown_ms=0.0)
    cg.avg[("/a", "memory.pressure")] = 0.2
    (ev,) = ctl.poll(0.0)
    assert (ev.action, ev.old, ev.new) == ("bump_high", 100.0, 150.0)
    assert cg.files[("/a", "memory.high")] == 150
    cg.avg[("/a", "memory.pressure")] = 0.01
    (ev,) = ctl.poll(1.0)
    assert (ev.action, ev.old, ev.new) == ("restore_high", 150.0, 100.0)
    assert cg.files[("/a", "memory.high")] == 100
    assert ctl.poll(2.0) == []            # nothing bumped: calm is a no-op
    assert len(cg.log.records) == 2       # every action hit the event log


def test_adaptive_never_exceeds_memory_max():
    cg, ctl = _scripted(high=100, maximum=120, bump_factor=2.0,
                        cooldown_ms=0.0)
    cg.avg[("/a", "memory.pressure")] = 0.9
    (ev,) = ctl.poll(0.0)
    assert ev.new == 120.0                # capped, not 200
    assert ctl.poll(1.0) == []            # at the wall: no further bump
    assert cg.files[("/a", "memory.high")] == 120


def test_adaptive_bump_ceiling():
    cg, ctl = _scripted(high=100, bump_factor=2.0, max_bumps=2,
                        cooldown_ms=0.0)
    cg.avg[("/a", "memory.pressure")] = 0.9
    assert ctl.poll(0.0) and ctl.poll(1.0)
    assert ctl.poll(2.0) == []            # max_bumps reached
    assert cg.files[("/a", "memory.high")] == 400


def test_adaptive_cooldown_and_dead_band():
    cg, ctl = _scripted(high=100, cooldown_ms=100.0)
    cg.avg[("/a", "memory.pressure")] = 0.9
    assert ctl.poll(0.0)
    assert ctl.poll(50.0) == []           # cooling down
    assert ctl.poll(100.0)
    # hysteresis: between low_frac and high_frac nothing moves, even
    # with bumps outstanding
    cg.avg[("/a", "memory.pressure")] = 0.10
    assert ctl.poll(300.0) == []
    assert cg.files[("/a", "memory.high")] == 225


def test_adaptive_skips_unlimited_high():
    cg, ctl = _scripted(high=D.UNLIMITED, cooldown_ms=0.0)
    cg.avg[("/a", "memory.pressure")] = 0.9
    assert ctl.poll(0.0) == []


def test_adaptive_cpu_retune_roundtrip():
    cg, ctl = _scripted(high=D.UNLIMITED, cooldown_ms=0.0,
                        retune=(("sched_boost", 2.0, 1.0),))
    cg.avg[("/a", "cpu.pressure")] = 0.5
    (ev,) = ctl.poll(0.0)
    assert (ev.action, ev.file) == ("retune", "cpu.pressure")
    assert cg.param_writes == [("/a", {"sched_boost": 2.0})]
    assert ctl.poll(1.0) == []            # already retuned
    cg.avg[("/a", "cpu.pressure")] = 0.0
    (ev,) = ctl.poll(2.0)
    assert ev.action == "restore_params"
    assert cg.param_writes[-1] == ("/a", {"sched_boost": 1.0})


def test_adaptive_watch_defaults_to_children_of_root():
    cg = _ScriptedCg({("/a", "memory.high"): 10,
                      ("/a/leaf", "memory.high"): 10,
                      ("/b", "memory.high"): 10})
    ctl = AdaptiveController(cg, AdaptiveConfig())
    assert ctl._watched() == ["/a", "/b"]
    ctl2 = AdaptiveController(cg, AdaptiveConfig(watch=("/a/leaf", "/gone")))
    assert ctl2._watched() == ["/a/leaf"]


# ------------------------------------------------- live host counters


def test_host_counters_files_and_rollup():
    cg = AgentCgroup(HostTreeBackend(100))
    cg.mkdir("/t")
    cg.mkdir("/t/a", DomainSpec(high=5))
    for s in range(6):
        cg.set_time(s * 10.0)
        cg.try_charge("/t/a", 3, step=s)          # over high from step 2
    mem = cg.read("/t/a", "memory.stall")
    assert mem > 0
    # roll-up: the parent's counter includes the child's
    assert cg.read("/t", "memory.stall") == mem
    assert cg.read("/", "memory.stall") == mem
    psi = P.parse_psi(cg.read("/t/a", "memory.pressure"))
    assert psi["total"] == mem and psi["avg10"] == 0.0   # first read seeds
    for s in range(6, 12):
        cg.set_time(s * 10.0)
        cg.try_charge("/t/a", 3, step=s)
    psi = P.parse_psi(cg.read("/t/a", "memory.pressure"))
    assert psi["total"] > mem and psi["avg10"] > 0.0
    # cpu side: budget 1 over two runnable domains stalls the loser
    cg.mkdir("/t/b")
    for s in range(4):
        cg.schedule(["/t/a", "/t/b"], [1, 1], s, 1)
    assert cg.read("/t", "cpu.stall") > 0
    # rmdir forgets the meter rows and the counters leave the roll-up
    cg.uncharge("/t/a", cg.usage("/t/a"))
    cg.rmdir("/t/a")
    assert cg.read("/t", "memory.stall") == 0
    assert ("/t/a", "memory.pressure") not in cg._pressure._rows


# ------------------------------------------------------ pinned goldens

_RAMP_GOLDEN = [
    (28, ("/t", "memory.stall", 3)),
    (29, ("/t", "cpu.stall", 6)),
    (30, ("/t", "memory.pressure", "some avg10=0.00 avg60=0.00 total=3")),
    (31, ("/t", "cpu.pressure", "some avg10=0.00 avg60=0.00 total=6")),
    (32, ("/t/a", "memory.pressure", "some avg10=0.00 avg60=0.00 total=2")),
    (53, ("/t", "memory.stall", 13)),
    (54, ("/t", "cpu.stall", 11)),
    (55, ("/t", "memory.pressure", "some avg10=22.12 avg60=4.88 total=13")),
    (56, ("/t", "cpu.pressure", "some avg10=22.12 avg60=4.88 total=11")),
    (57, ("/t/a", "memory.pressure", "some avg10=22.12 avg60=4.88 total=7")),
    (94, ("/t", "memory.stall", 31)),
    (95, ("/t", "cpu.stall", 20)),
    (96, ("/t", "memory.pressure", "some avg10=50.34 avg60=13.06 total=31")),
    (97, ("/t", "cpu.pressure", "some avg10=50.34 avg60=13.06 total=20")),
    (98, ("/t/a", "memory.pressure", "some avg10=50.34 avg60=13.06 total=16")),
]

_RETUNE_GOLDEN = [
    (29, ("[agentcgroup] PRESSURE: /t/a memory.pressure avg10=18.13% "
          "-> bump_high 40 -> 80",)),
    (41, ("[agentcgroup] PRESSURE: /t/a memory.pressure avg10=32.97% "
          "-> bump_high 80 -> 160",)),
    (53, ("[agentcgroup] PRESSURE: /t/a memory.pressure avg10=26.99% "
          "-> bump_high 160 -> 200",)),
    (93, ("/t/a", "memory.high", 200)),
    (135, ("[agentcgroup] PRESSURE: /t/a memory.pressure avg10=4.93% "
           "-> restore_high 200 -> 40",)),
    (194, ("/t/a", "memory.high", 40)),
    (195, ("/t/a", "memory.stall", 8)),
]


def _host_obs(name, kinds):
    sc = get_scenario(name)
    cg = AgentCgroup(
        standard_backend_factory("host")(sc.capacity, sc.n_domains))
    return [(i, v) for i, kind, v in replay(cg, sc) if kind in kinds]


def test_pressure_ramp_absolute_golden():
    got = _host_obs("pressure_ramp", ("read",))
    assert got == _RAMP_GOLDEN


def test_adaptive_retune_absolute_golden():
    """The full closed loop, pinned: three bumps (the last capped at
    ``memory.max`` = 200), decay through the dead band, one restore."""
    got = _host_obs("adaptive_retune", ("read", "adaptive"))
    assert got == _RETUNE_GOLDEN


# -------------------------------------------------- snapshot back-compat


def test_restore_from_prepressure_snapshot_zeroes_counters():
    be = HostTreeBackend(100)
    cg = AgentCgroup(be)
    cg.mkdir("/a", DomainSpec(high=2))
    for s in range(4):
        cg.try_charge("/a", 2, step=s)
    assert cg.read("/a", "memory.stall") > 0
    snap = be.snapshot()
    assert "mem_stall" in snap and "cpu_stall" in snap
    for k in ("mem_stall", "cpu_stall"):      # a pre-PR-9 snapshot
        snap.pop(k)
    be2 = HostTreeBackend(100)
    be2.restore(snap)
    cg2 = AgentCgroup(be2)
    assert cg2.usage("/a") == cg.usage("/a")
    assert cg2.read("/a", "memory.stall") == 0
    assert cg2.read("/a", "cpu.stall") == 0

"""tracelint: per-rule fixtures (each rule must flag its seeded
violation and pass its clean twin), suppression + baseline round-trip,
JSON reporter schema, and the self-run certifying src/ clean — the
static half of the conformance story, registered in tier-1 so every PR
is verified against the same invariants the parity suites certify
dynamically."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis.lint import (apply_baseline, lint_paths, lint_sources,
                                 load_baseline, render_json, render_text,
                                 rules_by_id, write_baseline)
from repro.analysis.lint.cli import main as cli_main

REPO = Path(__file__).resolve().parents[1]


def lint(path, src, **more):
    sources = {path: textwrap.dedent(src)}
    for p, s in more.items():
        sources[p] = textwrap.dedent(s)
    return lint_sources(sources)


def rules_fired(findings):
    return {f.rule for f in findings}


# --------------------------------------------------------------- TL001


def test_tl001_flags_python_branch_in_decision_module():
    findings = lint("core/progs.py", """\
        def charge_decision(prog, view, req):
            if view.usage > view.high:
                return 1
            return 0
        """)
    assert rules_fired(findings) == {"TL001"}
    assert "forks the one decision path" in findings[0].message


def test_tl001_flags_item_cast_numpy_assert_in_program_hooks():
    findings = lint("serving/myprog.py", """\
        class MyProg(PolicyProgram):
            def on_charge(self, view, req, params):
                assert req.pages > 0
                usage = view.usage.item()
                cap = float(view.high)
                return np.minimum(usage, cap)
        """)
    msgs = [f.message for f in findings]
    assert all(f.rule == "TL001" for f in findings)
    assert any("assert" in m for m in msgs)
    assert any(".item()" in m for m in msgs)
    assert any("float()" in m for m in msgs)
    assert any("np.minimum" in m for m in msgs)


def test_tl001_flags_host_sync_anywhere_in_decision_module():
    findings = lint("core/sched.py", """\
        def helper(x):
            return jax.block_until_ready(x)
        """)
    assert "TL001" in rules_fired(findings)


def test_tl001_clean_twin():
    findings = lint("core/progs.py", """\
        def charge_decision(prog, view, req):
            grant = jnp.where(view.usage > view.high, 0, 1)
            return grant

        class GraduatedThrottleProgram:
            def delay_ms(self, view, params, priority=None):
                if priority is None:          # static dispatch, not traced
                    priority = params[0]
                return jnp.maximum(priority, 0.0)

        def host_helper(tree):
            # not a traced entry point: host-side numpy is fine here
            return np.asarray(tree)
        """)
    assert findings == []


# --------------------------------------------------------------- TL002


def test_tl002_flags_scalar_closure():
    findings = lint("core/build.py", """\
        import jax

        class Builder:
            def make(self):
                scale = 2.0
                return jax.jit(lambda v: v * scale)
        """)
    assert rules_fired(findings) == {"TL002"}
    assert "'scale'" in findings[0].message


def test_tl002_flags_loop_variable_closure():
    findings = lint("core/build.py", """\
        import jax

        def build():
            fns = []
            for k in range(3):
                fns.append(jax.jit(lambda v: v + k))
            return fns
        """)
    assert rules_fired(findings) == {"TL002"}
    assert "loop variable" in findings[0].message


def test_tl002_clean_twin():
    findings = lint("core/build.py", """\
        import jax

        def module_fn(v):
            return v * 2.0

        jit_module = jax.jit(module_fn)   # module level: no python frame

        class Builder:
            def make(self):
                prog = self.prog          # object identity IS the code
                return jax.jit(lambda v: prog.on_charge(v))

            def make_arg(self):
                return jax.jit(lambda v, scale: v * scale)
        """)
    assert findings == []


# --------------------------------------------------------------- TL003


def test_tl003_flags_wall_clock_and_entropy():
    findings = lint("core/rec.py", """\
        import os
        import random
        import time

        def stamp():
            return time.time()

        def token():
            return os.urandom(8), random.random()

        def rng():
            return np.random.default_rng(), np.random.rand(3)
        """)
    msgs = [f.message for f in findings]
    assert all(f.rule == "TL003" for f in findings)
    assert any("time.time()" in m for m in msgs)
    assert any("os.urandom" in m for m in msgs)
    assert any("random.random" in m for m in msgs)
    assert any("without a seed" in m for m in msgs)
    assert any("np.random.rand" in m for m in msgs)


def test_tl003_flags_import_forms():
    findings = lint("testing/mk.py", """\
        from time import time
        from random import randint
        """)
    assert len(findings) == 2
    assert rules_fired(findings) == {"TL003"}


def test_tl003_clean_twin_and_allowlist():
    assert lint("core/wait.py", """\
        import time

        def wait(deadline):
            t0 = time.monotonic()          # shapes timing, never recorded
            time.sleep(0.01)
            return time.monotonic() - t0

        def rng(seed):
            return np.random.default_rng(seed)
        """) == []
    # launch/ and benchmarks/ are outside the replay path
    assert lint("launch/run.py", """\
        import time

        def banner():
            return time.time()
        """) == []


# --------------------------------------------------------------- TL004


def test_tl004_flags_unlocked_inner_access():
    findings = lint("core/daemon.py", """\
        import threading

        class AsyncBackend:
            def __init__(self, inner):
                self.inner = inner
                self._apply_lock = threading.Lock()

            def peek(self):
                return self.inner.log
        """)
    assert rules_fired(findings) == {"TL004"}
    assert "epoch mid-application" in findings[0].message


def test_tl004_clean_twin():
    findings = lint("core/daemon.py", """\
        import threading

        class AsyncBackend:
            def __init__(self, inner):
                self.inner = inner
                self._apply_lock = threading.Lock()

            def _observe(self, fn):
                with self._apply_lock:
                    return fn()

            def locked(self):
                with self._apply_lock:
                    return self.inner.log

            def via_lambda(self):
                return self._observe(lambda: self.inner.log)

            def via_local_def(self):
                def take():
                    return self.inner.snapshot()
                return self._observe(take)

        class SyncWrapper:
            # no _apply_lock: single-writer wrapper, rule does not bind
            def __init__(self, inner):
                self._inner = inner

            def read(self):
                return self._inner.read()
        """)
    assert findings == []


# --------------------------------------------------------------- TL005


PROTO = """\
    from typing import Protocol

    class Backend(Protocol):
        log: int

        def read(self, path, file): ...
        def write(self, path, file, value): ...
    """


def test_tl005_flags_missing_method_and_signature_drift():
    findings = lint("core/cgroup.py", PROTO, **{"core/bad.py": """\
        class BadBackend:
            def __init__(self):
                self.log = 0

            def read(self, path): ...
        """})
    msgs = [f.message for f in findings]
    assert all(f.rule == "TL005" for f in findings)
    assert any("missing Backend method 'write" in m for m in msgs)
    assert any("drifts from the Backend protocol" in m for m in msgs)


def test_tl005_flags_unsanctioned_surface_and_missing_attr():
    findings = lint("core/cgroup.py", PROTO, **{"core/extra.py": """\
        class ExtraBackend:
            def __init__(self):
                pass

            def read(self, path, file): ...
            def write(self, path, file, value): ...
            def frobnicate(self): ...
        """})
    msgs = [f.message for f in findings]
    assert any("frobnicate is not in the Backend protocol" in m
               for m in msgs)
    assert any("does not provide Backend attribute 'log'" in m
               for m in msgs)


def test_tl005_clean_twin_and_getattr_passthrough():
    findings = lint("core/cgroup.py", PROTO, **{"core/good.py": """\
        class GoodBackend:
            def __init__(self):
                self.log = 0

            def read(self, path, file): ...
            def write(self, path, file, value): ...
            def device_view(self): ...

        class WrapBackend:
            def __init__(self, inner):
                self._inner = inner

            def __getattr__(self, name):
                return getattr(self._inner, name)
        """})
    assert findings == []


# --------------------------------------------------------------- TL006


def test_tl006_flags_conditional_key():
    findings = lint("core/state.py", """\
        def new_state(flag):
            st = {"usage": 0}
            if flag:
                st["max"] = 1
            return st
        """)
    assert rules_fired(findings) == {"TL006"}
    assert "'max'" in findings[0].message


def test_tl006_clean_twin():
    findings = lint("core/state.py", """\
        def new_state(flag):
            st = {"usage": 0}
            st["max"] = 1                  # unconditional: stable shape
            st["usage"] = 2 if flag else 0  # value change, not structure
            return st

        def restore(t, snap):
            st = dict(t.state)             # copy: shape is t's concern
            for key in ("usage", "peak"):
                if key in snap:
                    st[key] = snap[key]
            return st
        """)
    assert findings == []


# --------------------------------------------- suppressions / meta rule


def test_suppression_with_justification_covers_finding():
    findings = lint("core/clock.py", """\
        import time

        def stamp():
            return time.time()  # tracelint: disable=TL003 -- fixture clock
        """)
    assert findings == []


def test_own_line_suppression_covers_next_line():
    findings = lint("core/clock.py", """\
        import time

        def stamp():
            # tracelint: disable=TL003 -- fixture clock
            return time.time()
        """)
    assert findings == []


def test_suppression_without_justification_is_flagged():
    findings = lint("core/clock.py", """\
        import time

        def stamp():
            return time.time()  # tracelint: disable=TL003
        """)
    assert rules_fired(findings) == {"TL000"}
    assert "without justification" in findings[0].message


def test_suppression_in_decision_module_is_flagged():
    findings = lint("core/sched.py", """\
        import time

        def helper():
            return time.time()  # tracelint: disable=TL003 -- nope
        """)
    assert any(f.rule == "TL000"
               and "decision-path module" in f.message for f in findings)


def test_unknown_rule_in_pragma_is_flagged():
    findings = lint("core/clock.py", """\
        x = 1  # tracelint: disable=TL999 -- no such rule
        """)
    assert rules_fired(findings) == {"TL000"}
    assert "TL999" in findings[0].message


def test_file_level_suppression():
    findings = lint("core/clock.py", """\
        # tracelint: disable-file=TL003 -- whole-file fixture exemption
        import time

        def stamp():
            return time.time()

        def stamp2():
            return time.time()
        """)
    assert findings == []


# ----------------------------------------------------- baseline / report


BAD_CORE = """\
    import time

    def stamp():
        return time.time()
    """


def test_baseline_round_trip(tmp_path):
    findings = lint("core/rec.py", BAD_CORE)
    assert findings
    bpath = tmp_path / "baseline.json"
    write_baseline(str(bpath), findings)
    fps = load_baseline(str(bpath))
    kept, grandfathered = apply_baseline(findings, fps)
    assert kept == [] and grandfathered == len(findings)
    # a new finding is NOT covered
    more = lint("core/rec.py", BAD_CORE + "\n\ndef t2():\n"
                "    return time.time()\n")
    kept, _ = apply_baseline(more, fps)
    assert len(kept) == 1


def test_json_report_schema():
    findings = lint("core/rec.py", BAD_CORE)
    payload = json.loads(render_json(findings, suppressed_by_baseline=2))
    assert payload["version"] == 1
    assert payload["total"] == len(findings) > 0
    assert payload["suppressed_by_baseline"] == 2
    assert payload["counts"] == {"TL003": len(findings)}
    for f in payload["findings"]:
        assert set(f) == {"rule", "path", "line", "col", "message"}
    assert "no findings" in render_text([])


def test_syntax_error_is_a_finding_not_a_crash():
    findings = lint_sources({"core/broken.py": "def f(:\n"})
    assert findings and findings[0].rule == "TL000"
    assert "syntax error" in findings[0].message


# ---------------------------------------------------------- CLI + self-run


def test_cli_exit_codes_and_select(tmp_path, capsys):
    bad = tmp_path / "core" / "rec.py"
    bad.parent.mkdir()
    bad.write_text(textwrap.dedent(BAD_CORE), encoding="utf-8")
    assert cli_main([str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "TL003" in out and "time.time()" in out
    # selecting an unrelated rule: clean
    assert cli_main([str(tmp_path), "--select", "TL004"]) == 0
    capsys.readouterr()
    assert cli_main([str(tmp_path), "--select", "TL042"]) == 2
    assert cli_main(["--list-rules"]) == 0
    assert cli_main([str(tmp_path / "nope.txt")]) == 2


def test_cli_json_and_write_baseline(tmp_path, capsys):
    bad = tmp_path / "core" / "rec.py"
    bad.parent.mkdir()
    bad.write_text(textwrap.dedent(BAD_CORE), encoding="utf-8")
    assert cli_main([str(tmp_path), "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["total"] == 1
    bpath = tmp_path / "baseline.json"
    assert cli_main([str(tmp_path), "--write-baseline", str(bpath)]) == 0
    capsys.readouterr()
    assert cli_main([str(tmp_path), "--baseline", str(bpath)]) == 0
    assert "grandfathered" in capsys.readouterr().out
    assert cli_main([str(tmp_path), "--baseline",
                     str(tmp_path / "missing.json")]) == 2


def test_every_rule_has_id_name_description():
    by_id = rules_by_id()
    assert set(by_id) == {"TL001", "TL002", "TL003", "TL004", "TL005",
                          "TL006"}
    for r in by_id.values():
        assert r.name and r.description


# The self-run: the acceptance invariant, registered in tier-1 so every
# future PR is linted locally and in CI alike.


def test_selfrun_core_is_finding_free():
    findings = lint_paths([str(REPO / "src" / "repro" / "core")])
    assert findings == [], "\n".join(f.format() for f in findings)


def test_selfrun_full_src_exits_clean():
    rc = cli_main([str(REPO / "src"),
                   "--baseline", str(REPO / "tracelint-baseline.json")])
    assert rc == 0


def test_selfrun_decision_modules_have_zero_suppressions():
    # the acceptance criterion verbatim: no pragmas at all in the
    # decision-path modules, not even justified ones
    for mod in ("progs.py", "sched.py", "controller.py"):
        text = (REPO / "src" / "repro" / "core" / mod).read_text()
        assert "tracelint:" not in text, mod


def test_checked_in_baseline_is_empty():
    fps = load_baseline(str(REPO / "tracelint-baseline.json"))
    assert fps == frozenset()

"""Generator calibration against the paper's §3 statistics."""
import numpy as np
import pytest

from repro.traces.generator import generate_dataset, generate_task, named_trace
from repro.traces.schema import to_alloc_events


@pytest.fixture(scope="module")
def glm_set():
    return generate_dataset("glm", 40, seed=7)


@pytest.fixture(scope="module")
def haiku_set():
    return generate_dataset("haiku", 20, seed=9)


def test_framework_baseline(glm_set, haiku_set):
    """~185 MB framework baseline (Haiku 183 / GLM 188)."""
    for ds in (glm_set, haiku_set):
        base = np.mean([t.baseline_mb for t in ds])
        assert 165 <= base <= 205, base


def test_duration_range(glm_set, haiku_set):
    glm_mean = np.mean([t.duration_s for t in glm_set]) / 60
    haiku_mean = np.mean([t.duration_s for t in haiku_set]) / 60
    assert 7 <= glm_mean <= 15, glm_mean          # paper: 10.8 min
    assert 3.5 <= haiku_mean <= 9, haiku_mean     # paper: 5.8 min


def test_init_fraction(glm_set):
    fr = np.mean([t.init_s / t.total_s for t in glm_set])
    assert 0.28 <= fr <= 0.50, fr                 # paper: 31-48%


def test_bursts_inside_tool_calls(glm_set):
    """Memory bursts (>300 MB over run min) concentrate in tool calls
    (paper: 98.5% Haiku / 67.3% GLM)."""
    in_call = total = 0
    for t in glm_set:
        thr = t.baseline_mb + 112                 # ~300MB abs threshold
        for i, m in enumerate(t.mem_mb):
            if m > thr:
                total += 1
                in_call += t.in_tool_call(float(i))
    if total:
        assert in_call / total > 0.55, in_call / total


def test_retry_loops(glm_set, haiku_set):
    glm_frac = np.mean([1.0 if t.retry_groups() else 0.0 for t in glm_set])
    assert glm_frac >= 0.8                        # paper: 97%
    groups = np.mean([len(t.retry_groups()) for t in glm_set])
    assert 1.0 <= groups <= 7.0, groups           # paper: 3.9


def test_cross_task_spread(glm_set, haiku_set):
    peaks = np.array([t.peak_mb for t in glm_set + haiku_set])
    assert peaks.max() / peaks.min() > 5.0        # paper: 20x
    cv = peaks.std() / peaks.mean()
    assert cv > 0.5, cv                           # paper: CV 147%


def test_pydicom_peak_to_avg():
    t = named_trace("pydicom/pydicom#2022", seed=0)
    assert abs(t.peak_mb - 4060) < 5
    assert t.peak_to_avg > 4.0                    # paper: 15.4x on 1-s samples


def test_run_to_run_nondeterminism():
    runs = [generate_task("iterative/dvc#777", "glm", seed=s)
            for s in range(6)]
    durs = [r.duration_s for r in runs]
    assert max(durs) / min(durs) > 1.15           # paper: 1.8x


def test_alloc_events_conserve_memory():
    t = generate_task("x", "glm", seed=3)
    ev = to_alloc_events(t, accel=50.0)
    net = sum(e.delta_mb for e in ev)
    assert abs(net) < 1e-6
    assert ev == sorted(ev, key=lambda e: e.t_ms)

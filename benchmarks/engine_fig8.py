"""Fig 8 on the LIVE serving engine (beyond-paper): three concurrent
agent sessions on a real (reduced) model with KV-page budgets, comparing
no-isolation / user-space daemon / in-step AgentCgroup enforcement."""
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.core import domains as D
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import Phase, Session

PERF = perf_replace(DEFAULT_PERF, scan_chunk=32)
COMMON = dict(max_slots=4, s_max=384, pool_pages=40, page_tokens=16)
SESSION_HIGH = {"lo1": 12, "lo2": 12}


def sessions():
    hi = Session(sid="hi", tenant="t", priority=D.HIGH,
                 prompt=list(range(2, 34)),
                 phases=[Phase(8, 96, "test"), Phase(8, 64, "git"),
                         Phase(12, 0)])
    lows = [Session(sid=f"lo{i}", tenant="t", priority=D.LOW,
                    prompt=list(range(2, 26)),
                    phases=[Phase(8, 160, "test"), Phase(8, 96, "test"),
                            Phase(8, 0)]) for i in (1, 2)]
    return [hi] + lows


def run():
    cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                              dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    scens = {
        "nolimit": EngineConfig(**COMMON, mode="nolimit", use_freeze=False,
                                use_tool_domains=False, use_intent=False),
        "userspace": EngineConfig(**COMMON, mode="userspace",
                                  use_freeze=False, use_tool_domains=False,
                                  use_intent=False,
                                  session_high=SESSION_HIGH),
        "agentcgroup": EngineConfig(**COMMON, mode="inkernel",
                                    use_freeze=True,
                                    session_high=SESSION_HIGH),
    }
    print("\n== live-engine multi-tenant serving (beyond-paper Fig 8) ==")
    print(f"{'mode':12s} {'survival':>8s} {'evict':>6s} {'pool_over':>9s} "
          f"{'sess_over':>9s} {'throttles':>9s} {'freezes':>7s} "
          f"{'lowP95ms':>8s} {'steps':>6s}")
    out = {}
    for name, ecfg in scens.items():
        eng = Engine(cfg, params, perf=PERF, ecfg=ecfg, seed=0)
        for s in sessions():
            eng.submit(s)
        eng.run(8000)
        r = eng.report()
        out[name] = r
        print(f"{name:12s} {r['survival']:8.2f} {r['evicted']:6d} "
              f"{r['overshoot_pages']:9d} {r['session_overshoot_pages']:9d} "
              f"{r['throttle_triggers']:9d} {r['freezes']:7d} "
              f"{r['low_p95_ms']:8.1f} {r['steps']:6d}")
    return out


if __name__ == "__main__":
    run()

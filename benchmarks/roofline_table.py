"""Format the dry-run results (results/dryrun/*.json) into the
§Dry-run / §Roofline tables for EXPERIMENTS.md."""
import glob
import json
import os

from repro.analysis.roofline import fmt_seconds


def load(out_dir: str = "results/dryrun"):
    cells = {}
    for p in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        d = json.load(open(p))
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def table(out_dir: str = "results/dryrun", mesh: str = "single",
          markdown: bool = False) -> str:
    cells = load(out_dir)
    lines = []
    sep = " | " if markdown else "  "
    hdr = sep.join([f"{'arch':26s}", f"{'shape':11s}", f"{'fits':4s}",
                    f"{'GiB/dev':>7s}", f"{'compute':>9s}", f"{'memory':>9s}",
                    f"{'collect':>9s}", f"{'dom':>7s}", f"{'useful':>6s}",
                    f"{'RLfrac':>6s}"])
    lines.append(("| " + hdr + " |") if markdown else hdr)
    if markdown:
        lines.append("|" + "|".join(["---"] * 10) + "|")
    for (arch, shape, m), d in sorted(cells.items()):
        if m != mesh:
            continue
        if not d.get("applicable"):
            row = [f"{arch:26s}", f"{shape:11s}", "skip", "", "", "", "",
                   "", "", ""]
        elif "error" in d:
            row = [f"{arch:26s}", f"{shape:11s}", "ERR", "", "", "", "",
                   "", "", ""]
        else:
            r, mem = d["roofline"], d["memory"]
            row = [f"{arch:26s}", f"{shape:11s}",
                   "yes" if mem["fits_hbm"] else "NO",
                   f"{mem['per_device_bytes'] / 2**30:7.1f}",
                   f"{fmt_seconds(r['compute_s']):>9s}",
                   f"{fmt_seconds(r['memory_s']):>9s}",
                   f"{fmt_seconds(r['collective_s']):>9s}",
                   f"{r['dominant'][:-2]:>7s}",
                   f"{r['useful_flop_ratio']:6.2f}",
                   f"{r['roofline_fraction']:6.3f}"]
        lines.append(("| " + sep.join(row) + " |") if markdown
                     else sep.join(row))
    return "\n".join(lines)


def run():
    for mesh in ("single", "multi"):
        print(f"\n== roofline baselines — {mesh}-pod mesh ==")
        print(table(mesh=mesh))


if __name__ == "__main__":
    run()

"""Benchmark suite entry point — one section per paper table/figure:

  characterization   §3 Figs 1-7 / Table 1 (workload statistics)
  mismatch           §4 Table 2 (granularity/responsiveness/adaptability)
  fig8_replay        §6 Fig 8 (trace replay: survival + P95 latency)
  escalation_waste   §6 semantic OOM escalation (retry completion + waste)
  adaptive_pressure  §4/§5 PSI-driven soft-limit retuner vs static limits
  engine_fig8        beyond-paper: Fig 8 on the live serving engine
  multitenant_isolation  cpu.weight proportional share vs uniform gate
  throttle_precision §6 kernel-selftest analogue (2000 ms +/- 2.3%)
  roofline_table     dry-run roofline baselines (if results/ present)

Run: PYTHONPATH=src python -m benchmarks.run
"""
import os
import sys
import time


def main() -> None:
    t0 = time.perf_counter()
    from benchmarks import (adaptive_pressure, characterization, engine_fig8,
                            engine_overhead, escalation_waste, fig8_replay,
                            mismatch, multitenant_isolation,
                            throttle_precision)
    characterization.run()
    mismatch.run()
    fig8_replay.run()
    escalation_waste.run(n=4)
    adaptive_pressure.run(n=4)
    engine_fig8.run()
    engine_overhead.run()
    multitenant_isolation.run()
    throttle_precision.run()
    if os.path.isdir("results/dryrun"):
        from benchmarks import roofline_table
        roofline_table.run()
    else:
        print("\n(results/dryrun missing — run "
              "`python -m repro.launch.dryrun --all` for roofline tables)")
    print(f"\nbenchmarks done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()

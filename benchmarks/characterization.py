"""Paper §3 (Figs 1-7, Table 1): workload characterization re-measured
from generated trace datasets, printed next to the paper's numbers."""
import numpy as np

from repro.traces.generator import generate_dataset, named_trace


def _burst_stats(ds, thr_over_base=112.0):
    in_call = total = 0
    for t in ds:
        thr = t.baseline_mb + thr_over_base
        for i, m in enumerate(t.mem_mb):
            if m > thr:
                total += 1
                in_call += t.in_tool_call(float(i))
    return (in_call / total) if total else float("nan")


def run(n_glm: int = 40, n_haiku: int = 20, seed: int = 7):
    glm = generate_dataset("glm", n_glm, seed=seed)
    haiku = generate_dataset("haiku", n_haiku, seed=seed + 1)
    both = glm + haiku
    rows = []

    def add(name, ours, paper):
        rows.append((name, ours, paper))

    add("task_duration_glm_min", np.mean([t.duration_s for t in glm]) / 60,
        "10.8")
    add("task_duration_haiku_min",
        np.mean([t.duration_s for t in haiku]) / 60, "5.8")
    add("init_frac_of_total",
        np.mean([t.init_s / t.total_s for t in both]), "0.31-0.48")
    tool_frac = np.mean([t.tool_time_s() / t.duration_s for t in both])
    add("tool_frac_of_active", tool_frac, "0.36-0.42")
    os_frac = np.mean([(t.init_s + t.tool_time_s()) / t.total_s
                       for t in both])
    add("os_level_frac_of_total", os_frac, "0.56-0.74")
    add("framework_baseline_mb", np.mean([t.baseline_mb for t in both]),
        "185 (183/188)")
    bash = [c for t in glm for c in t.tool_calls if c.tool == "Bash"]
    tool_time = sum(c.dur_s for t in glm for c in t.tool_calls)
    add("bash_share_of_tool_time_glm",
        sum(c.dur_s for c in bash) / tool_time, "0.981")
    test_t = sum(c.dur_s for c in bash if c.category == "test")
    add("test_share_of_bash_glm", test_t / sum(c.dur_s for c in bash),
        "0.437")
    peaks = np.array([t.peak_mb for t in both])
    add("peak_mb_range", f"{peaks.min():.0f}-{peaks.max():.0f}", "197-4000")
    add("peak_cv", peaks.std() / peaks.mean(), "1.47")
    pyd = named_trace("pydicom/pydicom#2022", seed=0)
    add("pydicom_peak_to_avg", pyd.peak_to_avg, "15.4")
    add("bursts_in_tool_calls_frac", _burst_stats(glm), "0.673 (glm)")
    retry = np.mean([1.0 if t.retry_groups() else 0.0 for t in glm])
    add("retry_task_frac_glm", retry, "0.97")
    add("retry_groups_per_task_glm",
        np.mean([len(t.retry_groups()) for t in glm]), "3.9")
    acc = [sum(c.retained_mb for c in t.tool_calls) for t in glm]
    add("max_retained_mb", max(acc), "<=502")
    add("cpu_avg_pct_glm", np.mean([t.cpu_pct.mean() for t in glm]),
        "7.6")
    # non-determinism: same task, different seeds
    from repro.traces.generator import generate_task
    runs = [generate_task("iterative/dvc#777", "glm", seed=s)
            for s in range(5)]
    durs = [r.duration_s for r in runs]
    add("same_task_duration_spread", max(durs) / min(durs), "1.8")

    print("\n== characterization (paper §3) ==")
    print(f"{'metric':34s} {'ours':>12s}   paper")
    for name, ours, paper in rows:
        o = f"{ours:.3f}" if isinstance(ours, (int, float)) else str(ours)
        print(f"{name:34s} {o:>12s}   {paper}")
    return rows


if __name__ == "__main__":
    run()

"""Paper §4 Table 2: quantitative demos of the three mismatches.

granularity    — static memory.max: average-sized limits kill bursty
                 tasks; peak-sized limits waste >90% of the reservation
                 (peak demand <2% of samples) and cap concurrency.
responsiveness — PSI daemon poll+react latency vs 1-2s bursts: kills
                 land after the burst; AgentCgroup throttles in-step.
adaptability   — P95-from-history limits are defeated by 1.8x-20x
                 non-determinism; kill-and-restart loses all progress.
"""
import numpy as np

from repro.core import domains as D
from repro.core.policy import (AgentCgroupPolicy, NoIsolationPolicy,
                               PredictiveP95Policy, ReactivePSIPolicy,
                               StaticLimitPolicy)
from repro.traces.generator import (generate_spike_corpus, generate_task,
                                    named_trace)
from repro.traces.replay import ReplayConfig, replay


def run():
    tr = [named_trace("dask/dask#11628", seed=1),
          named_trace("sigmavirus24/github3.py#673", seed=2),
          named_trace("sigmavirus24/github3.py#673", seed=3)]
    prios = [D.HIGH, D.LOW, D.LOW]
    print("\n== mismatch analysis (paper §4, Table 2) ==")

    # ---- granularity
    avg = int(np.mean([t.avg_mb for t in tr]))
    peak = int(max(t.peak_mb for t in tr)) + 10
    cfg = ReplayConfig(capacity_mb=5000)
    r_avg = replay(tr, prios, StaticLimitPolicy(limit_mb=avg), cfg)
    pol_peak = StaticLimitPolicy(limit_mb=peak)
    r_peak = replay(tr, prios, pol_peak, cfg)
    # waste: fraction of a peak-sized reservation unused on average
    waste = 1.0 - np.mean([t.avg_mb for t in tr]) / peak
    peak_time_frac = np.mean([
        np.mean(t.mem_mb > 0.9 * t.peak_mb) for t in tr])
    print(f"granularity : memory.max=avg({avg}MB) survival "
          f"{r_avg.survival:.2f}; memory.max=peak({peak}MB) survival "
          f"{r_peak.survival:.2f}, reservation waste {waste * 100:.0f}% "
          f"(paper >90%), peak-demand time {peak_time_frac * 100:.1f}% "
          f"(paper <2%), concurrency {pol_peak.max_concurrency(1100, 0)} "
          f"tasks/1100MB")

    # ---- responsiveness
    cfg = ReplayConfig(capacity_mb=1100)
    r_psi = replay(tr, prios, ReactivePSIPolicy(poll_ms=100, react_ms=40,
                                                pressure_threshold=0.3), cfg)
    r_agent = replay(tr, prios, AgentCgroupPolicy(
        session_high={"sigmavirus24/github3.py#673": 400}), cfg)
    burst_ms = 1.5 * 1000 / 50          # 1-2s bursts at 50x accel
    print(f"responsiveness: burst duration ~{burst_ms:.0f}ms(replay) vs "
          f"PSI poll+react 140ms -> oomd survival {r_psi.survival:.2f} "
          f"(kills after the burst); in-step throttle survival "
          f"{r_agent.survival:.2f} with {r_agent.throttle_count} "
          f"same-allocation delays")

    # ---- adaptability
    hist, tasks = {}, []
    for i in range(4):
        runs = [generate_task(f"t{i}", "glm", seed=s, scale=0.5)
                for s in range(3)]
        hist[f"t{i}"] = [r.peak_mb for r in runs]
        tasks.append(generate_task(f"t{i}", "glm", seed=50 + i, scale=1.3))
    r_pred = replay(tasks, [D.NORMAL] * 4,
                    PredictiveP95Policy(hist, safety=1.1),
                    ReplayConfig(capacity_mb=10 ** 6))
    r_acg = replay(tasks, [D.NORMAL] * 4, AgentCgroupPolicy(),
                   ReplayConfig(capacity_mb=10 ** 6))
    print(f"adaptability: P95-history limits survival {r_pred.survival:.2f} "
          f"under run-to-run variance; AgentCgroup (no prediction) "
          f"{r_acg.survival:.2f}")

    # ---- burst-shape profiles: ONE policy across model trace classes.
    # The mismatches are workload properties, not policy bugs: the same
    # AgentCgroup policy must hold across burst-shape/baseline profiles
    # (Haiku's tall test bursts, GLM's bash-heavy steadiness, and the
    # in-between qwen class) without per-model tuning.
    by_model = {}
    # spike targets matched to each class's burst shape (the 15.4x
    # exemplar was a Haiku task; GLM's bash-heavy traces spike flatter)
    for model, ratio in (("haiku", 15.4), ("glm", 7.0), ("qwen", 10.0)):
        corpus = generate_spike_corpus(4, seed=9, model=model,
                                       duration_s=120.0,
                                       peak_to_avg=ratio)
        r = replay(corpus, [D.NORMAL] * len(corpus), AgentCgroupPolicy(),
                   ReplayConfig(capacity_mb=1500))
        by_model[model] = (r.survival, r.throttle_count, r.peak_pool_mb)
        print(f"profiles    : {model:<6} survival {r.survival:.2f}, "
              f"throttles {r.throttle_count}, "
              f"peak pool {r.peak_pool_mb} MB (untuned policy)")

    return {"granularity": (r_avg.survival, r_peak.survival, waste),
            "responsiveness": (r_psi.survival, r_agent.survival),
            "adaptability": (r_pred.survival, r_acg.survival),
            "profiles": by_model}


if __name__ == "__main__":
    run()

"""Static vs pressure-adaptive soft limits on the spike corpus (§4/§5).

The paper's adaptability mismatch: agent memory is heavy-tailed (15.4x
peak-to-average) AND non-deterministic, so any statically sized
``memory.high`` is wrong most of the time — average-sized limits
throttle every burst, peak-sized limits reserve idle headroom.  The
PSI-style pressure subsystem (``core/pressure.py``) closes the loop:
``AdaptiveController`` watches each session's ``memory.pressure`` and
bumps the soft limit while a burst is actually stalling the domain,
then restores it when pressure decays — the hard ``memory.max`` wall
is never crossed, so tenant isolation is untouched.

Two replays of the same corpus under identical limits:

  * static    — ``memory.high`` = 1.3x the trace average, fixed;
  * adaptive  — same start point + ``AdaptiveController`` polled every
                tick: sustained avg10 above 15% doubles the soft limit
                (up to 3 bumps, capped at ``memory.max``), decay below
                5% restores it.

Reported: throttle events per granted allocation, LOW-task completion
overhead, and the HIGH tenant's P95 allocation latency — the adaptive
arm must win on throttling without worsening the HIGH tenant (the
assertions run in every mode; CI runs ``--quick``).

Run: PYTHONPATH=src python -m benchmarks.adaptive_pressure [--quick]
"""
from repro.core import domains as D
from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.policy import AgentCgroupPolicy
from repro.traces.generator import generate_spike_corpus
from repro.traces.replay import Replay, ReplayConfig

# generous pool: the binding constraint is the per-session soft limit,
# not pool exhaustion — isolating the adaptability-mismatch failure mode
CAPACITY_MB = 24_000
HIGH_FACTOR = 1.3        # session memory.high = 1.3x the trace average
MAX_FACTOR = 8.0         # session memory.max = 8x that high (hard wall)
# PSI windows sized to the 50x-accelerated replay clock (ms); the
# default 10 s / 60 s windows would never decay inside one replay
PRESSURE_WINDOWS = (300.0, 1500.0)

ADAPTIVE = AdaptiveConfig(high_frac=0.15, low_frac=0.05,
                          bump_factor=2.0, max_bumps=3, cooldown_ms=50.0)


class TightSessionPolicy(AgentCgroupPolicy):
    """AgentCgroup with average-sized session soft limits plus the hard
    ``memory.max`` wall the retuner must never cross."""
    name = "agentcgroup_static"

    def setup(self, sim, tasks) -> None:
        super().setup(sim, tasks)
        for t in tasks:
            high = self.session_high.get(t.trace.task_id, D.UNLIMITED)
            if high < D.UNLIMITED:
                sim.cg.write(self.domain_for(t), "memory.max",
                             int(high * MAX_FACTOR))


class AdaptivePolicy(TightSessionPolicy):
    """Same limits + the pressure-driven retuner polled every tick."""
    name = "agentcgroup_adaptive"

    def __init__(self, **kw):
        super().__init__(**kw)
        self.retuner = None

    def setup(self, sim, tasks) -> None:
        super().setup(sim, tasks)
        sim.cg.pressure_clock(windows=PRESSURE_WINDOWS)
        self.retuner = AdaptiveController(sim.cg, ADAPTIVE)

    def tick(self, sim) -> None:
        super().tick(sim)
        self.retuner.poll(sim.now_ms)


def _arm(traces, prios, policy, cfg) -> dict:
    sim = Replay(traces, prios, policy, cfg)
    res = sim.run()
    allocs = sum(sum(1 for e in t.events if e.delta_mb > 0)
                 for t in sim.tasks)
    lows = [r for t, r in zip(sim.tasks, res.tasks.values())
            if t.priority == D.LOW and r.completed]
    return {
        "summary": res.summary(),
        "throttles": res.throttle_count,
        "throttle_frac": res.throttle_count / max(allocs, 1),
        "survival": res.survival,
        "high_p95_ms": res.latency_of(D.HIGH).p95,
        "low_overhead": (sum(r.overhead for r in lows) / len(lows)
                         if lows else float("nan")),
        "root_psi": sim.cg.read("/", "memory.pressure"),
        "events": list(policy.retuner.events) if getattr(
            policy, "retuner", None) else [],
    }


def run(n: int = 8, seed: int = 1) -> dict:
    traces = generate_spike_corpus(n, seed=seed)
    prios = [D.HIGH] + [D.LOW] * (len(traces) - 1)
    session_high = {t.task_id: max(64, int(t.avg_mb * HIGH_FACTOR))
                    for i, t in enumerate(traces) if prios[i] != D.HIGH}
    cfg = ReplayConfig(capacity_mb=CAPACITY_MB)

    static = _arm(traces, prios,
                  TightSessionPolicy(session_high=session_high), cfg)
    adapt = _arm(traces, prios,
                 AdaptivePolicy(session_high=session_high), cfg)

    bumps = [e for e in adapt["events"] if e.action == "bump_high"]
    restores = [e for e in adapt["events"] if e.action == "restore_high"]
    out = {
        "tasks": len(traces),
        "peak_to_avg": max(t.peak_mb / t.avg_mb for t in traces),
        "static": static["summary"],
        "adaptive": adapt["summary"],
        "throttle_frac_static": static["throttle_frac"],
        "throttle_frac_adaptive": adapt["throttle_frac"],
        "low_overhead_static": static["low_overhead"],
        "low_overhead_adaptive": adapt["low_overhead"],
        "bumps": len(bumps),
        "restores": len(restores),
    }

    print("\n== Pressure-adaptive soft limits vs static (spike corpus) ==")
    print(f"corpus: {out['tasks']} heavy-tailed traces, max peak/avg "
          f"{out['peak_to_avg']:.1f}x (paper: 15.4x); memory.high = "
          f"{HIGH_FACTOR:.1f}x avg, memory.max = {MAX_FACTOR:.0f}x high")
    print(f"throttle events/alloc: static {static['throttle_frac']:.3f} "
          f"({static['throttles']}) -> adaptive "
          f"{adapt['throttle_frac']:.3f} ({adapt['throttles']})")
    print(f"LOW completion overhead: static "
          f"{100 * static['low_overhead']:.1f}% -> adaptive "
          f"{100 * adapt['low_overhead']:.1f}%")
    print(f"HIGH P95 alloc latency: static {static['high_p95_ms']:.3f} ms "
          f"-> adaptive {adapt['high_p95_ms']:.3f} ms")
    print(f"survival: static {static['survival']:.2f} -> adaptive "
          f"{adapt['survival']:.2f}")
    print(f"retuner: {out['bumps']} bump(s), {out['restores']} restore(s); "
          f"root PSI after run: {adapt['root_psi']}")
    if bumps:
        print(f"  first: {bumps[0].render()}")

    # the closed loop must RELIEVE throttling without weakening the
    # walls: fewer throttles, HIGH tenant not worse, nobody dies
    assert adapt["throttles"] < static["throttles"], (
        f"adaptive did not reduce throttling: {adapt['throttles']} vs "
        f"{static['throttles']}")
    assert adapt["high_p95_ms"] <= static["high_p95_ms"] * 1.05 + 1e-9, (
        f"adaptive worsened the HIGH tenant: P95 {adapt['high_p95_ms']} "
        f"vs {static['high_p95_ms']}")
    assert adapt["survival"] >= static["survival"], (
        "adaptive lowered survival")
    assert bumps, "pressure never crossed high_frac: no bumps fired"
    return out


if __name__ == "__main__":
    import sys
    quick = "--quick" in sys.argv
    run(n=4 if quick else 8)

"""Per-tenant isolation under concurrent burst replays (paper Fig-8
workload, N tenants) — shared single-device table vs the sharded
multi-tenant backend.

Every tenant replays the same agent rhythm: steady decode-page
allocation plus periodic tool-result bursts; tenant 0 is the aggressor
(oversized bursts).  Both configurations get the SAME aggregate page
pool:

  * ``shared``   — one ``DeviceTableBackend`` table, every tenant charges
    the same root: an aggressor burst consumes pool the victims then
    cannot get (the paper's §3 memory-interference finding);
  * ``sharded``  — ``ShardedTableBackend`` on the N-device mesh, one
    device group per tenant, each owning 1/N of the pool: the in-step
    ``shard_map`` charge gates each tenant only against its own group.

Reported per tenant: grant rate, denial count, longest stall streak,
and peak pages; the interference headline is the victims' denial rate
delta between the two configurations.

A second scenario exercises the CPU half (``cpu.weight``): four
tenants with weights 400/200/100/100 compete for two decode slots per
step under ``WeightedFairProgram``, against a uniform-weight baseline.
Grant shares must track the flattened weight ratios within 5%, and the
high-weight tenant's P99 gap between consecutive grants must be lower
than under the uniform gate — weight buys latency, not just share.

Run on a CPU host with fake devices (set by default):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python benchmarks/multitenant_isolation.py
``--quick`` runs only the fairness scenario with its tolerance
assertion (the CI bench-smoke entry).
"""
import argparse
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.core.cgroup import (AgentCgroup, DeviceTableBackend,  # noqa: E402
                               DomainSpec)
from repro.core.controller import ControllerConfig            # noqa: E402
from repro.core.sharded import ShardedTableBackend            # noqa: E402

CTRL = ControllerConfig(base_delay_ms=10.0, max_delay_ms=200.0)


def burst_schedule(n_tenants: int, steps: int) -> np.ndarray:
    """(steps, n_tenants) page requests: steady decode trickle for all,
    plus tool-result bursts — oversized for the aggressor (tenant 0)."""
    amt = np.zeros((steps, n_tenants), np.int32)
    amt[::4, :] = 1                              # decode page crossings
    for t in range(n_tenants):
        period, start = 50, 10 + 3 * t
        size = 24 if t == 0 else 4               # aggressor vs victims
        for s in range(start, steps, period):
            amt[s:s + 8, t] += size
    return amt


def run_config(kind: str, n_tenants: int, steps: int, pool: int) -> dict:
    if kind == "sharded":
        # split the SAME aggregate pool over the shards actually built
        # (tenants share a shard when they outnumber devices)
        n_sh = min(n_tenants, len(jax.devices()))
        be = ShardedTableBackend(pool // n_sh, n_domains=8, cfg=CTRL,
                                 n_shards=n_sh)
    else:
        be = DeviceTableBackend(pool, n_domains=4 * n_tenants + 4, cfg=CTRL)
    cg = AgentCgroup(be)
    handles = []
    for t in range(n_tenants):
        cg.mkdir(f"/t{t}")
        handles.append(cg.mkdir(f"/t{t}/sess", DomainSpec()))
    view = cg.device_view()

    import functools

    @functools.partial(jax.jit, donate_argnums=(0,))
    def step_fn(state, dom, amt, step_no):
        return view.charge(state, dom, amt, step_no)

    amt_all = burst_schedule(n_tenants, steps)
    dom = jnp.asarray(handles, jnp.int32)
    grants = np.zeros((steps, n_tenants), bool)
    requested = amt_all > 0
    state = view.state
    t0 = time.perf_counter()
    for s in range(steps):
        state, g, _ = step_fn(state, dom, jnp.asarray(amt_all[s]), s)
        grants[s] = np.asarray(g)
        # a granted burst's pages retire two steps later (tool output
        # consumed), keeping usage oscillating the way serving does
        if s >= 2:
            retire = jnp.asarray(np.where(grants[s - 2], amt_all[s - 2], 0))
            state = view.uncharge(state, dom, retire)
    jax.block_until_ready(state["usage"])
    dt = time.perf_counter() - t0
    view.commit(state)

    out = {"kind": kind, "steps_per_s": steps / dt, "tenants": []}
    for t in range(n_tenants):
        req = requested[:, t]
        ok = grants[:, t] & req
        denied = req & ~grants[:, t]
        streak = best = 0
        for d in denied:
            streak = streak + 1 if d else 0
            best = max(best, streak)
        out["tenants"].append({
            "tenant": f"/t{t}",
            "requests": int(req.sum()),
            "grant_rate": float(ok.sum() / max(req.sum(), 1)),
            "denials": int(denied.sum()),
            "max_stall_steps": best,
            "peak_pages": cg.peak(f"/t{t}"),
        })
    victims = out["tenants"][1:]
    out["victim_denial_rate"] = float(
        sum(v["denials"] for v in victims)
        / max(sum(v["requests"] for v in victims), 1))
    return out


def run_fairness(weights=(400, 200, 100, 100), steps: int = 2000,
                 budget: int = 2, tol: float = 0.05) -> dict:
    """Weighted decode-slot fairness: the same always-runnable slot mix
    under ``WeightedFairProgram``, weighted vs uniform-weight baseline.

    Asserts (a) grant shares within ``tol`` relative of the flattened
    weight ratios and (b) the top-weight tenant's P99 grant gap strictly
    below its uniform-baseline gap.
    """
    import functools

    from repro.core.sched import WeightedFairProgram

    n = len(weights)
    results = {}
    for label, ws in (("weighted", tuple(weights)),
                      ("uniform", (100,) * n)):
        be = DeviceTableBackend(10 ** 6, n_domains=n + 4, cfg=CTRL,
                                prog=WeightedFairProgram(
                                    base_delay_ms=0.0, max_delay_ms=0.0))
        cg = AgentCgroup(be)
        handles = [cg.mkdir(f"/t{t}", DomainSpec(weight=w))
                   for t, w in enumerate(ws)]
        view = cg.device_view()

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step_fn(state, dom, cost, step_no):
            return view.schedule(state, dom, cost, step_no, budget)

        dom = jnp.asarray(handles, jnp.int32)
        cost = jnp.ones((n,), jnp.int32)
        grants = np.zeros((steps, n), bool)
        state = view.state
        t0 = time.perf_counter()
        for s in range(steps):
            state, adv = step_fn(state, dom, cost, s)
            grants[s] = np.asarray(adv)
        jax.block_until_ready(state["vruntime"])
        dt = time.perf_counter() - t0
        view.commit(state)

        share = grants.sum(axis=0) / max(int(grants.sum()), 1)
        p99 = []
        for t in range(n):
            gap = np.diff(np.flatnonzero(grants[:, t]))
            p99.append(float(np.percentile(gap, 99)) if gap.size
                       else float("inf"))
        results[label] = {"share": share.tolist(), "p99_gap": p99,
                          "steps_per_s": steps / dt}

    expect = [w / sum(weights) for w in weights]
    got = results["weighted"]["share"]
    for t, (e, g) in enumerate(zip(expect, got)):
        assert abs(g - e) <= tol * e, (
            f"tenant /t{t}: share {g:.3f} vs weight ratio {e:.3f} "
            f"(>{100 * tol:.0f}% off)")
    hi = int(np.argmax(weights))
    assert (results["weighted"]["p99_gap"][hi]
            < results["uniform"]["p99_gap"][hi]), (
        "high-weight tenant's P99 grant gap did not improve over the "
        "uniform baseline")

    print(f"\n== weighted decode-slot fairness: {n} tenants, weights "
          f"{list(weights)}, {budget} slots/step, {steps} steps ==")
    print(f"{'tenant':8s} {'weight':>6s} {'share':>7s} {'expect':>7s} "
          f"{'p99gap':>7s} {'uniform':>8s}")
    for t in range(n):
        print(f"/t{t:<6d} {weights[t]:6d} {got[t]:7.3f} {expect[t]:7.3f} "
              f"{results['weighted']['p99_gap'][t]:7.0f} "
              f"{results['uniform']['p99_gap'][t]:8.0f}")
    print(f"shares within {100 * tol:.0f}% of weight ratios; high-weight "
          f"p99 gap {results['weighted']['p99_gap'][hi]:.0f} vs uniform "
          f"{results['uniform']['p99_gap'][hi]:.0f} steps "
          f"({results['weighted']['steps_per_s']:.0f} sched-steps/s)")
    return results


def run() -> dict:
    """Suite entry point (benchmarks.run): the weighted-fairness
    scenario; the 8-device isolation comparison stays CLI-only."""
    return run_fairness(steps=1200)


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--pool", type=int, default=256)
    ap.add_argument("--quick", action="store_true",
                    help="fairness scenario + tolerance assertion only "
                         "(CI bench-smoke)")
    args = ap.parse_args()

    if args.quick:
        run_fairness(steps=400)
        print("quick fairness check: PASS")
        return {}

    print(f"\n== multi-tenant burst isolation: {args.tenants} tenants, "
          f"{args.steps} steps, {args.pool}-page aggregate pool, "
          f"{len(jax.devices())} devices ==")
    results = {}
    for kind in ("shared", "sharded"):
        r = run_config(kind, args.tenants, args.steps, args.pool)
        results[kind] = r
        print(f"\n[{kind}]  {r['steps_per_s']:.0f} charge-steps/s, "
              f"victim denial rate {r['victim_denial_rate']:.3f}")
        print(f"{'tenant':8s} {'reqs':>5s} {'grant%':>7s} {'denied':>6s} "
              f"{'stallmax':>8s} {'peak':>5s}")
        for row in r["tenants"]:
            print(f"{row['tenant']:8s} {row['requests']:5d} "
                  f"{100 * row['grant_rate']:6.1f}% {row['denials']:6d} "
                  f"{row['max_stall_steps']:8d} {row['peak_pages']:5d}")
    shared = results["shared"]["victim_denial_rate"]
    shard = results["sharded"]["victim_denial_rate"]
    print(f"\nvictim denial rate: shared={shared:.3f}  sharded={shard:.3f}"
          f"  (interference removed: "
          f"{100 * (shared - shard) / max(shared, 1e-9):.0f}%)")
    results["fairness"] = run_fairness()
    return results


if __name__ == "__main__":
    main()

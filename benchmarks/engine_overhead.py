"""Paper §6: "Enforcement overhead is negligible: P50 latency increases
by 0.3%".  Ours: wall-clock engine-step times with the in-step
controller ON vs OFF (accounting-only), uncontended (huge pool, no
throttles fire), same model/sessions/seed.

``--quick`` runs a short smoke (CI): fewer timed steps plus a hard
ceiling on the enforcement overhead, so a change to the program
dispatch path (core/progs.py) cannot silently regress step latency.
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import domains as D
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import Phase, Session


def _sessions():
    return [Session(sid=f"s{i}", tenant="t",
                    priority=D.HIGH if i == 0 else D.LOW,
                    prompt=list(range(2, 34)),
                    phases=[Phase(16, 64, "test"), Phase(16, 0)])
            for i in range(3)]


def _run(cfg, params, mode: str, steps: int = 400,
         tool_domains: bool = False, backend: str = "device"):
    ecfg = EngineConfig(max_slots=4, s_max=512, pool_pages=4096,
                        page_tokens=16, mode=mode, use_freeze=False,
                        use_tool_domains=tool_domains,
                        use_intent=tool_domains, backend=backend)
    eng = Engine(cfg, params, perf=perf_replace(DEFAULT_PERF, scan_chunk=32),
                 ecfg=ecfg, seed=0)
    for s in _sessions():
        eng.submit(s)
    # warm the jit
    for _ in range(5):
        eng.step()
    times = []
    for _ in range(steps):
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    eng.close()
    return np.array(times) * 1e3


def fused_kernel_gate(quick: bool, iters: int = 300, batch: int = 32,
                      n_domains: int = 64) -> dict:
    """Tentpole gate: wall-clock the fused Pallas enforcement kernel
    against the lax scan reference at the same shape (mixed two-program
    registry, like a busy engine).  On real TPUs the fused path must
    not lose at P50; in interpret mode (CPU CI) the "kernel" is
    emulated with traced jax ops, so only the numbers are reported."""
    from repro import compat
    from repro.analysis.roofline import enforcement_roofline
    from repro.core import controller as C
    from repro.core.cgroup import AgentCgroup, DeviceTableBackend, DomainSpec
    from repro.core.progs import GraduatedThrottleProgram, TokenBucketProgram
    from repro.kernels.enforcement import fused_charge_batch
    import jax.numpy as jnp

    cg = AgentCgroup(DeviceTableBackend(1 << 20, n_domains=n_domains))
    cg.attach("/", GraduatedThrottleProgram())
    cg.mkdir("/grad", DomainSpec(high=1000))
    cg.mkdir("/bkt")
    cg.attach("/bkt", TokenBucketProgram(bucket_capacity=64,
                                         refill=(1.0, 1.0, 1.0)))
    progs = cg.programs
    st = cg.device_view().state
    dom = jnp.array([cg.handle("/grad"), cg.handle("/bkt")] * (batch // 2),
                    jnp.int32)
    amt = jnp.ones((batch,), jnp.int32)
    lax_j = jax.jit(lambda s, d, a: C._lax_charge_batch(s, d, a, 0, progs))
    fused_j = jax.jit(lambda s, d, a: fused_charge_batch(s, d, a, 0, progs))

    def p50(fn):
        jax.block_until_ready(fn(st, dom, amt))          # warm the jit
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(st, dom, amt))
            times.append(time.perf_counter() - t0)
        return float(np.percentile(np.array(times) * 1e3, 50))

    lp, fp = p50(lax_j), p50(fused_j)
    rl = enforcement_roofline(n_domains=n_domains, batch=batch)
    print("\n== fused enforcement kernel vs lax scan "
          f"(batch={batch}, {len(progs)} programs) ==")
    print(f"charge_batch P50: lax {lp:.3f} ms | fused {fp:.3f} ms "
          f"({(fp / lp - 1) * 100:+.1f}%)")
    print(f"cost model: lax {rl['lax']['bytes']:.0f} B / "
          f"{rl['lax']['flops']:.0f} flop, fused "
          f"{rl['fused']['bytes']:.0f} B / {rl['fused']['flops']:.0f} flop")
    if quick:
        if compat.on_tpu():
            assert fp <= lp, \
                f"fused P50 {fp:.3f} ms > lax P50 {lp:.3f} ms on TPU"
            print(f"fused-kernel gate OK (fused {fp:.3f} <= lax {lp:.3f})")
        else:
            print("fused-kernel gate: interpret mode, P50 assert skipped "
                  "(the kernel is emulated off-TPU)")
    return {"p50_lax_charge": lp, "p50_fused_charge": fp}


def run(steps: int = 400, quick: bool = False, backend: str = "device"):
    cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                              dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    off = _run(cfg, params, "nolimit", steps=steps)
    core = _run(cfg, params, "inkernel", steps=steps)     # in-step charge only
    full = _run(cfg, params, "inkernel", steps=steps, tool_domains=True)
    p = lambda a, q: float(np.percentile(a, q))
    print("\n== in-step enforcement overhead (paper: P50 +0.3%) ==")
    print(f"engine step P50: accounting-only {p(off,50):.2f} ms | "
          f"+in-step enforcement {p(core,50):.2f} ms "
          f"({(p(core,50)/p(off,50)-1)*100:+.1f}%) | "
          f"+tool-domains/intent (host daemon) {p(full,50):.2f} ms "
          f"({(p(full,50)/p(off,50)-1)*100:+.1f}%)")
    print("   (the in-kernel analogue is the middle column; host-side "
          "domain lifecycle is the paper's user-space daemon work)")
    out = {"p50_off": p(off, 50), "p50_core": p(core, 50),
           "p50_full": p(full, 50)}
    if backend == "async":
        # the async lifecycle daemon: same in-step enforcement, but all
        # lifecycle ops queued to the daemon thread and applied in
        # step-boundary epochs — the wrapper may not add measurable
        # per-step latency to the enforcement path
        acore = _run(cfg, params, "inkernel", steps=steps, backend="async")
        ratio_async = p(acore, 50) / p(core, 50)
        print(f"async lifecycle daemon: P50 {p(acore,50):.2f} ms "
              f"({(ratio_async-1)*100:+.1f}% vs synchronous in-step)")
        out["p50_async"] = p(acore, 50)
        if quick:
            assert ratio_async < 1.25, \
                f"async wrapper P50 ratio {ratio_async:.2f} >= 1.25"
            print(f"async-wrapper smoke OK (ratio {ratio_async:.2f} < 1.25)")
    if quick:
        # smoke ceiling: in-step program dispatch may not blow up the
        # step (generous bound — CI machines are noisy; the point is to
        # catch an accidental host sync / retrace in the dispatch path)
        ratio = p(core, 50) / p(off, 50)
        assert ratio < 2.0, f"in-step enforcement P50 ratio {ratio:.2f} >= 2"
        print(f"quick-mode smoke OK (ratio {ratio:.2f} < 2.0)")
    out.update(fused_kernel_gate(quick, iters=60 if quick else 300))
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: few steps + overhead ceiling assert")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--backend", default="device",
                    choices=["device", "async"],
                    help="async: also time the async-daemon wrapper and "
                         "(with --quick) assert it adds no measurable "
                         "per-step enforcement latency")
    args = ap.parse_args()
    run(steps=args.steps or (60 if args.quick else 400), quick=args.quick,
        backend=args.backend)

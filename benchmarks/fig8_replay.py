"""Paper §6 Fig 8: multi-tenant trace replay, baseline vs AgentCgroup.

(a) tight memory  — 1100 MB pool vs ~1233 MB combined demand:
    OOM survival rate (paper: 66% -> 100%).
(b) moderate memory — 1300 MB pool: HIGH-priority P95 allocation
    latency (paper: 70.97 -> 50.14 ms, -29%), P50 ~unchanged (+0.3%),
    HIGH completion overhead (paper: +2.8%), throttle delay triggers
    (paper: 239).
"""
from repro.core import domains as D
from repro.core.policy import AgentCgroupPolicy, NoIsolationPolicy
from repro.traces.generator import named_trace
from repro.traces.replay import ReplayConfig, replay

LOWHIGH = {"sigmavirus24/github3.py#673": 400}


def traces():
    return ([named_trace("dask/dask#11628", seed=1),
             named_trace("sigmavirus24/github3.py#673", seed=2),
             named_trace("sigmavirus24/github3.py#673", seed=3)],
            [D.HIGH, D.LOW, D.LOW])


def run():
    tr, prios = traces()
    out = {}
    # uncontended reference for overhead accounting
    ref = replay(tr, prios, NoIsolationPolicy(),
                 ReplayConfig(capacity_mb=10 ** 7))
    ref_hi = list(ref.tasks.values())[0].finish_ms

    for cap, tag in ((1100, "tight"), (1300, "moderate")):
        cfg = ReplayConfig(capacity_mb=cap)
        base = replay(tr, prios, NoIsolationPolicy(), cfg)
        agent = replay(tr, prios, AgentCgroupPolicy(session_high=LOWHIGH),
                       cfg)
        bh, ah = base.latency_of(D.HIGH), agent.latency_of(D.HIGH)
        hi_base = list(base.tasks.values())[0]
        hi_agent = list(agent.tasks.values())[0]
        out[tag] = {
            "survival_base": base.survival,
            "survival_agent": agent.survival,
            "high_p95_base_ms": bh.p95,
            "high_p95_agent_ms": ah.p95,
            "high_p95_delta": (ah.p95 / bh.p95 - 1) if bh.p95 else 0.0,
            "high_p50_base_ms": bh.p50,
            "high_p50_agent_ms": ah.p50,
            "throttle_triggers": agent.throttle_count,
            "freezes": agent.log.count(
                __import__("repro.core.events", fromlist=["Ev"]).Ev.FREEZE),
            "high_overhead_base": (hi_base.finish_ms / ref_hi - 1
                                   if hi_base.completed else float("nan")),
            "high_overhead_agent": hi_agent.finish_ms / ref_hi - 1,
        }

    print("\n== Fig 8 trace replay ==")
    t, m = out["tight"], out["moderate"]
    print(f"(a) tight 1100MB   survival: base {t['survival_base']:.2f} -> "
          f"agentcgroup {t['survival_agent']:.2f}   (paper 0.66 -> 1.00)")
    ob = t["high_overhead_base"]
    ob_s = f"{ob*100:+.1f}%" if ob == ob else "killed"
    print(f"    HIGH overhead: base {ob_s} -> "
          f"agent {t['high_overhead_agent']*100:+.1f}%  (paper +2.8%)")
    print(f"(b) moderate 1300MB HIGH P95: {m['high_p95_base_ms']:.2f} -> "
          f"{m['high_p95_agent_ms']:.2f} ms "
          f"({m['high_p95_delta']*100:+.1f}%)  (paper 70.97 -> 50.14, -29%)")
    print(f"    HIGH P50: {m['high_p50_base_ms']:.2f} -> "
          f"{m['high_p50_agent_ms']:.2f} ms            (paper +0.3%)")
    print(f"    throttle delay triggers: {m['throttle_triggers']} "
          f"(paper 239); freezes: {m['freezes']}")
    return out


if __name__ == "__main__":
    run()

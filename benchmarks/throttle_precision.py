"""Paper §6 selftest analogue: throttle-delay precision.

The paper configures a 2000 ms memcg_bpf_ops delay and measures
2.000 +/- 0.046 s (2.3% relative error).  Our in-step controller
quantizes delays to engine steps; we measure:
  (1) mechanism precision — configured delay vs the step at which the
      slot gate actually reopens (quantization error), and
  (2) wall-clock precision — the same, timed through the REAL jitted
      engine step on a reduced model.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.cgroup import AgentCgroup, DeviceTableBackend, DomainSpec
from repro.core.controller import ControllerConfig


def _throttled_view(delay_ms: float, step_ms: float):
    """One over-``high`` charge through the unified control plane;
    returns the device view + post-charge state + the domain index."""
    cfg = ControllerConfig(step_ms=step_ms, base_delay_ms=delay_ms,
                           max_delay_ms=delay_ms, overage_gain=0.0)
    cg = AgentCgroup(DeviceTableBackend(10_000, n_domains=8, cfg=cfg))
    idx = cg.mkdir("/s", DomainSpec(high=10))
    view = cg.device_view()
    st, granted, _ = view.charge(view.state, jnp.array([idx]),
                                 jnp.array([50], jnp.int32), 0)
    assert bool(granted[0])
    return view, st, idx


def mechanism_precision(delay_ms: float = 2000.0, step_ms: float = 10.0):
    view, st, idx = _throttled_view(delay_ms, step_ms)
    gate_fn = jax.jit(lambda s, d, t: view.gate(s, d, t))
    reopened = None
    for step in range(1, int(delay_ms / step_ms) + 10):
        if bool(gate_fn(st, jnp.array([idx]), step)[0]):
            reopened = step
            break
    measured = reopened * step_ms
    err = abs(measured - delay_ms) / delay_ms
    return measured, err


def wallclock_precision(delay_ms: float = 2000.0, step_ms: float = 10.0):
    """Time the reopen through actual jitted gate evaluations, pacing
    steps at step_ms (the engine cadence)."""
    view, st, idx = _throttled_view(delay_ms, step_ms)
    gate_fn = jax.jit(lambda s, d, t: view.gate(s, d, t))
    bool(gate_fn(st, jnp.array([idx]), 0)[0])     # warm the jit
    t0 = time.perf_counter()
    step = 0
    deadline = t0
    while True:
        step += 1
        deadline += step_ms / 1000.0
        while time.perf_counter() < deadline:
            pass
        if bool(gate_fn(st, jnp.array([idx]), step)[0]):
            break
    measured = (time.perf_counter() - t0) * 1000.0
    err = abs(measured - delay_ms) / delay_ms
    return measured, err


def run():
    m_ms, m_err = mechanism_precision()
    w_ms, w_err = wallclock_precision()
    print("\n== throttle precision (paper: 2.000 +/- 0.046 s, 2.3%) ==")
    print(f"mechanism : configured 2000 ms, reopened at {m_ms:.0f} ms "
          f"(err {m_err * 100:.2f}%)")
    print(f"wall-clock: configured 2000 ms, measured {w_ms:.1f} ms "
          f"(err {w_err * 100:.2f}%)")
    return {"mechanism_ms": m_ms, "mechanism_err": m_err,
            "wall_ms": w_ms, "wall_err": w_err}


if __name__ == "__main__":
    run()

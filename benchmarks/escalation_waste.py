"""Semantic OOM escalation vs. a no-retry hard limit (paper §6).

The paper's waste argument: agentic memory is heavy-tailed (measured
15.4x peak-to-average spikes), so a hard per-tool ``memory.max`` sized
for the typical call kills the spikes — and a kill without retry
discards the task's entire resident set.  The escalation loop absorbs
the same kill at tool-call granularity: the killed lease's ``OomEvent``
is negotiated into a bounded exponentially-growing grant and the call
replays under the new limit.

Two replays of the same heavy-tailed corpus, identical tool limits:

  * static      — ``lease_max_factor`` only: a breach is fatal.
  * escalating  — same limits + ``EscalationPolicy``: breach -> kill
                  the CALL -> negotiate -> retry; the ``WasteLedger``
                  accounts discarded pages per attempt vs. the
                  baseline's whole-task loss.

Run: PYTHONPATH=src python -m benchmarks.escalation_waste [--quick]
"""
from repro.core import domains as D
from repro.core.escalation import EscalationPolicy
from repro.core.policy import AgentCgroupPolicy
from repro.traces.generator import generate_spike_corpus
from repro.traces.replay import ReplayConfig, replay

# generous pool: the binding constraint is the per-tool lease max, not
# pool exhaustion — isolating the granularity-mismatch failure mode
CAPACITY_MB = 24_000
LEASE_MAX_FACTOR = 1.0          # hard lease max = the intent-hinted high


def run(n: int = 8, seed: int = 1) -> dict:
    traces = generate_spike_corpus(n, seed=seed)
    prios = [D.NORMAL] * len(traces)
    cfg = ReplayConfig(capacity_mb=CAPACITY_MB)

    static = replay(traces, prios,
                    AgentCgroupPolicy(lease_max_factor=LEASE_MAX_FACTOR),
                    cfg)
    esc = replay(traces, prios,
                 AgentCgroupPolicy(lease_max_factor=LEASE_MAX_FACTOR,
                                   escalation=EscalationPolicy()),
                 cfg)
    led = esc.escalation
    out = {
        "tasks": len(traces),
        "peak_to_avg": max(t.peak_mb / t.avg_mb for t in traces),
        "survival_static": static.survival,
        "survival_escalating": esc.survival,
        "killed_calls": led["killed_calls"],
        "recovered_calls": led["recovered_calls"],
        "recovery_rate": led["recovery_rate"],
        "kills": led["kills"],
        "exhausted": led["exhausted"],
        "attempt_waste_mb": led["attempt_waste_pages"],
        "baseline_waste_mb": led["baseline_waste_pages"],
        "saved_mb": led["saved_pages"],
    }

    print("\n== Semantic OOM escalation: retry completion & waste ==")
    print(f"corpus: {out['tasks']} heavy-tailed traces, max peak/avg "
          f"{out['peak_to_avg']:.1f}x (paper: 15.4x), pool {CAPACITY_MB} MB, "
          f"lease max = {LEASE_MAX_FACTOR:.1f}x hinted high")
    print(f"task survival:   static {out['survival_static']:.2f} -> "
          f"escalating {out['survival_escalating']:.2f}")
    print(f"killed tool calls: {out['killed_calls']} "
          f"({out['kills']} kill(s) over all attempts, "
          f"{out['exhausted']} exhausted)")
    print(f"retry completion: {out['recovered_calls']}/{out['killed_calls']} "
          f"({out['recovery_rate'] * 100:.0f}%)")
    print(f"waste: no-retry baseline discards {out['baseline_waste_mb']} MB "
          f"(whole tasks); escalation discards {out['attempt_waste_mb']} MB "
          f"(per-attempt) -> {out['saved_mb']} MB saved")

    # the paper's claim, asserted (CI runs ``--quick``): escalation
    # turns fatal breaches into recoveries and discards strictly less
    assert out["survival_escalating"] >= out["survival_static"], (
        "escalation lowered task survival")
    assert out["killed_calls"] > 0, (
        "corpus never breached a lease max: nothing was exercised")
    assert out["recovered_calls"] > 0, "no killed call recovered"
    assert out["saved_mb"] > 0, (
        "escalation did not reduce discarded work vs the no-retry baseline")
    return out


if __name__ == "__main__":
    import sys
    quick = "--quick" in sys.argv
    run(n=4 if quick else 8)

"""Training step: CE loss + AdamW, with microbatching (gradient
accumulation via ``lax.scan``), optional int8 gradient compression with
error feedback, and remat handled inside the model's scanned groups.

``make_train_step(cfg, perf, opt_cfg)`` returns a pure function
``(params, opt_state, batch, step) -> (params, opt_state, metrics)``
suitable for ``jax.jit`` / the dry-run's ``jit(...).lower()``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.perf import PerfConfig, DEFAULT_PERF
from repro.training import compression
from repro.training.optimizer import (OptConfig, adamw_update, init_opt_state,
                                      make_schedule)


def init_train_state(cfg: ModelConfig, params,
                     perf: PerfConfig = DEFAULT_PERF) -> dict:
    st = init_opt_state(params)
    if perf.grad_compress:
        st["err_fb"] = compression.init_error_feedback(params)
    return st


def _split_microbatches(batch: dict, k: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % k == 0, (b, k)
        return x.reshape(k, b // k, *x.shape[1:])
    return {key: f(v) for key, v in batch.items()}


def make_train_step(cfg: ModelConfig, perf: PerfConfig = DEFAULT_PERF,
                    opt_cfg: OptConfig = OptConfig()) -> Callable:
    sched = make_schedule(opt_cfg)

    def loss_of(params, batch):
        return M.loss_fn(cfg, params, batch, perf=perf)

    def grads_of(params, batch):
        if perf.microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads
        mb = _split_microbatches(batch, perf.microbatches)

        def acc_step(carry, micro):
            g_acc, l_acc = carry
            (loss, _), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, micro)
            g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                                 g_acc, grads)
            return (g_acc, l_acc + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (g_acc, l_acc), _ = jax.lax.scan(acc_step, (g0, 0.0), mb)
        k = float(perf.microbatches)
        grads = jax.tree.map(lambda g: g / k, g_acc)
        loss = l_acc / k
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch, step):
        loss, metrics, grads = grads_of(params, batch)
        if perf.grad_compress:
            grads, new_err = compression.quantize_with_feedback(
                grads, opt_state["err_fb"])
        lr = sched(step)
        new_params, new_opt, gnorm = adamw_update(
            grads, opt_state, params, lr, opt_cfg)
        if perf.grad_compress:
            new_opt["err_fb"] = new_err
        out_metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        if "ce" in metrics:
            out_metrics["ce"] = metrics["ce"]
        return new_params, new_opt, out_metrics

    return train_step

"""Optimizers + LR schedules (hand-rolled; no external deps).

AdamW with decoupled weight decay; schedules: linear-warmup cosine and
WSD (warmup-stable-decay — MiniCPM's schedule, required by the
minicpm-2b assignment).  Optimizer state mirrors the parameter tree
leaf-for-leaf, so it inherits the parameters' NamedShardings (with FSDP
rules this is ZeRO-style sharded optimizer state for free).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"          # cosine | wsd
    wsd_stable_frac: float = 0.8      # fraction of post-warmup steps at peak
    min_lr_frac: float = 0.1


def make_schedule(cfg: OptConfig) -> Callable:
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "cosine":
            t = jnp.clip((step - cfg.warmup_steps)
                         / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                         0.0, 1.0)
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * t))
        elif cfg.schedule == "wsd":
            stable_end = (cfg.warmup_steps
                          + cfg.wsd_stable_frac
                          * (cfg.total_steps - cfg.warmup_steps))
            t = jnp.clip((step - stable_end)
                         / jnp.maximum(cfg.total_steps - stable_end, 1),
                         0.0, 1.0)
            # MiniCPM's decay phase: exponential-ish fast anneal
            decay = cfg.min_lr_frac ** t
        else:
            raise ValueError(cfg.schedule)
        return cfg.lr * warm * decay
    return sched


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, lr, cfg: OptConfig):
    """One AdamW step.  Params stay in their storage dtype (bf16/fp32);
    moments are fp32."""
    count = opt_state["count"] + 1
    b1, b2 = cfg.betas
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** count)
        vh = v / (1 - b2 ** count)
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = treedef.unflatten([l[0] for l in leaves])
    new_m = treedef.unflatten([l[1] for l in leaves])
    new_v = treedef.unflatten([l[2] for l in leaves])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm

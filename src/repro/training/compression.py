"""Gradient compression: int8 quantization with error feedback.

Two layers:
  * ``quantize_with_feedback`` / integration in train_step — the math:
    per-leaf symmetric int8 quantization, the residual carried in an
    error-feedback buffer so compression error does not accumulate
    (convergence-safe; property-tested against fp32 training).
  * ``compressed_psum`` — the comms: an explicit ``shard_map`` all-reduce
    that moves int8 over the wire (4x fewer bytes than fp32).  Its
    lowered HLO is inspected in tests/benchmarks to confirm the
    all-reduce operand really is int8 — this is the §Perf lever for
    collective-bound training cells.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat


def quantize_leaf(g, err):
    """Symmetric int8 quantization with error feedback.  Returns
    (dequantized g_hat, new error buffer)."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    g_hat = q.astype(jnp.float32) * scale
    return g_hat.astype(g.dtype), gf - g_hat


def quantize_with_feedback(grads, err_tree):
    out = jax.tree.map(quantize_leaf, grads, err_tree)
    leaves, treedef = jax.tree.flatten(out,
                                       is_leaf=lambda x: isinstance(x, tuple))
    g_hat = treedef.unflatten([l[0] for l in leaves])
    new_err = treedef.unflatten([l[1] for l in leaves])
    return g_hat, new_err


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x, mesh, axis: str = "data"):
    """All-reduce ``x`` over ``axis`` moving int8 on the wire.

    Each shard quantizes against a pre-agreed scale (max|x| is itself
    all-reduced in fp32 — one scalar), all-gathers the int8 payload (the
    wire format — an int8 psum would overflow), and accumulates locally
    in int32.  Wire bytes: ~1 byte/elem vs ~8 bytes/elem for a ring
    fp32 all-reduce.
    """
    def body(xs):
        local_max = jnp.max(jnp.abs(xs.astype(jnp.float32)))
        gmax = jax.lax.pmax(local_max, axis)
        scale = jnp.maximum(gmax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(xs.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int8)
        gathered = jax.lax.all_gather(q, axis)            # int8 on the wire
        total = gathered.astype(jnp.int32).sum(axis=0)
        return (total.astype(jnp.float32) * scale).astype(xs.dtype)

    return compat.shard_map(body, mesh=mesh, in_specs=P(*(None,) * x.ndim),
                            out_specs=P(*(None,) * x.ndim),
                            check_rep=False)(x)

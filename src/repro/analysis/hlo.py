"""Trip-count-aware cost extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but our models
scan over layer groups (and microbatches), so naive HLO_FLOPs under-
counts by ~n_groups.  This parser:

  * builds a per-computation symbol table (op name -> result type),
  * counts dot/convolution FLOPs from shapes + contracting dims
    (recursing into fusion called-computations),
  * estimates bytes-accessed as sum(operand bytes + result bytes) over
    non-trivial ops (fusions counted at their boundary, like XLA does),
  * sums collective operand bytes by kind (all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute),
  * classifies each collective as intra-pod (ICI) or cross-pod (DCN) by
    the device-index stride of its replica groups,
  * multiplies every computation's cost by the product of enclosing
    whiles' ``known_trip_count`` (from backend_config).

Validated against ``cost_analysis()`` on scan-free graphs in tests.
"""
from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _parse_type(t: str) -> tuple[int, int]:
    """'f32[4,64]{1,0}' or tuple -> (elements, bytes). Tuples summed."""
    elems = 0
    nbytes = 0
    for m in _SHAPE_RE.finditer(t):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


def _shape_dims(t: str) -> list[int]:
    m = _SHAPE_RE.search(t)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class OpLine:
    name: str
    rtype: str
    opcode: str
    operands: list
    attrs: str


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)    # kind -> operand bytes
    coll_dcn_bytes: float = 0.0
    coll_count: int = 0


_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_NAME_RE = re.compile(r"^(%[\w.\-]+)\s*=\s*")


def _split_computations(txt: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: Optional[str] = None
    for line in txt.splitlines():
        line = _COMMENT_RE.sub("", line)
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
            if not cur.startswith("%"):
                cur = "%" + cur
            comps[cur] = []
            continue
        if cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _scan_type(s: str, i: int) -> int:
    """Return the index just past the type starting at s[i] (handles
    nested tuple types)."""
    if s[i] != "(":
        j = s.find(" ", i)
        return len(s) if j < 0 else j
    depth = 0
    for j in range(i, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(s)


def _parse_ops(lines: list[str]) -> list[OpLine]:
    ops = []
    for line in lines:
        s = line.strip()
        if s.startswith("ROOT "):
            s = s[5:]
        m = _NAME_RE.match(s)
        if not m:
            continue
        name = m.group(1)
        i = m.end()
        j = _scan_type(s, i)
        rtype = s[i:j]
        rest = s[j:].lstrip()
        k = rest.find("(")
        if k < 0:
            continue
        opcode = rest[:k].strip()
        body = rest[k + 1:]
        depth = 1
        e = 0
        for e, ch in enumerate(body):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str, attrs = body[:e], body[e + 1:]
        operands = []
        for o in operand_str.split(","):
            o = o.strip()
            # operands may be typed ("f32[4,64] %x") in some dumps
            if " " in o:
                o = o.split()[-1]
            if o.startswith("%"):
                operands.append(o)
        ops.append(OpLine(name, rtype, opcode, operands, attrs))
    return ops


def _dot_flops(op: OpLine, symtab: dict) -> float:
    out_elems, _ = _parse_type(op.rtype)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    lhs_t = symtab.get(op.operands[0]) if op.operands else None
    if lhs_t is None:
        return 0.0
    dims = _shape_dims(lhs_t)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            contract *= dims[int(d)] if int(d) < len(dims) else 1
    return 2.0 * out_elems * contract


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(r"(?:body|to_apply|calls)=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_GROUPS_RE = re.compile(
    r"replica_groups=\{(\{[\d,{} ]*\})\}|"
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")

# ops whose bytes we skip (pure metadata / layout bookkeeping)
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "copy-start", "copy-done", "after-all"}

# ops that touch only a SLICE of their big operand: counting the full
# operand would book a scan's whole stacked tensor on every iteration
# (e.g. a 4096-step sequence scan reading 131 KB/step out of a 536 MB
# stack would be charged 2.2 TB).  Count result/update bytes instead,
# matching XLA's HloCostAnalysis convention.
_SLICED_READS = {"dynamic-slice", "gather", "slice"}
_SLICED_WRITES = {"dynamic-update-slice", "scatter", "scatter-add"}


def _collective_span(op: OpLine, pod_size: int) -> bool:
    """True if any replica group spans a device-index gap >= pod_size
    (i.e. the collective crosses the pod boundary -> DCN)."""
    m = _GROUPS_RE.search(op.attrs)
    if not m:
        return False
    if m.group(1):
        for grp in re.findall(r"\{([\d, ]+)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids and (max(ids) - min(ids)) >= pod_size:
                return True
        return False
    # iota form: replica_groups=[G,S]<=[d0,d1,...]T(perm)? — reconstruct
    # the actual device ids: iota over prod(dims), reshaped to dims,
    # transposed by perm, flattened into (G, S) groups
    import numpy as np
    G, S = int(m.group(2)), int(m.group(3))
    dims = [int(x) for x in m.group(4).split(",")]
    ids = np.arange(int(np.prod(dims))).reshape(dims)
    if m.group(5):
        perm = [int(x) for x in m.group(5).split(",")]
        ids = ids.transpose(perm)
    groups = ids.reshape(G, S)
    span = (groups.max(axis=1) - groups.min(axis=1)).max() if S > 1 else 0
    return int(span) >= pod_size


class HloCost:
    def __init__(self, txt: str, *, pod_size: int = 10 ** 9):
        self.comps = _split_computations(txt)
        self.ops = {c: _parse_ops(lines) for c, lines in self.comps.items()}
        self.pod_size = pod_size
        self._memo: dict[str, CompCost] = {}
        entry = None
        m = re.search(r"^ENTRY\s+(%[\w.\-]+)", txt, re.M)
        if m:
            entry = m.group(1)
        else:  # fall back to the last computation
            entry = list(self.comps)[-1] if self.comps else None
        self.entry = entry

    # ------------------------------------------------------------- costing

    def comp_cost(self, comp: str) -> CompCost:
        if comp in self._memo:
            return self._memo[comp]
        total = CompCost()
        self._memo[comp] = total            # break cycles defensively
        symtab = {op.name: op.rtype for op in self.ops.get(comp, [])}
        for op in self.ops.get(comp, []):
            if op.opcode == "while":
                n = 1
                tm = _TRIP_RE.search(op.attrs)
                if tm:
                    n = int(tm.group(1))
                body = _CALLED_RE.search(op.attrs)
                if body:
                    sub = self.comp_cost(body.group(1))
                    _accumulate(total, sub, n)
                continue
            if op.opcode in ("fusion", "call", "conditional", "custom-call",
                             "reduce", "sort", "scatter", "map"):
                # count inner dot flops of called computations once
                for cm in _CALLED_RE.finditer(op.attrs):
                    sub = self.comp_cost(cm.group(1))
                    total.flops += sub.flops
                    _merge_coll(total, sub, 1)
            if op.opcode == "dot":
                total.flops += _dot_flops(op, symtab)
            elif op.opcode == "convolution":
                # rough: 2 * out_elems * (kernel elems per output)
                out_elems, _ = _parse_type(op.rtype)
                k_elems = (_parse_type(symtab.get(op.operands[1], ""))[0]
                           if len(op.operands) > 1 else 0)
                total.flops += 2.0 * out_elems * max(k_elems, 1) ** 0.5
            if op.opcode in COLLECTIVES:
                ob = sum(_parse_type(symtab.get(o, ""))[1]
                         for o in op.operands)
                total.coll_bytes[op.opcode] = (
                    total.coll_bytes.get(op.opcode, 0.0) + ob)
                total.coll_count += 1
                if _collective_span(op, self.pod_size):
                    total.coll_dcn_bytes += ob
            if op.opcode in _SLICED_READS:
                _, rb = _parse_type(op.rtype)
                total.bytes += 2 * rb          # read slice + write result
            elif op.opcode in _SLICED_WRITES:
                # update bytes in + out (operand 1 is the update for dus;
                # conservatively use the smallest non-index operand)
                upd = min((_parse_type(symtab.get(o, ""))[1]
                           for o in op.operands[1:] or op.operands),
                          default=0)
                total.bytes += 2 * upd
            elif op.opcode not in _SKIP_BYTES:
                _, rb = _parse_type(op.rtype)
                opb = sum(_parse_type(symtab.get(o, ""))[1]
                          for o in op.operands)
                total.bytes += rb + opb
        return total

    def total(self) -> CompCost:
        if self.entry is None:
            return CompCost()
        return self.comp_cost(self.entry)


def _accumulate(total: CompCost, sub: CompCost, n: int) -> None:
    total.flops += sub.flops * n
    total.bytes += sub.bytes * n
    _merge_coll(total, sub, n)


def _merge_coll(total: CompCost, sub: CompCost, n: int) -> None:
    for k, v in sub.coll_bytes.items():
        total.coll_bytes[k] = total.coll_bytes.get(k, 0.0) + v * n
    total.coll_dcn_bytes += sub.coll_dcn_bytes * n
    total.coll_count += sub.coll_count * n


def analyze(txt: str, *, pod_size: int = 10 ** 9) -> dict:
    """Parse optimized HLO text -> trip-count-corrected per-device costs."""
    hc = HloCost(txt, pod_size=pod_size)
    t = hc.total()
    return {
        "flops": t.flops,
        "bytes": t.bytes,
        "coll_bytes": dict(t.coll_bytes),
        "coll_bytes_total": float(sum(t.coll_bytes.values())),
        "coll_dcn_bytes": t.coll_dcn_bytes,
        "coll_count": t.coll_count,
    }

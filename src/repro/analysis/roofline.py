"""Three-term roofline from the dry-run's compiled artifact.

    compute    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory     = HLO_bytes / HBM_bw               (per chip)
    collective = ICI_bytes / ICI_bw + DCN_bytes / DCN_bw

All inputs are per-device (post-SPMD partitioning), trip-count-corrected
by analysis/hlo.py.  MODEL_FLOPS is the analytic useful compute:
  train   : 6 * N * D        (N = params, active-only for MoE; D = tokens)
  prefill : 2 * N * D
  decode  : 2 * N * B        (one token per slot)
The ratio MODEL_FLOPS / (HLO_FLOPs * chips) exposes remat/dispatch waste.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HW


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch        # decode: 1 token/slot


def roofline_from_costs(cfg: ModelConfig, shape: ShapeConfig, parsed: dict,
                        *, n_chips: int) -> dict:
    flops = parsed["flops"]                 # per device
    byts = parsed["bytes"]
    coll_total = parsed["coll_bytes_total"]
    dcn = parsed.get("coll_dcn_bytes", 0.0)
    ici = max(coll_total - dcn, 0.0)
    compute_s = flops / HW["flops_bf16"]
    memory_s = byts / HW["hbm_bw"]
    collective_s = ici / HW["ici_bw"] + dcn / HW["dcn_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = flops * n_chips
    step_s = max(compute_s, memory_s, collective_s)
    ideal_s = mf / (n_chips * HW["flops_bf16"])
    return {
        **{k: float(v) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flop_ratio": (mf / hlo_global) if hlo_global else 0.0,
        # fraction of the compute roofline this step achieves if the
        # dominant term is the critical path (no overlap assumed)
        "roofline_fraction": (ideal_s / step_s) if step_s else 0.0,
        "step_time_bound_s": step_s,
    }


def enforcement_roofline(n_domains: int = 64, batch: int = 32) -> dict:
    """Roofline the fused Pallas enforcement kernel against the lax
    scan reference at the same shape: compile both, read the XLA cost
    model (flops / bytes accessed), and bound each with the HW table.

    Both paths are compiled explicitly (``_lax_charge_batch`` vs
    ``kernels.enforcement.fused_charge_batch``) so the numbers do not
    depend on the runtime dispatch seam.  Off-TPU the fused kernel
    compiles in interpret mode — its cost numbers then describe the
    traced jax ops, which is still the apples-to-apples comparison the
    gate in ``benchmarks/engine_overhead.py`` wall-clocks.  The hot
    path is control-state sized (KBs, not GBs): both columns sit far
    under the memory roofline, and the win the fused pass buys is
    fewer HBM round-trips per request slot (``bytes_ratio``).
    """
    import jax
    import jax.numpy as jnp

    from repro import compat
    from repro.core import controller as C
    from repro.core.cgroup import AgentCgroup, DeviceTableBackend, DomainSpec
    from repro.core.progs import GraduatedThrottleProgram, TokenBucketProgram
    from repro.kernels.enforcement import fused_charge_batch

    cg = AgentCgroup(DeviceTableBackend(1 << 20, n_domains=n_domains))
    cg.attach("/", GraduatedThrottleProgram())
    cg.mkdir("/grad", DomainSpec(high=1000))
    cg.mkdir("/bkt")
    cg.attach("/bkt", TokenBucketProgram(bucket_capacity=64,
                                         refill=(1.0, 1.0, 1.0)))
    progs = cg.programs
    view = cg.device_view()
    dom = jnp.array([cg.handle("/grad"), cg.handle("/bkt")]
                    * (batch // 2) + [cg.handle("/grad")] * (batch % 2),
                    jnp.int32)
    amt = jnp.ones((batch,), jnp.int32)

    def lax_fn(st, d, a):
        return C._lax_charge_batch(st, d, a, 0, progs)

    def fused_fn(st, d, a):
        return fused_charge_batch(st, d, a, 0, progs)

    out: dict = {"n_domains": n_domains, "batch": batch,
                 "n_programs": len(progs), "on_tpu": compat.on_tpu()}
    for name, fn in (("lax", lax_fn), ("fused", fused_fn)):
        compiled = jax.jit(fn).lower(view.state, dom, amt).compile()
        ca = compat.cost_analysis(compiled)
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        out[name] = {"flops": flops, "bytes": byts,
                     "compute_s": flops / HW["flops_bf16"],
                     "memory_s": byts / HW["hbm_bw"]}
    if out["lax"]["bytes"] and out["fused"]["bytes"]:
        out["bytes_ratio"] = out["fused"]["bytes"] / out["lax"]["bytes"]
    return out


def fmt_seconds(s: float) -> str:
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"

"""Reporters: human text and machine JSON.

The JSON shape is stable (schema-checked in ``tests/test_lint.py``)
because CI uploads it as an artifact and downstream tooling may parse
it: ``{"version": 1, "findings": [...], "counts": {rule: n}, "total": N}``.
"""
from __future__ import annotations

import json
from collections import Counter
from typing import Sequence

from repro.analysis.lint.core import Finding

REPORT_VERSION = 1


def render_text(findings: Sequence[Finding], *,
                suppressed_by_baseline: int = 0) -> str:
    lines = [f.format() for f in findings]
    counts = Counter(f.rule for f in findings)
    if findings:
        summary = ", ".join(f"{rule}: {n}"
                            for rule, n in sorted(counts.items()))
        lines.append("")
        lines.append(f"{len(findings)} finding"
                     f"{'s' if len(findings) != 1 else ''} ({summary})")
    else:
        lines.append("no findings")
    if suppressed_by_baseline:
        lines.append(f"({suppressed_by_baseline} grandfathered by baseline)")
    return "\n".join(lines)


def render_json(findings: Sequence[Finding], *,
                suppressed_by_baseline: int = 0) -> str:
    counts = Counter(f.rule for f in findings)
    payload = {
        "version": REPORT_VERSION,
        "findings": [f.to_dict() for f in findings],
        "counts": dict(sorted(counts.items())),
        "total": len(findings),
        "suppressed_by_baseline": suppressed_by_baseline,
    }
    return json.dumps(payload, indent=2)

"""tracelint framework: findings, suppressions, rule base, the runner.

Pure stdlib (``ast`` + ``tokenize``) on purpose: the linter must run in
a bare CI container and in pre-commit hooks without importing jax or
the package under analysis — like the kernel verifier, it reads the
program text, it never executes it.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Optional, Sequence

# `# tracelint: disable=TL001,TL003 -- justification`
# `# tracelint: disable-file=TL003 -- justification`
PRAGMA_RE = re.compile(
    r"#\s*tracelint:\s*(disable(?:-file)?)\s*=\s*"
    r"([A-Za-z0-9_,\s]+?)(?:\s*(?:--|—|:)\s*(.*))?$")

# modules whose decision code must stay suppression-free: these are the
# one-decision-path files every substrate traces (acceptance invariant)
DECISION_MODULES = ("core/progs.py", "core/sched.py", "core/controller.py",
                    "core/pressure.py", "kernels/enforcement.py")

META_RULE = "TL000"          # framework findings about suppressions


class LintError(Exception):
    """The linter itself could not proceed (bad path, bad baseline)."""


@dataclass(frozen=True)
class Finding:
    """One rule violation, anchored to file:line:col."""
    rule: str
    path: str                # posix, as scanned (relative to the cwd)
    line: int
    col: int
    message: str

    @property
    def fingerprint(self) -> str:
        """Line-independent identity used by the baseline: a finding
        survives unrelated edits shifting it up or down the file."""
        return f"{self.path}::{self.rule}::{self.message}"

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


@dataclass(frozen=True)
class Suppression:
    line: int                # line the pragma sits on
    rules: frozenset
    file_level: bool
    own_line: bool           # comment-only line: applies to the next line
    justification: str

    def covers(self, f: Finding) -> bool:
        if f.rule == META_RULE or f.rule not in self.rules:
            return False
        if self.file_level:
            return True
        if f.line == self.line:
            return True
        return self.own_line and f.line == self.line + 1


class FileContext:
    """One parsed source file: AST + suppressions + finding factory."""

    def __init__(self, path: str, source: str):
        self.path = Path(path).as_posix()
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=self.path)
        self.suppressions = _parse_suppressions(source)

    # ---------------------------------------------------------- scoping

    @property
    def segments(self) -> tuple:
        return tuple(Path(self.path).parts)

    def in_dirs(self, names: Iterable[str]) -> bool:
        return any(n in self.segments for n in names)

    def endswith(self, suffixes: Iterable[str]) -> bool:
        return any(self.path.endswith(s) for s in suffixes)

    @property
    def is_decision_module(self) -> bool:
        return self.endswith(DECISION_MODULES)

    # --------------------------------------------------------- findings

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        return Finding(rule, self.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0), message)


def _parse_suppressions(source: str) -> list:
    out = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.start[1], t.string)
                    for t in tokens if t.type == tokenize.COMMENT]
    except tokenize.TokenizeError:
        return out
    lines = source.splitlines()
    for line, col, text in comments:
        m = PRAGMA_RE.match(text)
        if not m:
            continue
        kind, rule_list, justification = m.groups()
        rules = frozenset(r.strip().upper()
                          for r in rule_list.split(",") if r.strip())
        own = lines[line - 1][:col].strip() == ""
        out.append(Suppression(line=line, rules=rules,
                               file_level=(kind == "disable-file"),
                               own_line=own,
                               justification=(justification or "").strip()))
    return out


class Rule:
    """One invariant.  Subclasses set ``id``/``name``/``description``
    and implement ``check`` (per file) or, with ``project_wide=True``,
    ``check_project`` (once, over every scanned file — for cross-file
    invariants like protocol drift)."""

    id: str = "TL000"
    name: str = ""
    description: str = ""
    project_wide: bool = False

    def applies(self, ctx: FileContext) -> bool:
        return True

    def check(self, ctx: FileContext) -> list:
        return []

    def check_project(self, ctxs: Sequence[FileContext]) -> list:
        return []


# ------------------------------------------------------------------ runner


def _suppression_policy(ctx: FileContext, known_rules: set) -> list:
    """The pragmas themselves are checked: decision-path modules admit
    no suppressions at all (the acceptance invariant), and every pragma
    must carry a justification — an audit trail, like a verifier
    override that must name its reviewer."""
    out = []
    for s in ctx.suppressions:
        if ctx.is_decision_module:
            out.append(Finding(
                META_RULE, ctx.path, s.line, 0,
                "suppression pragma in decision-path module "
                "(core/progs.py, core/sched.py and core/controller.py "
                "must lint clean with zero suppressions)"))
        if not s.justification:
            out.append(Finding(
                META_RULE, ctx.path, s.line, 0,
                "suppression without justification (write "
                "'# tracelint: disable=TLxxx -- why it is safe')"))
        unknown = sorted(r for r in s.rules if r not in known_rules)
        if unknown:
            out.append(Finding(
                META_RULE, ctx.path, s.line, 0,
                f"suppression names unknown rule(s): {', '.join(unknown)}"))
    return out


def iter_py_files(paths: Iterable[str]) -> list:
    files = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
        else:
            raise LintError(f"not a python file or directory: {p}")
    return files


def lint_sources(sources: dict, rules: Optional[Sequence[Rule]] = None,
                 ) -> list:
    """Lint in-memory ``{path: source}`` pairs (the test harness entry
    point; ``lint_paths`` is the filesystem wrapper)."""
    from repro.analysis.lint.rules import ALL_RULES
    rules = list(ALL_RULES) if rules is None else list(rules)
    known = {r.id for r in rules} | {META_RULE}
    ctxs, findings = [], []
    for path, src in sorted(sources.items()):
        try:
            ctxs.append(FileContext(path, src))
        except SyntaxError as e:
            findings.append(Finding(META_RULE, Path(path).as_posix(),
                                    e.lineno or 1, e.offset or 0,
                                    f"syntax error: {e.msg}"))
    for rule in rules:
        if rule.project_wide:
            findings.extend(rule.check_project(ctxs))
        else:
            for ctx in ctxs:
                if rule.applies(ctx):
                    findings.extend(rule.check(ctx))
    for ctx in ctxs:
        findings.extend(_suppression_policy(ctx, known))
    # apply pragma suppressions (never to TL000 — the policy above IS
    # the check on the pragmas)
    by_path = {c.path: c.suppressions for c in ctxs}
    kept = [f for f in findings
            if not any(s.covers(f) for s in by_path.get(f.path, ()))]
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return kept


def lint_paths(paths: Iterable[str],
               rules: Optional[Sequence[Rule]] = None) -> list:
    files = iter_py_files(paths)
    sources = {}
    for f in files:
        try:
            sources[str(f)] = f.read_text(encoding="utf-8")
        except (OSError, UnicodeDecodeError) as e:
            raise LintError(f"cannot read {f}: {e}") from e
    return lint_sources(sources, rules)


# ------------------------------------------------------------ AST helpers


def qualname(node: ast.AST) -> Optional[str]:
    """Dotted name of a Name/Attribute chain ('np.random.default_rng'),
    None for anything dynamic."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def is_static_test(t: ast.AST) -> bool:
    """Tests that cannot involve traced values: identity checks
    (``x is None``), ``isinstance``/``hasattr``/``callable`` dispatch,
    constants, and boolean combinations thereof.  Everything else in a
    traced scope is assumed reachable by a tracer."""
    if isinstance(t, ast.Compare):
        return all(isinstance(op, (ast.Is, ast.IsNot)) for op in t.ops)
    if isinstance(t, ast.Call):
        return qualname(t.func) in ("isinstance", "hasattr", "callable",
                                    "issubclass")
    if isinstance(t, ast.BoolOp):
        return all(is_static_test(v) for v in t.values)
    if isinstance(t, ast.UnaryOp) and isinstance(t.op, ast.Not):
        return is_static_test(t.operand)
    return isinstance(t, ast.Constant)

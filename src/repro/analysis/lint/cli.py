"""tracelint CLI.

    python -m repro.analysis.lint [paths...] [--json] [--baseline FILE]
                                  [--write-baseline FILE] [--select IDS]
                                  [--list-rules]

Exit codes: 0 clean, 1 findings, 2 usage / IO error.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.analysis.lint.baseline import (apply_baseline, load_baseline,
                                          write_baseline)
from repro.analysis.lint.core import LintError, lint_paths
from repro.analysis.lint.report import render_json, render_text
from repro.analysis.lint.rules import ALL_RULES, rules_by_id


def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="verifier-style invariant linter for the control plane")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to lint (default: src)")
    p.add_argument("--json", action="store_true",
                   help="emit the JSON report instead of text")
    p.add_argument("--baseline", metavar="FILE",
                   help="suppress findings fingerprinted in FILE; "
                        "only new findings fail")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="write current findings to FILE as the new "
                        "baseline and exit 0")
    p.add_argument("--select", metavar="IDS",
                   help="comma-separated rule ids to run (e.g. TL001,TL003)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = _build_parser().parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.id}  {r.name:<20} {r.description}")
        return 0

    rules = None
    if args.select:
        by_id = rules_by_id()
        wanted = [s.strip().upper() for s in args.select.split(",")
                  if s.strip()]
        unknown = [w for w in wanted if w not in by_id]
        if unknown:
            print(f"unknown rule id(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2
        rules = [by_id[w] for w in wanted]

    try:
        findings = lint_paths(args.paths or ["src"], rules)
    except LintError as e:
        print(f"tracelint: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(args.write_baseline, findings)
        print(f"wrote {len(findings)} entr"
              f"{'ies' if len(findings) != 1 else 'y'} to "
              f"{args.write_baseline}")
        return 0

    grandfathered = 0
    if args.baseline:
        try:
            fps = load_baseline(args.baseline)
        except LintError as e:
            print(f"tracelint: {e}", file=sys.stderr)
            return 2
        findings, grandfathered = apply_baseline(findings, fps)

    render = render_json if args.json else render_text
    print(render(findings, suppressed_by_baseline=grandfathered))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())

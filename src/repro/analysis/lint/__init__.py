"""tracelint — a verifier-style invariant linter for the control plane.

The paper's in-kernel enforcement story only works because the kernel
*verifier* statically rejects unsafe eBPF programs before they load:
the one decision path that runs at the memcg charge point is proven
safe ahead of time, not discovered unsafe at runtime.  This repo's
analogue of that guarantee is a set of load-bearing invariants that
until now were only enforced dynamically (conformance parity suites,
hypothesis fuzzing) — late and probabilistically:

  * one decision path: host replay, the jitted engine, and the sharded
    ``shard_map`` kernels all trace the same ``charge_decision`` /
    ``schedule_decision`` (no python control flow forking the trace);
  * zero-retrace retunes: live parameter writes must not bake python
    scalars into jit caches;
  * bit-stable replay: nothing on the record/replay path may read
    wall clocks or unseeded entropy;
  * lock discipline: async-daemon readers only observe whole epochs;
  * protocol stability: every backend speaks the exact ``Backend``
    vocabulary;
  * pytree-structure stability: control-state dicts never grow keys
    conditionally (a structure change is a silent retrace).

``tracelint`` is the static pass that checks them: pure-stdlib AST
analysis (no jax import — it runs anywhere), per-rule ``Finding``s
with file:line, ``# tracelint: disable=<rule> -- why`` suppressions,
text/JSON reporters, and a checked-in baseline for grandfathered
findings.  Run it as::

    python -m repro.analysis.lint src --baseline tracelint-baseline.json

Rules
-----
TL001  trace-purity       python control flow / host casts / numpy in
                          traced decision scopes
TL002  retrace-hazard     python scalars closed over inside jitted
                          callables (jit-cache explosion)
TL003  replay-determinism wall clocks & unseeded entropy in
                          core/ traces/ testing/
TL004  lock-discipline    inner-backend access outside the apply lock
TL005  protocol-drift     backend classes vs the ``Backend`` protocol
TL006  pytree-stability   conditionally-created control-state dict keys
"""
from repro.analysis.lint.baseline import (apply_baseline, load_baseline,
                                          write_baseline)
from repro.analysis.lint.core import (Finding, LintError, Rule, lint_paths,
                                      lint_sources)
from repro.analysis.lint.report import render_json, render_text
from repro.analysis.lint.rules import ALL_RULES, rules_by_id

__all__ = [
    "Finding", "LintError", "Rule", "lint_paths", "lint_sources",
    "ALL_RULES", "rules_by_id", "load_baseline", "write_baseline",
    "apply_baseline", "render_text", "render_json",
]

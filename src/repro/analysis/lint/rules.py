"""The tracelint rules: the repo's load-bearing invariants, statically.

Each rule encodes an invariant the conformance kit certifies
dynamically (tests/test_cgroup.py parity, hypothesis fuzz) — here it is
checked the way the kernel verifier checks an eBPF program: from the
text alone, before anything runs.  See the package docstring for the
rule table and ``tests/test_lint.py`` for one seeded-violation /
clean-twin fixture pair per rule.
"""
from __future__ import annotations

import ast
import builtins
from typing import Optional

from repro.analysis.lint.core import (FileContext, Finding, Rule,
                                      is_static_test, qualname)

_BUILTINS = frozenset(dir(builtins))

# program classes: the memcg_bpf_ops analogues whose hooks are traced
# by every backend (core/progs.py) — subclasses anywhere inherit the
# trace-purity obligation
PROGRAM_BASES = frozenset({
    "PolicyProgram", "GraduatedThrottleProgram", "TokenBucketProgram",
    "WeightedFairProgram",
})
TRACED_HOOKS = frozenset({"on_charge", "on_over_high", "on_gate",
                          "on_schedule"})
# module-level decision entry points in the decision-path modules —
# the functions all six backend kinds trace verbatim.  The fused Pallas
# kernel bodies and wrappers (kernels/enforcement.py) are included: the
# kernel glue traces the same decision code and carries the same
# purity obligation.  Python-time registry dispatch helpers
# (``_single_prog``, the branch factories) are deliberately NOT roots —
# their length checks run at trace time, never on traced values.
TRACED_FUNCS = frozenset({
    "charge_decision", "schedule_decision", "charge_batch", "slot_gate",
    "uncharge_batch", "_chain_view", "_ancestor_chain",
    "charge_stall_event", "sched_stall_events",
    "_decision_one", "gate_decision", "schedule_weight",
    "saturating_count",
    "fused_charge_batch", "fused_slot_gate",
    "_lax_charge_batch", "_lax_slot_gate",
    "_charge_kernel", "_gate_kernel", "_view_state",
})


def _is_program_class(node: ast.ClassDef) -> bool:
    if node.name in PROGRAM_BASES:
        return True
    for base in node.bases:
        q = qualname(base)
        if q is not None and q.split(".")[-1] in PROGRAM_BASES:
            return True
    return any(isinstance(m, ast.FunctionDef) and m.name in TRACED_HOOKS
               for m in node.body)


class TracePurity(Rule):
    """TL001: no python control flow, host casts, numpy, or host syncs
    inside traced decision scopes.  A python ``if`` on a traced value
    does not error — it silently *forks the trace* on the tracer's
    boolean, and host replay / jitted engine / shard_map stop running
    the same decision path.  The eBPF verifier rejects unverifiable
    branches for the same reason."""

    id = "TL001"
    name = "trace-purity"
    description = ("python if/while/assert, .item()/float()/int() casts, "
                   "np.* calls and host syncs in traced decision scopes")

    CASTS = frozenset({"float", "int", "bool", "complex"})
    HOST_SYNCS = frozenset({"block_until_ready", "device_get"})

    def applies(self, ctx: FileContext) -> bool:
        return ctx.is_decision_module or any(
            isinstance(n, ast.ClassDef) and _is_program_class(n)
            for n in ast.walk(ctx.tree))

    # ------------------------------------------------------ traced scopes

    def _traced_roots(self, ctx: FileContext) -> list:
        roots = []
        for node in ctx.tree.body:
            if (isinstance(node, ast.FunctionDef)
                    and ctx.is_decision_module
                    and node.name in TRACED_FUNCS):
                roots.append(node)
            elif isinstance(node, ast.ClassDef) and _is_program_class(node):
                for m in node.body:
                    if (isinstance(m, ast.FunctionDef)
                            and m.name in (TRACED_HOOKS | {"delay_ms"})):
                        roots.append(m)
        return roots

    def check(self, ctx: FileContext) -> list:
        out = []
        for root in self._traced_roots(ctx):
            scope = (f"{root.name}" if isinstance(root, ast.FunctionDef)
                     else "<traced>")
            for node in ast.walk(root):
                out.extend(self._check_node(ctx, node, scope))
        if ctx.is_decision_module:
            # host syncs are module-wide poison in decision modules:
            # even outside a traced scope they mean the decision path
            # depends on a device round trip
            for node in ast.walk(ctx.tree):
                if (isinstance(node, ast.Attribute)
                        and node.attr in self.HOST_SYNCS):
                    out.append(ctx.finding(
                        self.id, node,
                        f"host sync '{node.attr}' in decision-path module"))
        return out

    def _check_node(self, ctx, node, scope) -> list:
        out = []
        if isinstance(node, (ast.If, ast.While)):
            if not is_static_test(node.test):
                kw = "if" if isinstance(node, ast.If) else "while"
                out.append(ctx.finding(
                    self.id, node,
                    f"python '{kw}' on a potentially-traced value in "
                    f"traced scope '{scope}' (use jnp.where/lax.cond — "
                    "a python branch forks the one decision path)"))
        elif isinstance(node, ast.IfExp):
            if not is_static_test(node.test):
                out.append(ctx.finding(
                    self.id, node,
                    f"python conditional expression in traced scope "
                    f"'{scope}' (use jnp.where)"))
        elif isinstance(node, ast.Assert):
            out.append(ctx.finding(
                self.id, node,
                f"python 'assert' in traced scope '{scope}' (asserts on "
                "traced values sync or silently vanish under jit; use "
                "checkify or move the check host-side)"))
        elif isinstance(node, ast.Call):
            q = qualname(node.func)
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "item"):
                out.append(ctx.finding(
                    self.id, node,
                    f".item() host sync in traced scope '{scope}'"))
            elif (q in self.CASTS
                  and node.args
                  and not all(isinstance(a, ast.Constant)
                              for a in node.args)):
                out.append(ctx.finding(
                    self.id, node,
                    f"{q}() cast in traced scope '{scope}' forces a host "
                    "sync on traced values (use jnp dtypes/astype)"))
            elif q is not None and q.split(".")[0] in ("np", "numpy"):
                out.append(ctx.finding(
                    self.id, node,
                    f"numpy call '{q}' in traced scope '{scope}' "
                    "(silently syncs traced arrays to host; use jnp)"))
            elif (isinstance(node.func, ast.Attribute)
                  and node.func.attr in self.HOST_SYNCS):
                out.append(ctx.finding(
                    self.id, node,
                    f"host sync '{node.func.attr}' in traced scope "
                    f"'{scope}'"))
        return out


class RetraceHazards(Rule):
    """TL002: python scalars closed over inside jitted callables.  A
    closed-over ``float(cfg.x)`` is baked into the trace as a constant:
    every new value is a new jit cache entry (cache explosion) and a
    'retune' that should be a param-table write silently recompiles —
    breaking the zero-retrace contract ``update_params`` promises.
    Retunable values belong in the program param table (state), not the
    closure."""

    id = "TL002"
    name = "retrace-hazard"
    description = ("non-param-table python scalars (or loop variables) "
                   "closed over inside jit-compiled callables")

    JIT_NAMES = frozenset({"jax.jit", "jit"})

    def applies(self, ctx: FileContext) -> bool:
        return ctx.in_dirs(("core",)) or ctx.is_decision_module

    def check(self, ctx: FileContext) -> list:
        out = []
        self._walk(ctx, ctx.tree, [], out)
        return out

    def _walk(self, ctx, node, stack, out) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.Call) and \
                    qualname(child.func) in self.JIT_NAMES and stack:
                self._check_jit_call(ctx, child, stack, out)
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)):
                self._walk(ctx, child, stack + [child], out)
            else:
                self._walk(ctx, child, stack, out)

    def _check_jit_call(self, ctx, call, stack, out) -> None:
        if not call.args:
            return
        target = call.args[0]
        fn = None
        if isinstance(target, ast.Lambda):
            fn = target
        elif isinstance(target, ast.Name):
            # a local def referenced by name; module-level defs have no
            # enclosing python frame to close over
            for scope in reversed(stack):
                for n in ast.walk(scope):
                    if (isinstance(n, ast.FunctionDef)
                            and n.name == target.id):
                        fn = n
                        break
                if fn is not None:
                    break
        if fn is None:
            return
        for name in sorted(_free_names(fn)):
            verdict = _closure_binding_hazard(name, stack)
            if verdict is not None:
                out.append(ctx.finding(
                    self.id, call,
                    f"jitted callable closes over '{name}' ({verdict}); "
                    "pass it as an argument or move it into the program "
                    "param table so retunes stay zero-retrace"))


def _free_names(fn) -> set:
    """Names loaded in ``fn`` but bound neither locally nor as params
    (builtins excluded) — the closure surface."""
    bound, loads = set(), set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        bound.add(a.arg)
    for a in (args.vararg, args.kwarg):
        if a is not None:
            bound.add(a.arg)
    for n in ast.walk(fn):
        if isinstance(n, ast.Name):
            (bound if isinstance(n.ctx, (ast.Store, ast.Del))
             else loads).add(n.id)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            if n is not fn:
                bound.add(n.name)
        elif isinstance(n, ast.Lambda) and n is not fn:
            la = n.args
            for a in (la.posonlyargs + la.args + la.kwonlyargs):
                bound.add(a.arg)
        elif isinstance(n, (ast.Import, ast.ImportFrom)):
            for alias in n.names:
                bound.add((alias.asname or alias.name).split(".")[0])
    return loads - bound - _BUILTINS


def _scalar_like(node) -> bool:
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float, bool, complex))
    if isinstance(node, ast.Call):
        return qualname(node.func) in ("int", "float", "bool", "len")
    if isinstance(node, ast.BinOp):
        return _scalar_like(node.left) or _scalar_like(node.right)
    if isinstance(node, ast.UnaryOp):
        return _scalar_like(node.operand)
    return False


def _closure_binding_hazard(name, stack) -> Optional[str]:
    """How ``name`` is bound in the enclosing function scopes, innermost
    first; returns a hazard description or None when the binding looks
    safe (an object reference like ``prog = self.prog``, whose identity
    IS the compiled code) or is module-global."""
    for scope in reversed(stack):
        if isinstance(scope, ast.Lambda):
            continue
        for n in ast.walk(scope):
            if isinstance(n, ast.For):
                targets = [t.id for t in ast.walk(n.target)
                           if isinstance(t, ast.Name)]
                if name in targets:
                    return ("bound as a loop variable — one jit cache "
                            "entry per iteration")
            elif isinstance(n, ast.Assign):
                targets = [t.id for t in n.targets
                           if isinstance(t, ast.Name)]
                if name in targets and _scalar_like(n.value):
                    return "a python scalar baked in as a trace constant"
            elif isinstance(n, ast.AnnAssign):
                if (isinstance(n.target, ast.Name) and n.target.id == name
                        and n.value is not None
                        and _scalar_like(n.value)):
                    return "a python scalar baked in as a trace constant"
    return None


class ReplayDeterminism(Rule):
    """TL003: no wall clocks or unseeded entropy on the record/replay
    path.  ``fig8_replay`` has been bit-identical since PR 2 — one
    ``time.time()`` stamped into a state record breaks snapshot
    stability and replay equality probabilistically, which no parity
    test catches until it flakes.  ``time.monotonic``/``time.sleep``
    stay legal: they shape wall-clock behaviour (timeouts, injected
    delays), never recorded state.

    The ``launch``/``benchmarks`` allowlist is for *measurement*, not a
    license for wall clocks in recorded state: benchmark timing code
    must still use ``time.perf_counter()`` (monotonic, highest
    resolution) rather than ``time.time()``, which steps under NTP slew
    and makes latency numbers irreproducible."""

    id = "TL003"
    name = "replay-determinism"
    description = ("time.time/datetime.now/os.urandom/stdlib random/"
                   "unseeded np.random in core/, traces/, testing/")

    SCOPE_DIRS = ("core", "traces", "testing")
    ALLOW_DIRS = ("launch", "benchmarks")
    DATETIME_FNS = frozenset({"now", "utcnow", "today"})
    NP_RANDOM_OK = frozenset({"default_rng", "SeedSequence", "Generator",
                              "PCG64", "Philox"})

    def applies(self, ctx: FileContext) -> bool:
        return (ctx.in_dirs(self.SCOPE_DIRS)
                and not ctx.in_dirs(self.ALLOW_DIRS))

    def check(self, ctx: FileContext) -> list:
        out = []
        # `from time import time` / `from random import ...` defeat the
        # attribute checks below — ban the import form itself
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.ImportFrom):
                if node.module == "time" and any(
                        a.name == "time" for a in node.names):
                    out.append(ctx.finding(
                        self.id, node,
                        "'from time import time' — wall clock on the "
                        "replay path (use the facade/step clock)"))
                if node.module == "random":
                    out.append(ctx.finding(
                        self.id, node,
                        "'from random import ...' — unseeded global RNG "
                        "on the replay path (use np.random.default_rng"
                        "(seed))"))
            q = qualname(node) if isinstance(node, ast.Attribute) else None
            if q == "time.time":
                out.append(ctx.finding(
                    self.id, node,
                    "time.time() — wall clock stamped on the replay path "
                    "(use the facade/step clock passed by the caller)"))
            elif q in ("os.urandom",):
                out.append(ctx.finding(
                    self.id, node,
                    "os.urandom — entropy on the replay path"))
            elif (q is not None and q.startswith("datetime.")
                  and q.split(".")[-1] in self.DATETIME_FNS):
                out.append(ctx.finding(
                    self.id, node,
                    f"{q}() — wall clock on the replay path"))
            elif (q is not None and q.startswith("random.")
                  and q.count(".") == 1):
                fn = q.split(".")[-1]
                if fn != "Random":
                    out.append(ctx.finding(
                        self.id, node,
                        f"stdlib {q} — process-global RNG on the replay "
                        "path (use np.random.default_rng(seed))"))
            if isinstance(node, ast.Call):
                fq = qualname(node.func)
                if fq in ("np.random.default_rng",
                          "numpy.random.default_rng"):
                    if not node.args and not node.keywords:
                        out.append(ctx.finding(
                            self.id, node,
                            "np.random.default_rng() without a seed — "
                            "entropy on the replay path"))
                elif (fq is not None
                      and (fq.startswith("np.random.")
                           or fq.startswith("numpy.random."))
                      and fq.split(".")[-1] not in self.NP_RANDOM_OK):
                    out.append(ctx.finding(
                        self.id, node,
                        f"legacy global-state '{fq}' on the replay path "
                        "(use a seeded np.random.default_rng)"))
        return out


class LockDiscipline(Rule):
    """TL004: inner-backend access outside the apply lock.  The async
    daemon's correctness argument is 'readers observe whole epochs':
    every ``self.inner`` touch outside ``with self._apply_lock`` (or a
    callable run under it via ``_observe``) can see a batch
    mid-application — the race the epoch tag exists to prevent."""

    id = "TL004"
    name = "lock-discipline"
    description = ("inner-backend attribute access outside a "
                   "'with self._apply_lock' block (async daemon classes)")

    MODULES = ("core/daemon.py", "core/faults.py")
    INNER_NAMES = ("inner", "_inner")
    EXEMPT_METHODS = frozenset({"__init__", "_observe"})

    def applies(self, ctx: FileContext) -> bool:
        return ctx.endswith(self.MODULES)

    def check(self, ctx: FileContext) -> list:
        out = []
        for node in ctx.tree.body:
            if isinstance(node, ast.ClassDef):
                out.extend(self._check_class(ctx, node))
        return out

    def _check_class(self, ctx, cls) -> list:
        init = next((m for m in cls.body if isinstance(m, ast.FunctionDef)
                     and m.name == "__init__"), None)
        if init is None:
            return []
        assigned = {n.attr for n in ast.walk(init)
                    if isinstance(n, ast.Attribute)
                    and isinstance(n.ctx, ast.Store)
                    and isinstance(n.value, ast.Name)
                    and n.value.id == "self"}
        # lock discipline only binds classes that HAVE the lock: a
        # synchronous single-writer wrapper (FaultyBackend) has no
        # epochs to protect
        if "_apply_lock" not in assigned:
            return []
        inner = next((n for n in self.INNER_NAMES if n in assigned), None)
        if inner is None:
            return []
        out = []
        for m in cls.body:
            if (isinstance(m, ast.FunctionDef)
                    and m.name not in self.EXEMPT_METHODS):
                out.extend(self._check_method(ctx, m, inner))
        return out

    def _observe_callables(self, method) -> set:
        """Callables executed under the lock by ``self._observe``:
        lambda/def arguments plus local defs passed by name."""
        passed = set()
        for n in ast.walk(method):
            if (isinstance(n, ast.Call)
                    and qualname(n.func) == "self._observe"):
                for a in n.args:
                    if isinstance(a, (ast.Lambda, ast.FunctionDef)):
                        passed.add(id(a))
                    elif isinstance(a, ast.Name):
                        passed.add(a.id)
        locked = set()
        for n in ast.walk(method):
            if isinstance(n, ast.Lambda) and id(n) in passed:
                locked.add(n)
            elif (isinstance(n, ast.FunctionDef)
                  and (id(n) in passed or n.name in passed)):
                locked.add(n)
        return locked

    def _check_method(self, ctx, method, inner) -> list:
        locked_fns = self._observe_callables(method)
        out = []

        def is_lock_with(stmt) -> bool:
            return isinstance(stmt, ast.With) and any(
                qualname(item.context_expr) == "self._apply_lock"
                for item in stmt.items)

        def visit(node, locked):
            if node in locked_fns:
                locked = True
            if is_lock_with(node):
                locked = True
            if (not locked and isinstance(node, ast.Attribute)
                    and node.attr == inner
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"
                    and isinstance(node.ctx, ast.Load)):
                out.append(ctx.finding(
                    self.id, node,
                    f"self.{inner} accessed outside 'with "
                    "self._apply_lock' — a reader here can observe an "
                    "epoch mid-application (route it through "
                    "self._observe)"))
            for child in ast.iter_child_nodes(node):
                visit(child, locked)

        visit(method, False)
        return out


class ProtocolDrift(Rule):
    """TL005: backend classes vs the ``Backend`` protocol, statically.
    Conformance certifies the ops a scenario happens to exercise; a
    missing method or drifted signature on a rarely-hit op (kill during
    rmdir races) surfaces only in production.  This diff is total."""

    id = "TL005"
    name = "protocol-drift"
    description = ("backend classes missing protocol methods, carrying "
                   "signature mismatches, or growing unsanctioned surface")
    project_wide = True

    PROTOCOL_CLASS = "Backend"
    # sanctioned extensions beyond the protocol (each is documented on
    # the class that carries it); anything else is drift until either
    # added here deliberately or promoted into the protocol
    EXTENSIONS = frozenset({
        "device_view", "restore", "flush", "barrier", "close",
        "throttle_delay_ms", "reconcile", "unwedge", "placement",
        "offload_fault",
    })

    def check_project(self, ctxs) -> list:
        proto = None
        for ctx in ctxs:
            for node in ast.walk(ctx.tree):
                if (isinstance(node, ast.ClassDef)
                        and node.name == self.PROTOCOL_CLASS
                        and any((qualname(b) or "").endswith("Protocol")
                                for b in node.bases)):
                    proto = node
                    break
            if proto is not None:
                break
        if proto is None:
            return []
        methods = {m.name: _sig(m) for m in proto.body
                   if isinstance(m, ast.FunctionDef)
                   and not m.name.startswith("_")}
        attrs = {s.target.id for s in proto.body
                 if isinstance(s, ast.AnnAssign)
                 and isinstance(s.target, ast.Name)}
        out = []
        for ctx in ctxs:
            for node in ast.walk(ctx.tree):
                if (isinstance(node, ast.ClassDef)
                        and node.name.endswith("Backend")
                        and node.name != self.PROTOCOL_CLASS
                        and not _is_exception(node)):
                    out.extend(self._check_backend(ctx, node, methods,
                                                   attrs))
        return out

    def _check_backend(self, ctx, cls, methods, attrs) -> list:
        defined = {m.name: m for m in cls.body
                   if isinstance(m, ast.FunctionDef)}
        has_getattr = "__getattr__" in defined
        out = []
        for name, want in sorted(methods.items()):
            if name not in defined:
                if not has_getattr:
                    out.append(ctx.finding(
                        self.id, cls,
                        f"{cls.name} is missing Backend method "
                        f"'{name}{_fmt(want)}'"))
                continue
            got = _sig(defined[name])
            if got is not None and want is not None and got != want:
                out.append(ctx.finding(
                    self.id, defined[name],
                    f"{cls.name}.{name}{_fmt(got)} drifts from the "
                    f"Backend protocol {_fmt(want)}"))
        for name, m in sorted(defined.items()):
            if (name.startswith("_") or name in methods
                    or name in self.EXTENSIONS
                    or _is_property(m)):
                continue
            out.append(ctx.finding(
                self.id, m,
                f"{cls.name}.{name} is not in the Backend protocol nor "
                "the sanctioned extension list (promote it or rename it "
                "to a private helper)"))
        if not has_getattr:
            init = defined.get("__init__")
            assigned = set()
            if init is not None:
                assigned = {n.attr for n in ast.walk(init)
                            if isinstance(n, ast.Attribute)
                            and isinstance(n.ctx, ast.Store)
                            and isinstance(n.value, ast.Name)
                            and n.value.id == "self"}
            props = {m.name for m in cls.body
                     if isinstance(m, ast.FunctionDef) and _is_property(m)}
            class_assigns = {t.id for s in cls.body
                             if isinstance(s, ast.Assign)
                             for t in s.targets
                             if isinstance(t, ast.Name)}
            for a in sorted(attrs):
                if a not in assigned | props | class_assigns:
                    out.append(ctx.finding(
                        self.id, cls,
                        f"{cls.name} does not provide Backend attribute "
                        f"'{a}'"))
        return out


def _sig(fn) -> Optional[tuple]:
    a = fn.args
    if a.vararg is not None or a.kwarg is not None:
        return None                    # dynamic signature: can't compare
    names = tuple(x.arg for x in (a.posonlyargs + a.args))
    return names[1:] if names and names[0] in ("self", "cls") else names


def _fmt(sig) -> str:
    return "(...)" if sig is None else f"({', '.join(sig)})"


def _is_exception(cls) -> bool:
    return any((qualname(b) or "").endswith(("Error", "Exception"))
               for b in cls.bases)


def _is_property(fn) -> bool:
    for d in fn.decorator_list:
        q = qualname(d)
        if q == "property" or (q is not None and q.endswith(".setter")):
            return True
    return False


class PytreeStability(Rule):
    """TL006: conditionally-created dict keys in control-state builders.
    jit caches key on pytree *structure*: a dict that sometimes carries
    a key and sometimes doesn't retraces on every structure flip — and
    snapshot/restore across the flip silently drops state.  Keys must
    exist unconditionally (use a neutral value instead of absence)."""

    id = "TL006"
    name = "pytree-stability"
    description = ("dict keys created under a conditional in functions "
                   "building control-state pytrees")

    def applies(self, ctx: FileContext) -> bool:
        return ctx.in_dirs(("core",))

    def check(self, ctx: FileContext) -> list:
        out = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_fn(ctx, node, out)
        return out

    def _check_fn(self, ctx, fn, out) -> None:
        tracked: dict = {}

        def literal_keys(value) -> Optional[set]:
            if isinstance(value, ast.Dict):
                keys = set()
                for k in value.keys:
                    if (isinstance(k, ast.Constant)
                            and isinstance(k.value, str)):
                        keys.add(k.value)
                    else:
                        return None     # **spread / computed key: opaque
                return keys
            if (isinstance(value, ast.Call)
                    and qualname(value.func) == "dict"
                    and not value.args):
                return {kw.arg for kw in value.keywords
                        if kw.arg is not None}
            return None

        def visit(stmts, depth) -> None:
            for s in stmts:
                if isinstance(s, ast.Assign) and len(s.targets) == 1:
                    t = s.targets[0]
                    if isinstance(t, ast.Name):
                        keys = literal_keys(s.value)
                        if keys is not None and depth == 0:
                            tracked[t.id] = keys
                        else:
                            tracked.pop(t.id, None)
                    elif (isinstance(t, ast.Subscript)
                          and isinstance(t.value, ast.Name)
                          and t.value.id in tracked
                          and isinstance(t.slice, ast.Constant)
                          and isinstance(t.slice.value, str)):
                        key = t.slice.value
                        if key in tracked[t.value.id]:
                            pass
                        elif depth > 0:
                            out.append(ctx.finding(
                                self.id, s,
                                f"dict key '{key}' created conditionally "
                                f"on '{t.value.id}' — pytree structure "
                                "now depends on runtime state (create "
                                "the key unconditionally with a neutral "
                                "value)"))
                        else:
                            tracked[t.value.id].add(key)
                for child, extra in _nested_blocks(s):
                    visit(child, depth + extra)

        visit(fn.body, 0)

    # note: nested function defs inside `fn` get their own _check_fn
    # pass via ast.walk in check(), so we skip them here


def _nested_blocks(stmt):
    """(body, conditional-depth-delta) pairs for compound statements.
    ``for``/``with`` bodies are not conditional structure-wise (the same
    keys are set each iteration); ``if``/``while``/``try`` are."""
    if isinstance(stmt, ast.If):
        return [(stmt.body, 1), (stmt.orelse, 1)]
    if isinstance(stmt, ast.While):
        return [(stmt.body, 1), (stmt.orelse, 1)]
    if isinstance(stmt, ast.Try):
        blocks = [(stmt.body, 1), (stmt.orelse, 1), (stmt.finalbody, 0)]
        blocks.extend((h.body, 1) for h in stmt.handlers)
        return blocks
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return [(stmt.body, 0), (stmt.orelse, 1)]
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return [(stmt.body, 0)]
    return []


ALL_RULES = (TracePurity(), RetraceHazards(), ReplayDeterminism(),
             LockDiscipline(), ProtocolDrift(), PytreeStability())


def rules_by_id() -> dict:
    return {r.id: r for r in ALL_RULES}

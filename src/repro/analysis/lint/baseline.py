"""Baseline handling: grandfathered findings, by fingerprint.

The baseline is a checked-in JSON file of finding fingerprints
(``path::rule::message`` — deliberately line-independent, so unrelated
edits shifting code up or down a file do not invalidate it).  CI runs
with ``--baseline``: any finding not in the file fails the build, which
ratchets the codebase toward clean without blocking on a big-bang fix.
The checked-in ``tracelint-baseline.json`` is empty — ``src/`` lints
clean as of PR 8 — so the file exists purely as the ratchet's anchor.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable, Sequence

from repro.analysis.lint.core import Finding, LintError

BASELINE_VERSION = 1


def load_baseline(path: str) -> frozenset:
    """Read a baseline file into a set of fingerprints."""
    try:
        data = json.loads(Path(path).read_text(encoding="utf-8"))
    except FileNotFoundError:
        raise LintError(f"baseline file not found: {path}") from None
    except (OSError, json.JSONDecodeError) as e:
        raise LintError(f"cannot read baseline {path}: {e}") from e
    if not isinstance(data, dict) or data.get("version") != BASELINE_VERSION:
        raise LintError(f"unsupported baseline format in {path}")
    entries = data.get("entries", [])
    fps = set()
    for e in entries:
        try:
            fps.add(f"{e['path']}::{e['rule']}::{e['message']}")
        except (TypeError, KeyError):
            raise LintError(f"malformed baseline entry in {path}: {e!r}"
                            ) from None
    return frozenset(fps)


def write_baseline(path: str, findings: Iterable[Finding]) -> None:
    """Write the current findings out as the new baseline."""
    entries = sorted(
        ({"path": f.path, "rule": f.rule, "message": f.message}
         for f in findings),
        key=lambda e: (e["path"], e["rule"], e["message"]))
    payload = {"version": BASELINE_VERSION, "entries": entries}
    Path(path).write_text(json.dumps(payload, indent=2) + "\n",
                          encoding="utf-8")


def apply_baseline(findings: Sequence[Finding],
                   fingerprints: frozenset) -> tuple:
    """Split findings against a baseline: ``(kept, suppressed_count)``.
    Kept findings are new relative to the baseline and should fail CI."""
    kept = [f for f in findings if f.fingerprint not in fingerprints]
    return kept, len(findings) - len(kept)

"""Token sampling for the serving engine (jit-safe)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits: (B, V) fp32 -> (B,) int32.

    temperature == 0 -> greedy.  top_k > 0 restricts to the k best."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

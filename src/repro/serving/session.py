r"""Agent sessions: the serving engine's unit of tenancy.

A session models one sandboxed agent: a prompt, then an alternating
reason/act loop in which each tool call's *result* is appended to the
context as a burst of tokens (the KV-page analogue of the paper's
tool-call memory bursts; a sub-agent fork appends an especially large
result).  Scripts can be built directly or derived from a §3 trace.

State machine: WAITING -> RUNNING <-> (THROTTLED | FROZEN) -> DONE
                                   \-> EVICTED (last resort)
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

from repro.core import domains as D
from repro.core.intent import Hint, CATEGORY_HINT
from repro.traces.schema import TaskTrace


class SState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FROZEN = "frozen"
    DONE = "done"
    EVICTED = "evicted"


@dataclass
class Phase:
    """One reason/act cycle: generate ``gen_tokens``, then a tool call
    whose result appends ``append_tokens`` to the context."""
    gen_tokens: int
    append_tokens: int = 0
    category: str = "python"
    hint: Optional[Hint] = None


@dataclass
class Session:
    sid: str
    tenant: str
    priority: int = D.NORMAL
    prompt: list = field(default_factory=list)       # token ids
    phases: list = field(default_factory=list)       # list[Phase]
    state: SState = SState.WAITING
    slot: int = -1
    dom_idx: int = -1
    length: int = 0                  # tokens in cache
    pages: int = 0                   # pages charged
    # progress
    phase_idx: int = 0
    phase_gen_left: int = 0
    feed_queue: list = field(default_factory=list)   # tokens to force-feed
    out_tokens: list = field(default_factory=list)
    cur_token: int = 1
    # metrics
    t_admit: int = 0                 # engine step of admission
    t_done: int = 0
    stall_steps: int = 0
    stall_started: Optional[int] = None
    alloc_latencies_steps: list = field(default_factory=list)
    n_freezes: int = 0
    feedbacks: list = field(default_factory=list)
    # snapshot at the start of the current tool-result burst, so the
    # engine can roll the call back (subprocess-kill + retry analogue)
    burst_start_len: int = -1
    burst_start_pages: int = 0
    burst_start_token: int = 1
    burst_total: int = 0
    n_rollbacks: int = 0

    @property
    def domain(self) -> str:
        return f"/{self.tenant}/{self.sid}"

    def start(self) -> None:
        self.feed_queue = list(self.prompt)
        if self.phases:
            self.phase_gen_left = self.phases[0].gen_tokens
        self.state = SState.RUNNING

    # ---------------------------------------------------------- stepping

    def next_input(self) -> int:
        """Token to feed this step (prompt/tool-result chunk, or the
        last sampled token during generation)."""
        if self.feed_queue:
            return self.feed_queue[0]
        return self.cur_token

    def advance(self, sampled: int) -> None:
        """Called when the engine step granted this slot's token."""
        self.length += 1
        if self.feed_queue:
            self.feed_queue.pop(0)       # consumed one forced token
            if not self.feed_queue:
                self.cur_token = sampled
            return
        self.cur_token = sampled
        self.out_tokens.append(sampled)
        if self.phase_idx < len(self.phases):
            ph = self.phases[self.phase_idx]
            self.phase_gen_left -= 1
            if self.phase_gen_left <= 0:
                # the tool call returns: its result floods the context
                if ph.append_tokens:
                    self.burst_start_len = self.length
                    self.burst_start_pages = self.pages
                    self.burst_start_token = self.cur_token
                    self.burst_total = ph.append_tokens
                    self.feed_queue.extend(
                        (i % 1000) + 2 for i in range(ph.append_tokens))
                self.phase_idx += 1
                if self.phase_idx < len(self.phases):
                    self.phase_gen_left = self.phases[self.phase_idx].gen_tokens

    @property
    def finished(self) -> bool:
        return (self.phase_idx >= len(self.phases) and not self.feed_queue)

    def current_phase(self) -> Optional[Phase]:
        if self.phase_idx < len(self.phases):
            return self.phases[self.phase_idx]
        return None

    def declared_hint(self) -> Optional[Hint]:
        ph = self.current_phase()
        if ph is None:
            return None
        return ph.hint or CATEGORY_HINT.get(ph.category)

    # ----------------------------------------------- feedback adaptation

    def apply_feedback(self, fb, scale: float) -> None:
        """Strategy reconstruction: shrink the pending context append."""
        self.feedbacks.append(fb)
        if self.feed_queue:
            keep = max(1, int(len(self.feed_queue) * scale))
            del self.feed_queue[keep:]

    def rollback_burst(self, scale: float) -> int:
        """Subprocess-kill analogue: revert to the pre-tool-call context,
        releasing its pages, and queue a scaled-down retry of the result.
        Returns pages freed (engine uncharges them)."""
        if self.burst_start_len < 0:
            return 0
        freed = self.pages - self.burst_start_pages
        self.length = self.burst_start_len
        self.pages = self.burst_start_pages
        self.cur_token = self.burst_start_token
        self.burst_total = max(1, int(self.burst_total * scale))
        self.feed_queue = [(i % 1000) + 2 for i in range(self.burst_total)]
        self.n_rollbacks += 1
        return max(freed, 0)


def session_from_trace(sid: str, tenant: str, trace: TaskTrace, *,
                       priority: int = D.NORMAL, tokens_per_mb: float = 4.0,
                       gen_per_call: int = 24, max_phases: int = 12,
                       prompt_tokens: int = 48) -> Session:
    """Map a §3 trace to a serving session: each tool call becomes a
    phase whose appended result size scales with the call's burst."""
    phases = []
    for c in sorted(trace.tool_calls, key=lambda c: c.t_start_s)[:max_phases]:
        phases.append(Phase(
            gen_tokens=gen_per_call,
            append_tokens=max(4, int(c.peak_mb * tokens_per_mb)),
            category=c.category))
    return Session(sid=sid, tenant=tenant, priority=priority,
                   prompt=[(i % 997) + 2 for i in range(prompt_tokens)],
                   phases=phases)

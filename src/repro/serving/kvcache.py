"""KV/state cache management + page accounting for the serving engine.

Two layers:
  * ``PageAccountant`` — maps context length to page counts (the charge
    unit of the resource domains; 1 page = ``page_tokens`` tokens of KV/
    state footprint).  This is what AgentCgroup governs.
  * ``SlotCaches`` — the dense per-slot decode state (built from
    ``model.decode_state_schema``), with freeze/thaw slot offload to a
    ``FrozenStore`` (host memory) and slot recycling.

The Pallas paged-decode kernel (kernels/decode_attention.py) is the TPU
production path for the GQA cache layout; on the CPU test rig the engine
runs the dense per-slot layout with identical page-granular accounting
(see DESIGN.md §hardware-adaptation).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.freezer import FrozenStore
from repro.models import model as M
from repro.models.schema import Leaf, tree_map_schema


@dataclass(frozen=True)
class PageAccountant:
    page_tokens: int = 16

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(max(n_tokens, 1) / self.page_tokens)

    def crossing(self, length: int) -> int:
        """Pages that must be charged to append token #length (0-based)."""
        return 1 if length % self.page_tokens == 0 else 0


class SlotCaches:
    """Dense per-slot decode state with host offload."""

    def __init__(self, cfg: ModelConfig, max_slots: int, s_max: int):
        self.cfg = cfg
        self.max_slots = max_slots
        self.s_max = s_max
        sch = M.decode_state_schema(cfg, max_slots, s_max)
        self.state = tree_map_schema(
            lambda l: jnp.zeros(l.shape, jnp.dtype(l.dtype or cfg.dtype)), sch)
        self._free = list(range(max_slots))
        self.store = FrozenStore()

    # ------------------------------------------------------------- slots

    def alloc_slot(self) -> Optional[int]:
        return self._free.pop(0) if self._free else None

    def free_slot(self, slot: int) -> None:
        # zero the slot's state so a recycled slot starts clean
        self.state = jax.tree.map(
            lambda x: x.at[:, slot].set(jnp.zeros_like(x[:, slot])), self.state)
        self._free.append(slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    # ----------------------------------------------------- freeze / thaw

    def freeze_slot(self, session_id: str, slot: int, *, pages: int,
                    meta: Optional[dict] = None, now: float = 0.0) -> None:
        """Offload one slot's state to host memory and recycle the slot."""
        blob = jax.tree.map(lambda x: np.asarray(x[:, slot]), self.state)
        self.store.freeze(session_id, blob, pages=pages, meta=meta, now=now)
        self.free_slot(slot)

    def thaw_slot(self, session_id: str) -> tuple[int, dict]:
        """Restore a frozen session into a fresh slot."""
        slot = self.alloc_slot()
        assert slot is not None, "no free slot to thaw into"
        entry = self.store.thaw(session_id)
        self.state = jax.tree.map(
            lambda x, b: x.at[:, slot].set(jnp.asarray(b, x.dtype)),
            self.state, entry.blobs)
        return slot, entry.meta

"""Multi-tenant continuous-batching engine with AgentCgroup enforcement.

Every engine step advances all active slots by one token (uniform
chunked prefill: prompt/tool-result tokens are force-fed one per step,
so *every* context-page allocation flows through the same charge path a
decoded token uses).  The resource controller runs in one of two modes:

  * ``inkernel``  — the AgentCgroup design: the control plane's
    ``device_view().charge`` executes INSIDE the jitted step; a slot
    whose page charge is denied (hard limit, freeze, throttle) simply
    does not advance *this same step*.  Microsecond-class reaction, no
    host round trip.
  * ``userspace`` — the baseline the paper's §4.2 criticizes: the daemon
    observes usage with a poll interval + reaction latency and gates
    slots one-or-more steps late; bursts land before control does (the
    engine measures the resulting budget overshoot).

Host-side daemon work (lifecycle only, as in the paper): admission,
per-tool-call child domains with intent-hint highs, freeze/thaw with
state offload (SlotCaches/FrozenStore), downward feedback that lets a
session shrink a pending context append (strategy reconstruction).
With ``EngineConfig(backend="async")`` that lifecycle work runs on the
``AsyncDaemonBackend`` daemon thread in FIFO epochs applied at the
``cg.flush()`` each step issues before reading control state —
bit-exact with the synchronous backends, lifecycle off the step
critical path.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import domains as D
from repro.core import pressure as PSI
from repro.core.adaptive import AdaptiveConfig, AdaptiveController
from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DeviceView,
                               DomainSpec)
from repro.core.controller import ControllerConfig
from repro.core.daemon import AsyncDaemonBackend, DaemonError
from repro.core.events import Ev, EventLog
from repro.core.intent import Hint
from repro.core.progs import PolicyProgram
from repro.models import model as M
from repro.perf import PerfConfig, DEFAULT_PERF
from repro.serving.kvcache import PageAccountant, SlotCaches
from repro.serving.sampling import sample
from repro.serving.session import Phase, Session, SState


@dataclass(frozen=True)
class EngineConfig:
    max_slots: int = 8
    s_max: int = 512
    pool_pages: int = 256                # KV pool per device group
    page_tokens: int = 16
    mode: str = "inkernel"               # inkernel | userspace | nolimit
    backend: str = "device"              # device | sharded | async
    async_inner: str = "device"          # async: the wrapped backend
    n_shards: Optional[int] = None       # sharded: device-group count
    ctrl: ControllerConfig = ControllerConfig(step_ms=10.0)
    temperature: float = 0.0
    # daemon knobs
    freeze_threshold: float = 0.97
    thaw_threshold: float = 0.80
    feedback_patience_steps: int = 40
    evict_patience_steps: int = 400
    userspace_poll_steps: int = 8        # PSI-poll analogue
    userspace_react_steps: int = 4       # daemon decision+write latency
    use_intent: bool = True
    use_tool_domains: bool = True
    use_freeze: bool = True              # graceful-degradation step 2
    # weighted CPU scheduler (cpu.weight / cpu.max): when set, at most
    # ``sched_slots`` weighted slots advance per step, picked by the
    # hierarchical fair scheduler (core/sched.py).  None keeps the
    # binary slot gate — the pre-scheduler behavior, bit for bit.
    sched_slots: Optional[int] = None
    # closed-loop adaptive retuner over memory.pressure / cpu.pressure
    # (core/adaptive.py): polls at step boundaries (the async backend's
    # epoch cadence), bumps soft limits / retunes params through
    # zero-retrace knobs.  None (the default) keeps behavior
    # bit-identical — the loop never runs, no pressure file is read.
    adaptive: Optional[AdaptiveConfig] = None
    # intent hints in engine pages (LOW/MEDIUM/HIGH priority of Hint enum)
    intent_high_pages: Optional[dict] = None
    session_high: Optional[dict] = None  # sid -> memory.high (pages)
    max_steps: int = 20_000


def _gate_shape(gate, x):
    return gate.reshape((1, gate.shape[0]) + (1,) * (x.ndim - 2))


def _make_step_fn(cfg: ModelConfig, perf: PerfConfig, ecfg: EngineConfig,
                  view: DeviceView):
    @functools.partial(jax.jit, static_argnames=("mode",), donate_argnums=(1, 2))
    def step_fn(params, dstate, ctrl, tokens, lengths, dom, amt, host_gate,
                step_no, key, *, mode: str):
        if ecfg.sched_slots is not None:
            # weighted step scheduler: rank this step's runnable slots by
            # vruntime and grant at most sched_slots of them; a slot the
            # scheduler defers simply does not advance this step (its
            # charge never reaches the memory controller).  Slots whose
            # program weight is <= 0 bypass the budget entirely, so the
            # stock program keeps this a no-op.
            cost = (dom >= 0).astype(jnp.int32)
            ctrl, advance = view.schedule(ctrl, dom, cost, step_no,
                                          ecfg.sched_slots)
            dom = jnp.where(advance, dom, -1)
        if mode == "inkernel":
            # in-step enforcement: charge + gate inside the same program
            ctrl, granted, stalled = view.charge(ctrl, dom, amt, step_no)
            gate = granted
        else:
            # user-space baseline: the (stale) host gate decides; usage is
            # charged after the fact, so bursts overshoot the budget
            gate = host_gate & (dom >= 0)
            ctrl = view.account(ctrl, jnp.where(gate, dom, -1), amt)
            granted, stalled = gate, (dom >= 0) & ~gate
        logits, new_state = M.decode_step(cfg, params, dstate, tokens,
                                          lengths, perf=perf)
        nxt = sample(logits, key, temperature=ecfg.temperature)
        new_state = jax.tree.map(
            lambda n, o: jnp.where(_gate_shape(gate, n), n, o),
            new_state, dstate)
        nxt = jnp.where(gate, nxt, tokens)
        return nxt, new_state, ctrl, granted, stalled

    return step_fn


@dataclass
class EngineMetrics:
    root_usage: list = field(default_factory=list)
    overshoot_pages: int = 0             # max pages over pool budget
    session_overshoot_pages: int = 0     # max pages over any session high
    throttle_triggers: int = 0
    n_feedbacks: int = 0
    n_freezes: int = 0
    n_thaws: int = 0
    n_evictions: int = 0
    n_rebuilds: int = 0                  # poisoned-daemon backend rebuilds
    steps: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, *,
                 perf: PerfConfig = DEFAULT_PERF,
                 ecfg: EngineConfig = EngineConfig(), seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.perf = perf
        self.ecfg = ecfg
        self.caches = SlotCaches(cfg, ecfg.max_slots, ecfg.s_max)
        self.accountant = PageAccountant(ecfg.page_tokens)
        be = self._make_inner()
        if ecfg.backend == "async":
            # lifecycle off the hot path: mkdir/rmdir/write/freeze/thaw/
            # lease ops run on the daemon thread in FIFO epochs, applied
            # at the flush() in step() — the jitted enforcement path
            # closes over the INNER backend's device view and never
            # blocks on lifecycle work
            be = AsyncDaemonBackend(be)
        self.cg = AgentCgroup(be)
        # the engine's facade clock counts steps (set_time(step_no)),
        # not ms: one step per clock unit, PSI windows converted from
        # ms to steps via the controller's step_ms
        self.cg.pressure_clock(
            step_quantum=1.0,
            windows=(PSI.AVG10_MS / ecfg.ctrl.step_ms,
                     PSI.AVG60_MS / ecfg.ctrl.step_ms))
        self._adaptive = (AdaptiveController(self.cg, ecfg.adaptive)
                          if ecfg.adaptive is not None else None)
        self._adaptive_epoch = None
        # pool_pages is per device group: each shard root is capped at
        # pool_pages in-step, so the aggregate the daemon reasons about
        # (root_usage sums every group) is pool_pages * n_shards
        self.pool_capacity = ecfg.pool_pages * getattr(be, "n_shards", 1)
        self._view = self.cg.device_view()
        self.log = EventLog()
        self.metrics = EngineMetrics()
        self.sessions: dict[str, Session] = {}
        self.waiting: list[str] = []
        self.slot_session: list[Optional[str]] = [None] * ecfg.max_slots
        self.step_no = 0
        self.key = jax.random.PRNGKey(seed)
        self._step = _make_step_fn(cfg, perf, ecfg, self._view)
        self._host_gate = np.ones(ecfg.max_slots, bool)
        self._lease: dict[str, object] = {}      # sid -> open tool Lease
        self._tool_seq = 0
        self._prev_throttle = np.zeros(self.cg.backend.n_domains, np.int64)
        # ordered attach history (scope -> program, same-scope replaces in
        # place) so a backend rebuild replays the exact registry slots
        self._attachments: list = []
        self._last_snapshot: Optional[dict] = None

    def _make_inner(self):
        e = self.ecfg
        n_domains = 4 * e.max_slots + 8
        inner_kind = e.async_inner if e.backend == "async" else e.backend
        if inner_kind == "sharded":
            from repro.core.sharded import ShardedTableBackend
            return ShardedTableBackend(e.pool_pages, n_domains=n_domains,
                                       cfg=e.ctrl, n_shards=e.n_shards)
        return DeviceTableBackend(e.pool_pages, n_domains=n_domains,
                                  cfg=e.ctrl)

    # ---------------------------------------------------- policy programs

    def attach_program(self, prog: PolicyProgram, path: str = "/") -> None:
        """Swap or compose in-step enforcement programs (BPF object
        load): the next step re-traces against the new decision code.
        A root attach replaces the whole registry; a subtree attach at
        ``path`` composes — that tenant's domains run ``prog`` while
        everyone else keeps theirs (``AgentCgroup.attach``).  For pure
        parameter retunes use ``update_params`` — no retrace."""
        if path == "/":
            self._attachments = [("/", prog)]
        else:
            for i, (p, _) in enumerate(self._attachments):
                if p == path:
                    self._attachments[i] = (path, prog)
                    break
            else:
                self._attachments.append((path, prog))
        self.cg.attach(path, prog)
        self._view = self.cg.device_view()
        self._step = _make_step_fn(self.cfg, self.perf, self.ecfg,
                                   self._view)

    def update_params(self, path: str = "/", **kv) -> None:
        """Retune the live program mid-run (BPF map write): plain state,
        takes effect the following step, never recompiles."""
        self.cg.update_params(path, **kv)

    # ------------------------------------------------------------ admission

    def submit(self, session: Session) -> None:
        self.sessions[session.sid] = session
        tenant_path = f"/{session.tenant}"
        if not self.cg.exists(tenant_path):
            self.cg.mkdir(tenant_path)
        self.waiting.append(session.sid)

    def _try_admit(self) -> None:
        still = []
        for sid in self.waiting:
            s = self.sessions[sid]
            slot = self.caches.alloc_slot()
            if slot is None:
                still.append(sid)
                continue
            s.slot = slot
            low = 0
            if s.priority == D.HIGH:
                low = self.ecfg.pool_pages            # below_low protection
            high = (self.ecfg.session_high or {}).get(s.sid, D.UNLIMITED)
            s.dom_idx = self.cg.mkdir(s.domain, DomainSpec(
                priority=s.priority, low=low, high=high))
            s.t_admit = self.step_no
            self.slot_session[slot] = sid
            s.start()
            self.log.emit(self.step_no, Ev.ADMIT, s.domain)
        self.waiting = still

    # --------------------------------------------------- tool-call domains

    def _sync_tool_domain(self, s: Session) -> None:
        """Ephemeral child domain per tool-result burst (bash-wrapper
        analogue); intent hints set its memory.high."""
        if not self.ecfg.use_tool_domains:
            return
        in_burst = bool(s.feed_queue) and s.length > len(s.prompt)
        has = s.sid in self._lease
        if in_burst and not has:
            self._tool_seq += 1
            high = D.UNLIMITED
            hint = None
            if self.ecfg.use_intent:
                table = self.ecfg.intent_high_pages or {
                    Hint.LOW: 4, Hint.MEDIUM: 10, Hint.HIGH: 24}
                hint = s.declared_hint()
                high = table.get(hint, table[Hint.MEDIUM])
            lease = self.cg.intent.declare(f"tool_{self._tool_seq}", hint,
                                           parent=s.domain,
                                           priority=s.priority, high=high)
            self._lease[s.sid] = lease
            s.dom_idx = self.cg.handle(lease.path)
        elif not in_burst and has:
            # context pages persist: lease close moves the residual
            # charge up to the session
            self._lease.pop(s.sid).close()
            s.dom_idx = self.cg.handle(s.domain)

    # -------------------------------------------------------------- daemon

    def _userspace_policy(self) -> None:
        """User-space throttle daemon: the SAME graduated-delay policy the
        in-kernel path applies, but computed from telemetry polled every
        ``userspace_poll_steps`` and applied ``userspace_react_steps``
        late — the §4.2 responsiveness gap.  Bursts land before control
        does; the per-session ``high`` overshoot metric quantifies it."""
        e = self.ecfg
        if self.step_no % e.userspace_poll_steps == 0:
            snap = self.cg.snapshot()
            usage, high, maxl = snap["usage"], snap["high"], snap["max"]
            parent = snap["parent"]
            progs = self.cg.programs
            ids = snap.get("prog_id")
            decisions = {}
            for slot, sid in enumerate(self.slot_session):
                if sid is None:
                    continue
                s = self.sessions[sid]
                chain = [s.dom_idx]
                while parent[chain[-1]] >= 0:
                    chain.append(int(parent[chain[-1]]))
                over = max((usage[i] - high[i]) / max(high[i], 1)
                           for i in chain)
                hard = any(usage[i] >= maxl[i] for i in chain)
                if over > 0 or hard:
                    # the SAME delay curve the in-step program applies,
                    # computed from the session's live param row through
                    # the session's OWN program (its prog_id slot) —
                    # just polled late, the §4.2 responsiveness gap
                    pid = int(ids[s.dom_idx]) if ids is not None else 0
                    pr = progs[min(pid, len(progs) - 1)]
                    dly_ms = float(pr.delay_ms(
                        snap["params"][s.dom_idx], max(float(over), 0.0)))
                    dly = int(np.ceil(dly_ms / pr.step_ms)) or 1
                    decisions[slot] = self.step_no + e.userspace_react_steps + dly
            self._pending_gate = (self.step_no + e.userspace_react_steps,
                                  decisions)

    def _apply_pending_gate(self) -> None:
        pg = getattr(self, "_pending_gate", None)
        if pg is not None and self.step_no >= pg[0]:
            self._ungate_step = getattr(self, "_ungate_step",
                                        np.zeros(self.ecfg.max_slots))
            for slot, until in pg[1].items():
                self._ungate_step[slot] = max(self._ungate_step[slot], until)
                self.metrics.throttle_triggers += 1
            self._pending_gate = None
        ug = getattr(self, "_ungate_step", None)
        if ug is not None:
            self._host_gate = ug <= self.step_no

    def _daemon(self) -> None:
        e = self.ecfg
        snap = self.cg.snapshot()
        # last known-good step-boundary snapshot: the rebuild-from-
        # snapshot path (poisoned async daemon) restores from here
        self._last_snapshot = snap
        root_usage = int(snap.get("root_usage", snap["usage"][0]))
        self.metrics.root_usage.append(root_usage)
        self.metrics.overshoot_pages = max(
            self.metrics.overshoot_pages, root_usage - self.pool_capacity)
        usage, high = snap["usage"], snap["high"]
        lim = high < D.UNLIMITED
        if lim.any():
            self.metrics.session_overshoot_pages = max(
                self.metrics.session_overshoot_pages,
                int((usage[lim] - high[lim]).max()))
        # freeze under extreme pressure (graceful degradation step 2)
        if e.use_freeze and root_usage > e.freeze_threshold * self.pool_capacity:
            cands = [self.sessions[sid] for sid in self.slot_session
                     if sid is not None
                     and self.sessions[sid].state is SState.RUNNING
                     and self.sessions[sid].priority == D.LOW]
            if cands:
                victim = max(cands, key=lambda s: s.pages)
                self._freeze(victim)
        else:
            frozen = [s for s in self.sessions.values()
                      if s.state is SState.FROZEN]
            if frozen and self.caches.n_free > 0:
                cand = min(frozen, key=lambda s: s.pages)
                if (root_usage + cand.pages
                        < e.thaw_threshold * self.pool_capacity):
                    self._thaw(cand)
        if self._adaptive is not None:
            # closed loop: poll every step boundary for synchronous
            # backends; for the async daemon, once per applied epoch —
            # pressure reads observe the state the flush just settled
            epoch = snap.get("epoch")
            if epoch is None or epoch != self._adaptive_epoch:
                self._adaptive_epoch = epoch
                self._adaptive.poll(float(self.step_no))
        self._try_admit()

    def _freeze(self, s: Session) -> None:
        if s.sid in self._lease:
            self._lease.pop(s.sid).close()     # residual moves to session
        self.caches.freeze_slot(s.sid, s.slot, pages=s.pages,
                                meta={"length": s.length},
                                now=self.step_no)
        self.slot_session[s.slot] = None
        # release pages (offloaded to host) + freeze the domain
        self.cg.uncharge(s.domain, s.pages)
        self.cg.freeze(s.domain)
        s.slot = -1
        s.state = SState.FROZEN
        s.n_freezes += 1
        self.metrics.n_freezes += 1
        self.log.emit(self.step_no, Ev.FREEZE, s.domain, pages=s.pages)

    def _thaw(self, s: Session) -> None:
        slot, meta = self.caches.thaw_slot(s.sid)
        self.cg.thaw(s.domain)
        self.cg.charge_unchecked(s.domain, s.pages)   # thaw re-charge
        s.slot = slot
        s.dom_idx = self.cg.handle(s.domain)
        self.slot_session[slot] = s.sid
        s.state = SState.RUNNING
        self.metrics.n_thaws += 1
        self.log.emit(self.step_no, Ev.THAW, s.domain)

    def _finish(self, s: Session) -> None:
        if s.sid in self._lease:
            self._lease.pop(s.sid).close()
        self.cg.uncharge(s.domain, s.pages)
        self.cg.rmdir(s.domain, transfer_residual=False)
        self.caches.free_slot(s.slot)
        self.slot_session[s.slot] = None
        s.slot = -1
        s.state = SState.DONE
        s.t_done = self.step_no
        self.log.emit(self.step_no, Ev.DONE, s.domain)

    def _evict(self, s: Session) -> None:
        """Last resort — the paper's triple-penalty path; counted so the
        benchmarks can show how rarely it fires."""
        if s.sid in self._lease:
            self._lease.pop(s.sid).close()
        self.cg.uncharge(s.domain, s.pages)
        self.cg.rmdir(s.domain, transfer_residual=False)
        if s.slot >= 0:
            self.caches.free_slot(s.slot)
            self.slot_session[s.slot] = None
        s.state = SState.EVICTED
        s.t_done = self.step_no
        self.metrics.n_evictions += 1
        self.log.emit(self.step_no, Ev.EVICT, s.domain)

    # ------------------------------------------------- daemon-fault recovery

    def _rebuild_backend(self) -> None:
        """Survive a poisoned/wedged async daemon: drop the backend,
        stand up a fresh one from the last step-boundary ``snapshot()``,
        and reconcile anything newer than the snapshot from the engine's
        Python-side session state (which is authoritative)."""
        e = self.ecfg
        try:
            self.cg.backend.close(flush=False)
        except Exception:                # noqa: BLE001 — already poisoned
            pass
        inner = self._make_inner()
        for path, prog in self._attachments:
            inner.attach(path, prog)
        if self._last_snapshot is not None:
            inner.restore(self._last_snapshot)
        be = inner
        if e.backend == "async":
            be = AsyncDaemonBackend(inner)
        self.cg.backend = be
        self.cg.set_time(self.step_no)
        self._reconcile_sessions()
        self._view = self.cg.device_view()
        self._step = _make_step_fn(self.cfg, self.perf, self.ecfg,
                                   self._view)
        self._prev_throttle = np.asarray(
            self._view.state["throttle_until"]).reshape(-1).astype(
                np.int64).copy()
        self.metrics.n_rebuilds += 1
        self.log.emit(self.step_no, Ev.REBUILD, "/")

    def _reconcile_sessions(self) -> None:
        """The snapshot is up to one step-boundary stale: admissions,
        freeze/thaw flips and charge drift since it was taken exist only
        in the Session objects — re-apply them to the rebuilt tree."""
        e = self.ecfg
        for s in self.sessions.values():
            if s.state in (SState.DONE, SState.EVICTED):
                continue
            tenant_path = f"/{s.tenant}"
            if not self.cg.exists(tenant_path):
                self.cg.mkdir(tenant_path)
            if s.state is SState.WAITING:
                continue
            if not self.cg.exists(s.domain):
                low = e.pool_pages if s.priority == D.HIGH else 0
                high = (e.session_high or {}).get(s.sid, D.UNLIMITED)
                self.cg.mkdir(s.domain, DomainSpec(
                    priority=s.priority, low=low, high=high))
            lease = self._lease.get(s.sid)
            if lease is not None and not self.cg.exists(lease.path):
                # the lease postdates the snapshot: drop it rather than
                # resurrect it — the next burst step re-declares
                self._lease.pop(s.sid)
                self.cg.intent._open.pop(lease.path, None)
                lease.closed = True
                lease = None
            path = lease.path if lease is not None else s.domain
            s.dom_idx = self.cg.handle(path)
            frozen = bool(self.cg.read(s.domain, "cgroup.freeze"))
            if s.state is SState.FROZEN and not frozen:
                self.cg.freeze(s.domain)
            elif s.state is not SState.FROZEN and frozen:
                self.cg.thaw(s.domain)
            want = 0 if s.state is SState.FROZEN else s.pages
            have = self.cg.usage(s.domain)
            if want > have:
                self.cg.charge_unchecked(path, want - have)
            elif have > want:
                self.cg.uncharge(path, have - want)

    # ----------------------------------------------------------------- step

    def step(self) -> None:
        e = self.ecfg
        # epoch boundary: queued lifecycle ops (async backend) apply
        # here, before the step reads the control state — never between
        # the state read and the post-step commit.  A wedged/poisoned
        # daemon surfaces here as DaemonError; the engine rebuilds the
        # whole backend from the last step-boundary snapshot and the
        # step proceeds on the fresh control plane.
        try:
            self.cg.set_time(self.step_no)
            self.cg.flush()
        except DaemonError:
            self._rebuild_backend()
        if self.ecfg.mode == "userspace":
            self._userspace_policy()
            self._apply_pending_gate()
        tokens = np.zeros(e.max_slots, np.int32)
        lengths = np.zeros(e.max_slots, np.int32)
        dom = np.full(e.max_slots, -1, np.int32)
        amt = np.zeros(e.max_slots, np.int32)
        for slot, sid in enumerate(self.slot_session):
            if sid is None:
                continue
            s = self.sessions[sid]
            if s.state is not SState.RUNNING:
                continue
            self._sync_tool_domain(s)
            tokens[slot] = s.next_input() % self.cfg.padded_vocab
            lengths[slot] = min(s.length, e.s_max - 1)
            dom[slot] = s.dom_idx
            amt[slot] = self.accountant.crossing(s.length)
        self.key, sub = jax.random.split(self.key)
        nxt, self.caches.state, new_ctrl, granted, stalled = \
            self._step(self.params, self.caches.state, self._view.state,
                       jnp.asarray(tokens), jnp.asarray(lengths),
                       jnp.asarray(dom), jnp.asarray(amt),
                       jnp.asarray(self._host_gate), self.step_no, sub,
                       mode=("inkernel" if e.mode == "inkernel"
                             else "userspace"))
        self._view.commit(new_ctrl)
        nxt = np.asarray(nxt)
        granted = np.asarray(granted)
        # throttle-trigger accounting (memcg_bpf_ops delay counter)
        tu = np.asarray(self._view.state["throttle_until"]).reshape(-1)
        self.metrics.throttle_triggers += int(np.sum(tu > self._prev_throttle))
        self._prev_throttle = np.maximum(tu, self._prev_throttle)

        for slot, sid in enumerate(self.slot_session):
            if sid is None:
                continue
            s = self.sessions[sid]
            if s.state is not SState.RUNNING:
                continue
            if granted[slot]:
                if s.stall_started is not None:
                    s.alloc_latencies_steps.append(
                        self.step_no - s.stall_started)
                    s.stall_started = None
                elif amt[slot]:
                    s.alloc_latencies_steps.append(0)
                s.pages += int(amt[slot])
                s.advance(int(nxt[slot]))
                if s.finished or s.length >= e.s_max - 1:
                    self._finish(s)
            else:
                s.stall_steps += 1
                if s.stall_started is None:
                    s.stall_started = self.step_no
                stall = self.step_no - s.stall_started
                # graduated feedback: first shrink the pending append;
                # if the session is wedged against the pool wall, roll
                # the whole tool call back (subprocess-kill + retry
                # analogue) so its pages free and a smaller retry fits
                if (stall > 0 and stall % e.feedback_patience_steps == 0
                        and s.feed_queue):
                    fb = self.cg.intent.feedback(
                        s.domain, "throttled", peak=s.pages,
                        limit=int(self.cg.read(self.cg.path_of(s.dom_idx),
                                               "memory.high")))
                    if (stall >= 2 * e.feedback_patience_steps
                            and s.burst_start_len >= 0):
                        freed = s.rollback_burst(scale=0.5)
                        if freed:
                            self.cg.uncharge(s.dom_idx, freed)
                        s.feedbacks.append(fb)
                        self.log.emit(self.step_no, Ev.FEEDBACK, s.domain,
                                      action="rollback", freed=freed)
                    else:
                        s.apply_feedback(fb, scale=0.5)
                        self.log.emit(self.step_no, Ev.FEEDBACK, s.domain,
                                      action="shrink")
                    self.metrics.n_feedbacks += 1
                elif stall > e.evict_patience_steps:
                    self._evict(s)
        self._daemon()
        self.step_no += 1
        self.metrics.steps = self.step_no

    def close(self) -> None:
        """Release backend resources — stops the async lifecycle daemon
        thread (a no-op for the synchronous backends)."""
        fn = getattr(self.cg.backend, "close", None)
        if fn is not None:
            fn()

    def run(self, max_steps: Optional[int] = None) -> EngineMetrics:
        limit = max_steps or self.ecfg.max_steps
        for _ in range(limit):
            if all(s.state in (SState.DONE, SState.EVICTED)
                   for s in self.sessions.values()) and not self.waiting:
                break
            self.step()
        return self.metrics

    # -------------------------------------------------------------- report

    def report(self) -> dict:
        e = self.ecfg
        done = [s for s in self.sessions.values() if s.state is SState.DONE]
        evicted = [s for s in self.sessions.values()
                   if s.state is SState.EVICTED]
        lat_by_prio: dict[int, list] = {}
        for s in self.sessions.values():
            lat_by_prio.setdefault(s.priority, []).extend(
                x * e.ctrl.step_ms for x in s.alloc_latencies_steps)

        def pct(xs, p):
            if not xs:
                return 0.0
            xs = sorted(xs)
            return xs[min(len(xs) - 1, int(round(p / 100 * (len(xs) - 1))))]

        return {
            "mode": e.mode,
            "completed": len(done),
            "evicted": len(evicted),
            "survival": len(done) / max(len(self.sessions), 1),
            "steps": self.step_no,
            "high_p50_ms": pct(lat_by_prio.get(D.HIGH, []), 50),
            "high_p95_ms": pct(lat_by_prio.get(D.HIGH, []), 95),
            "low_p95_ms": pct(lat_by_prio.get(D.LOW, []), 95),
            "throttle_triggers": self.metrics.throttle_triggers,
            "freezes": self.metrics.n_freezes,
            "thaws": self.metrics.n_thaws,
            "feedbacks": self.metrics.n_feedbacks,
            "overshoot_pages": self.metrics.overshoot_pages,
            "session_overshoot_pages": self.metrics.session_overshoot_pages,
            "peak_pool_pages": max(self.metrics.root_usage, default=0),
        }

"""JAX version-compatibility shims (single choke point for API drift).

The repo targets the Pallas/sharding surface of recent JAX, but must run
on every version the CI matrix installs (currently 0.4.37).  Three APIs
moved between 0.4.x and 0.5+:

  * ``pltpu.CompilerParams``       was ``pltpu.TPUCompilerParams``
  * ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of
    ``jax.make_mesh``              did not exist (meshes were implicitly
    all-auto, which is exactly what we want)
  * ``jax.shard_map``              lived at
    ``jax.experimental.shard_map.shard_map``

Every kernel, the mesh launcher, the sharded backend, and the
multi-device test snippets route through this module instead of probing
``jax.__version__`` themselves.  Import-time failures here are the
canary for a new drift — ``tests/test_compat.py`` asserts each shimmed
symbol resolves under the installed JAX.
"""
from __future__ import annotations

import os
from typing import Optional, Sequence

import jax

JAX_VERSION: tuple = tuple(int(x) for x in jax.__version__.split(".")[:3])


def tpu_compiler_params(*, dimension_semantics: Optional[tuple] = None, **kw):
    """``pltpu.CompilerParams`` on new JAX, ``TPUCompilerParams`` on old.

    Accepts the shared keyword surface (``dimension_semantics`` et al.)
    and returns whichever dataclass the installed Pallas understands, so
    ``pl.pallas_call(..., compiler_params=tpu_compiler_params(...))``
    works on both sides of the rename.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover — ancient pallas: params were a dict
        return dict(dimension_semantics=dimension_semantics, **kw)
    if dimension_semantics is not None:
        kw["dimension_semantics"] = tuple(dimension_semantics)
    return cls(**kw)


def make_auto_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
                   *, devices=None):
    """``jax.make_mesh`` with all-``Auto`` axis types on every version.

    New JAX requires ``axis_types=(AxisType.Auto, ...)`` to opt out of
    explicit sharding; old JAX predates ``AxisType`` and is implicitly
    auto.  Both paths produce a mesh usable under ``with mesh:`` with
    ``NamedSharding`` + ``PartitionSpec``.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    kw = {}
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axis_names)
    if devices is not None:
        kw["devices"] = devices
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    """Version-stable ``shard_map`` (moved out of ``jax.experimental``).

    The replication-check kwarg was spelled ``check_rep`` before the
    ``check_vma`` rename, so try both spellings before dropping it —
    callers pass ``check_rep=False`` because their bodies (scatter-add,
    manual all_gather) fail the check, and silently re-enabling it
    would error at trace time.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        for kw in ({"check_vma": check_rep}, {"check_rep": check_rep}, {}):
            try:
                return fn(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kw)
            except TypeError:
                continue
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_rep)


def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: newer JAX returns one
    dict, 0.4.x returns a per-computation list (possibly empty)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except RuntimeError:  # pragma: no cover — backend init failure
        return False


def force_interpret() -> bool:
    """The one reader of the ``REPRO_FORCE_PALLAS_INTERPRET`` knob —
    kernel dispatch (``kernels/ops.py``) and ``pallas_interpret`` both
    route through here so the documented env var has one meaning."""
    return os.environ.get("REPRO_FORCE_PALLAS_INTERPRET", "0") == "1"


def pallas_interpret(requested: Optional[bool] = None) -> bool:
    """Resolve the ``interpret=`` flag for a ``pallas_call``.

    Explicit requests win; otherwise fall back to interpret mode exactly
    when no TPU is attached (CPU-only hosts run the same kernel through
    the Pallas interpreter instead of erroring in Mosaic lowering).
    """
    if requested is not None:
        return requested
    if force_interpret():
        return True
    return not on_tpu()

"""Ambient activation-sharding context.

Model code calls ``constrain(x, ("act_batch", None, None))`` with
*logical* axes; the launcher installs a rules dict (logical -> mesh axes)
for the duration of a lowering/execution.  Outside any context the call
is a no-op, so unit tests and single-device smoke runs need no mesh.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

_RULES: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "activation_rules", default=None)
_MESH: contextvars.ContextVar[Optional[object]] = contextvars.ContextVar(
    "activation_mesh", default=None)


@contextlib.contextmanager
def activation_rules(rules: Optional[dict], mesh=None):
    tok = _RULES.set(rules)
    tok_m = _MESH.set(mesh)
    try:
        yield
    finally:
        _RULES.reset(tok)
        _MESH.reset(tok_m)


def current_rules() -> Optional[dict]:
    return _RULES.get()


def current_mesh():
    return _MESH.get()


def _resolve(entry, rules) -> Any:
    if entry is None:
        return None
    if isinstance(entry, tuple):
        axes = []
        for e in entry:
            r = rules.get(e, e)
            if r is None:
                continue
            axes.extend(r if isinstance(r, tuple) else (r,))
        return tuple(axes) if axes else None
    r = rules.get(entry, entry)
    return r


def constrain(x, logical_spec: tuple):
    """Apply ``with_sharding_constraint`` with logical axes, if rules are
    installed; otherwise identity."""
    rules = _RULES.get()
    if rules is None:
        return x
    ent = tuple(logical_spec) + (None,) * (x.ndim - len(logical_spec))
    spec = P(*[_resolve(e, rules) for e in ent])
    return jax.lax.with_sharding_constraint(x, spec)

"""Backend-conformance kit: one declarative op vocabulary, any backend.

PR 2–4 proved backend parity with ad-hoc op lists duplicated across
``tests/test_cgroup.py`` and ``tests/test_progs.py``; with a fourth
backend (the async lifecycle daemon) that plumbing becomes a reusable
kit.  A ``Scenario`` is a declarative op sequence; ``replay()`` drives
it through the ``AgentCgroup`` facade against any backend and records
every *observable* (grants, stalls, delays, residuals, reads, plus a
final usage/peak audit of the whole tree); ``ConformanceSuite.run()``
replays each scenario against the backend under test AND a reference
backend (the host tree — the reference semantics) and diffs the
observation streams.  A new ``Backend`` implementation certifies
itself with one parametrized fixture:

    suite = ConformanceSuite()
    report = suite.run(standard_backend_factory("async-device"))
    assert report.ok, report.summary()

Scenarios cover the memcg contract (charge/uncharge, hard-max walls,
freeze -> thaw re-charge, residual transfer on rmdir, subtree kill),
policy programs (graduated throttle windows, token-bucket pacing,
attach scoping, live retunes), the intent channel (lease open /
feedback / close), control files, and memcg event counters (feature
``"events"`` — only backends with full host-side counters run it).

Authoring new scenarios: write the op tuples directly, or drive a live
``AgentCgroup`` through an ``OpRecorder`` and call ``to_scenario()``.

Op vocabulary (``(name, *args)`` tuples; ``charge`` without an explicit
step runs on the op-index step clock):

    ("mkdir", path[, {spec kwargs}])        ("rmdir", path[, transfer])*
    ("charge", path, amt[, step])*          ("uncharge", path, amt)
    ("unchecked", path, amt)                ("kill", path)*
    ("freeze", path)  ("thaw", path)        ("write", path, file, value)
    ("read", path, file)*                   ("usage", path)* ("peak", path)*
    ("exists", path)*                       ("attach", scope, prog_key)
    ("update_params", path, {kv})           ("set_time", t)
    ("lease_open", tool, hint|None, parent[, {kw}])
    ("lease_feedback", tool, reason)*       ("lease_close", tool)*
    ("schedule", paths, costs, budget[, step])*
    ("adaptive", now[, {AdaptiveConfig kwargs}])*
    ("flush",)

The ``adaptive`` op polls a scenario-scoped ``AdaptiveController``
(created on first use from the op's config kwargs) and records the
rendered ``PressureEvent`` actions — the closed loop replayed through
the same public surface on every backend.

Starred ops record an observation; every replay ends with a flush (a
no-op on synchronous backends) and the final tree audit, so async
backends are compared at an epoch boundary — their bit-exactness
contract.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend)
from repro.core.events import Ev
from repro.core.intent import Hint
from repro.core.progs import GraduatedThrottleProgram, TokenBucketProgram

__all__ = ["Scenario", "ConformanceSuite", "ConformanceReport",
           "ScenarioResult", "OpRecorder", "replay", "get_scenario",
           "standard_backend_factory", "faulty_backend_factory",
           "backend_features", "BACKEND_KINDS", "STANDARD_SCENARIOS"]

# Event kinds every backend emits identically (lifecycle + intent).
# Breach/throttle counters (HIGH_BREACH/MAX_BREACH/THROTTLE) live
# in-step on the device backends, so they only appear host-side and are
# compared via the feature-gated full stream instead.
PORTABLE_EVENT_KINDS = frozenset({Ev.CREATE, Ev.REMOVE, Ev.FREEZE, Ev.THAW,
                                  Ev.OOM_KILL, Ev.OOM, Ev.FEEDBACK, Ev.DONE,
                                  Ev.PRESSURE})


# --------------------------------------------------------------- scenarios


@dataclass(frozen=True)
class Scenario:
    """A named, declarative op sequence plus the programs it attaches."""
    name: str
    ops: tuple
    programs: dict = field(default_factory=dict)     # key -> () -> program
    capacity: int = 500
    n_domains: int = 16
    requires: frozenset = frozenset()                # backend feature flags
    description: str = ""
    # PSI meter window override (avg10, avg60) in facade-clock units —
    # scenarios exercising pressure decay use short horizons so rises
    # and restores happen within a replayable op count
    pressure_windows: Optional[tuple] = None


def replay(cg: AgentCgroup, scenario: Scenario) -> list:
    """Drive ``scenario`` through the facade; return the observation
    stream ``[(op_idx, op_name, value), ...]`` ending with the final
    usage/peak audit of every surviving path (op_idx -1)."""
    obs: list = []
    leases: dict = {}
    adaptive = None
    if scenario.pressure_windows is not None:
        cg.pressure_clock(windows=scenario.pressure_windows)
    for i, op in enumerate(scenario.ops):
        name, *a = op
        if name == "mkdir":
            cg.mkdir(a[0], DomainSpec(**(a[1] if len(a) > 1 else {})))
        elif name == "charge":
            step = a[2] if len(a) > 2 else i
            t = cg.try_charge(a[0], a[1], step=step)
            obs.append((i, "charge",
                        (t.granted, t.stalled, round(t.delay_ms, 3))))
        elif name == "uncharge":
            cg.uncharge(a[0], a[1])
        elif name == "unchecked":
            cg.charge_unchecked(a[0], a[1])
        elif name == "freeze":
            cg.freeze(a[0])
        elif name == "thaw":
            cg.thaw(a[0])
        elif name == "kill":
            obs.append((i, "kill", cg.kill(a[0])))
        elif name == "rmdir":
            transfer = a[1] if len(a) > 1 else True
            obs.append((i, "rmdir",
                        cg.rmdir(a[0], transfer_residual=transfer)))
        elif name == "write":
            cg.write(a[0], a[1], a[2])
        elif name == "read":
            obs.append((i, "read", (a[0], a[1], cg.read(a[0], a[1]))))
        elif name == "usage":
            obs.append((i, "usage", (a[0], cg.usage(a[0]))))
        elif name == "peak":
            obs.append((i, "peak", (a[0], cg.peak(a[0]))))
        elif name == "exists":
            obs.append((i, "exists", (a[0], cg.exists(a[0]))))
        elif name == "attach":
            cg.attach(a[0], scenario.programs[a[1]]())
        elif name == "update_params":
            cg.update_params(a[0], **a[1])
        elif name == "set_time":
            cg.set_time(a[0])
        elif name == "lease_open":
            hint = Hint[a[1]] if a[1] else None
            kw = a[3] if len(a) > 3 else {}
            leases[a[0]] = cg.intent.declare(a[0], hint, parent=a[2], **kw)
        elif name == "lease_feedback":
            fb = leases[a[0]].feedback(a[1])
            obs.append((i, "lease_feedback",
                        (fb.reason, fb.peak_pages, fb.limit_pages)))
        elif name == "lease_close":
            obs.append((i, "lease_close", leases[a[0]].close()))
        elif name == "schedule":
            step = a[3] if len(a) > 3 else i
            adv = cg.schedule(list(a[0]), list(a[1]), step, a[2])
            obs.append((i, "schedule", tuple(bool(x) for x in adv)))
        elif name == "adaptive":
            if adaptive is None:
                from repro.core.adaptive import (AdaptiveConfig,
                                                 AdaptiveController)
                adaptive = AdaptiveController(
                    cg, AdaptiveConfig(**(a[1] if len(a) > 1 else {})))
            acts = adaptive.poll(a[0])
            if acts:                 # quiet polls record nothing
                obs.append((i, "adaptive",
                            tuple(e.render() for e in acts)))
        elif name == "flush":
            cg.flush()
        else:
            raise ValueError(f"unknown conformance op {name!r}")
    cg.flush()                     # epoch boundary: async == sync from here
    for path in sorted(cg.paths()):
        obs.append((-1, "final", (path, cg.usage(path), cg.peak(path))))
    # event-log audit (kind sequences, never timestamps): the portable
    # lifecycle stream is compared on every backend; the full stream
    # (breach/throttle counters) only where the backend surfaces it
    events = list(cg.log.events)
    obs.append((-2, "events_lifecycle",
                tuple((e.kind.value, e.domain) for e in events
                      if e.kind in PORTABLE_EVENT_KINDS)))
    obs.append((-2, "events_all",
                tuple((e.kind.value, e.domain) for e in events)))
    return obs


class OpRecorder:
    """Records facade calls into a declarative op list that ``replay``
    reproduces — drive a live ``AgentCgroup`` once, keep the scenario."""

    def __init__(self, cg: AgentCgroup):
        self.cg = cg
        self.ops: list = []

    def mkdir(self, path: str, **kw) -> int:
        self.ops.append(("mkdir", path, dict(kw)))
        return self.cg.mkdir(path, DomainSpec(**kw))

    def try_charge(self, path: str, pages: int, step: Optional[int] = None):
        # the step (explicit None = facade clock) replays verbatim
        self.ops.append(("charge", path, pages, step))
        return self.cg.try_charge(path, pages, step=step)

    def uncharge(self, path: str, pages: int) -> None:
        self.ops.append(("uncharge", path, pages))
        self.cg.uncharge(path, pages)

    def charge_unchecked(self, path: str, pages: int) -> None:
        self.ops.append(("unchecked", path, pages))
        self.cg.charge_unchecked(path, pages)

    def freeze(self, path: str) -> None:
        self.ops.append(("freeze", path))
        self.cg.freeze(path)

    def thaw(self, path: str) -> None:
        self.ops.append(("thaw", path))
        self.cg.thaw(path)

    def kill(self, path: str) -> int:
        self.ops.append(("kill", path))
        return self.cg.kill(path)

    def rmdir(self, path: str, *, transfer_residual: bool = True) -> int:
        self.ops.append(("rmdir", path, transfer_residual))
        return self.cg.rmdir(path, transfer_residual=transfer_residual)

    def write(self, path: str, file: str, value) -> None:
        self.ops.append(("write", path, file, value))
        self.cg.write(path, file, value)

    def read(self, path: str, file: str):
        self.ops.append(("read", path, file))
        return self.cg.read(path, file)

    def to_scenario(self, name: str, **kw) -> Scenario:
        return Scenario(name=name, ops=tuple(self.ops), **kw)


# ----------------------------------------------------- standard scenarios


def _zero_delay() -> GraduatedThrottleProgram:
    """Grant/deny semantics isolated from op timing."""
    return GraduatedThrottleProgram(base_delay_ms=0.0, max_delay_ms=0.0)


def _weighted_fair():
    """Scheduler semantics isolated from throttle timing."""
    from repro.core.sched import WeightedFairProgram
    return WeightedFairProgram(base_delay_ms=0.0, max_delay_ms=0.0)


def _sched_rounds(paths: tuple, costs: tuple, budget: int,
                  steps) -> tuple:
    return tuple(("schedule", paths, costs, budget, s) for s in steps)


def _throttling_fair():
    """Weighted scheduler WITH the stock graduated throttle — the
    pressure scenarios need real stall events on both resources."""
    from repro.core.sched import WeightedFairProgram
    return WeightedFairProgram()


def _pressure_ramp_ops() -> tuple:
    """Stalls on both resources under a ticking facade clock, with the
    PSI file surface read at three probe times."""
    ops = [("attach", "/", "wfair_t"),
           ("mkdir", "/t"),
           ("mkdir", "/t/a", {"high": 40}),
           ("mkdir", "/t/b", {"max": 100, "priority": D.LOW})]
    for t in range(20):
        ops.append(("set_time", float(t * 10)))
        ops.append(("charge", "/t/a", 10, t))     # over high=40 from t=4
        ops.append(("charge", "/t/b", 20, t))     # max=100 wall from t=5
        # 1-cost budget: the losing slot is a CPU-stall event
        ops.append(("schedule", ("/t/a", "/t/b"), (1, 1), 1, t))
        if t in (5, 10, 19):
            for f in ("memory.stall", "cpu.stall",
                      "memory.pressure", "cpu.pressure"):
                ops.append(("read", "/t", f))
            ops.append(("read", "/t/a", "memory.pressure"))
    return tuple(ops)


# the adaptive scenario's closed-loop config: bump /t/a's soft limit
# under sustained memory pressure (2x per bump, hard-capped by
# memory.max), restore once pressure decays below the low threshold
_ADAPTIVE_CFG = {"high_frac": 0.15, "low_frac": 0.05, "bump_factor": 2.0,
                 "max_bumps": 3, "cooldown_ms": 40.0, "watch": ("/t/a",)}


def _adaptive_retune_ops() -> tuple:
    ops = [("attach", "/", "wfair_t"),
           ("mkdir", "/t"),
           ("mkdir", "/t/a", {"high": 40, "max": 200})]
    for t in range(30):               # pressured phase: stall every step
        ops.append(("set_time", float(t * 10)))
        ops.append(("charge", "/t/a", 8, t))
        ops.append(("adaptive", float(t * 10), _ADAPTIVE_CFG))
    ops.append(("read", "/t/a", "memory.high"))
    for t in range(30, 80):           # calm phase: pressure decays
        ops.append(("set_time", float(t * 10)))
        ops.append(("adaptive", float(t * 10), _ADAPTIVE_CFG))
    ops.append(("read", "/t/a", "memory.high"))
    ops.append(("read", "/t/a", "memory.stall"))
    return tuple(ops)


def _std_tree(*extra) -> tuple:
    return (("mkdir", "/t"),
            ("mkdir", "/t/a", {"high": 120}),
            ("mkdir", "/t/b", {"max": 200, "priority": D.LOW}),
            ("mkdir", "/t/a/tool", {"high": 40})) + extra


_AUDIT = (("usage", "/"), ("usage", "/t"), ("usage", "/t/a"),
          ("usage", "/t/b"), ("peak", "/"), ("peak", "/t"),
          ("peak", "/t/a"), ("peak", "/t/b"))

STANDARD_SCENARIOS: tuple = (
    Scenario(
        "lifecycle",
        description="the canonical charge/deny/uncharge/freeze/thaw/"
                    "rmdir-residual/unchecked sequence (PR-2 golden ops)",
        programs={"zero": _zero_delay},
        ops=(("attach", "/", "zero"),) + _std_tree(
            ("charge", "/t/a/tool", 60),      # grant; over tool high
            ("charge", "/t/b", 150),          # grant
            ("charge", "/t/b", 100),          # deny: /t/b max=200
            ("uncharge", "/t/b", 50),
            ("charge", "/t/b", 100),          # grant now
            ("freeze", "/t/a"),
            ("charge", "/t/a/tool", 5),       # deny: frozen ancestor
            ("thaw", "/t/a"),
            ("charge", "/t/a/tool", 5),       # grant again
            ("rmdir", "/t/a/tool"),           # residual 65 -> /t/a
            ("unchecked", "/t/a", 20),        # lifecycle bookkeeping
            ("uncharge", "/t/a", 30),
            ("charge", "/t/a", 400),          # deny: root capacity 500
        ) + _AUDIT),
    Scenario(
        "residual_transfer",
        description="closing a non-empty tool domain keeps its pages "
                    "accounted to the session chain",
        programs={"zero": _zero_delay},
        ops=(("attach", "/", "zero"),
             ("mkdir", "/s"), ("mkdir", "/s/tool", {"high": 40}),
             ("charge", "/s/tool", 30),
             ("rmdir", "/s/tool"),
             ("exists", "/s/tool"),
             ("usage", "/s"), ("usage", "/"))),
    Scenario(
        "rmdir_release",
        programs={"zero": _zero_delay},
        ops=(("attach", "/", "zero"),
             ("mkdir", "/s"), ("mkdir", "/s/tool"),
             ("charge", "/s/tool", 30),
             ("rmdir", "/s/tool", False),
             ("usage", "/s"), ("usage", "/"))),
    Scenario(
        "freeze_thaw_recharge",
        description="the engine's freeze path: offload (uncharge) + "
                    "freeze, then thaw + unchecked re-charge round-trips",
        programs={"zero": _zero_delay},
        ops=(("attach", "/", "zero"),
             ("mkdir", "/s"), ("mkdir", "/s/sess"),
             ("charge", "/s/sess", 80),
             ("usage", "/"), ("usage", "/s"), ("usage", "/s/sess"),
             ("uncharge", "/s/sess", 80),
             ("freeze", "/s/sess"),
             ("charge", "/s/sess", 1),        # deny: frozen
             ("usage", "/"),
             ("thaw", "/s/sess"),
             ("unchecked", "/s/sess", 80),
             ("usage", "/"), ("usage", "/s"), ("usage", "/s/sess"))),
    Scenario(
        "kill_subtree",
        description="killed domains stay registered and deny charges",
        programs={"zero": _zero_delay},
        ops=(("attach", "/", "zero"),
             ("mkdir", "/s"), ("mkdir", "/s/a"),
             ("charge", "/s/a", 40), ("charge", "/s", 10),
             ("kill", "/s"),
             ("usage", "/"),
             ("exists", "/s"), ("exists", "/s/a"),
             ("charge", "/s", 5), ("charge", "/s/a", 5))),
    Scenario(
        "graduated_throttle",
        description="over-high charges impose graduated windows; charges "
                    "inside a window stall; windows expire with the clock",
        programs={"grad": GraduatedThrottleProgram},
        ops=(("attach", "/", "grad"),) + _std_tree(
            ("charge", "/t/a/tool", 60, 0),   # over tool high=40 -> window
            ("charge", "/t/a/tool", 5, 1),    # inside the window
            ("charge", "/t/b", 150, 2),
            ("charge", "/t/b", 100, 3),       # max=200 wall
            ("charge", "/t/b", 30, 4),
            ("charge", "/t/a/tool", 5, 8),
            ("charge", "/t/a/tool", 5, 12),   # after the window
            ("charge", "/t/b", 10, 20),
        ) + _AUDIT),
    Scenario(
        "token_bucket",
        description="pages-per-step pacing with per-priority refill, "
                    "across multiple tenant subtrees (multi-shard when "
                    "the backend shards)",
        programs={"bucket": lambda: TokenBucketProgram(
            bucket_capacity=16, refill=(1.0, 2.0, 4.0))},
        capacity=10_000,
        ops=(("attach", "/", "bucket"),
             ("mkdir", "/t0"), ("mkdir", "/t1"), ("mkdir", "/t2"),
             ("mkdir", "/t2/s", {"priority": D.LOW}),
             ("charge", "/t2", 16, 0),        # drains /t2's bucket
             ("charge", "/t2", 8, 1),
             ("charge", "/t2", 4, 2),
             ("charge", "/t2", 2, 3),
             ("charge", "/t0", 16, 4),
             ("charge", "/t2", 30, 5),
             ("charge", "/t2/s", 16, 6),
             ("charge", "/t2/s", 2, 7),       # LOW refill: 1/step
             ("charge", "/t1", 16, 8),
             ("usage", "/"), ("usage", "/t0"), ("usage", "/t1"),
             ("usage", "/t2"))),
    Scenario(
        "attach_retune",
        description="update_params writes the subtree; new children "
                    "inherit the parent's live row",
        programs={"grad": GraduatedThrottleProgram},
        ops=(("attach", "/", "grad"),
             ("mkdir", "/t"), ("mkdir", "/t/a", {"high": 40}),
             ("update_params", "/t", {"base_delay_ms": 40.0}),
             ("mkdir", "/t/a/kid", {"high": 10}),
             ("charge", "/t/a/kid", 20, 0),   # over 1.0 -> 40*(1+10) = 440
             ("charge", "/t/a/kid", 1, 5),    # inside the window
             ("charge", "/t/a/kid", 1, 60),   # window (44 steps) expired
             ("update_params", "/", {"base_delay_ms": 0.0,
                                     "max_delay_ms": 0.0}),
             ("charge", "/t/a/kid", 50, 61))),
    Scenario(
        "attach_scope",
        description="a subtree attach composes: only in-scope domains "
                    "switch to the attached program; out-of-scope domains "
                    "keep the program (and live row) they already had",
        programs={"bucket4": lambda: TokenBucketProgram(
            bucket_capacity=4, refill=(1.0, 1.0, 1.0))},
        capacity=10_000,
        ops=(("mkdir", "/scoped"), ("mkdir", "/free"),
             ("attach", "/scoped", "bucket4"),
             ("charge", "/scoped", 50, 0),    # deny: bucketed
             ("charge", "/free", 50, 0))),    # grant: prior program kept
    Scenario(
        "multi_program",
        description="two tenants run different policy programs "
                    "concurrently in one hierarchy: a subtree attach "
                    "gives /bkt the token bucket while /grad keeps the "
                    "graduated root program; children created after the "
                    "attach inherit the parent's program slot, and "
                    "update_params resolves each path through its own "
                    "program's parameter columns",
        programs={"grad": GraduatedThrottleProgram,
                  "bucket4": lambda: TokenBucketProgram(
                      bucket_capacity=4, refill=(1.0, 1.0, 1.0))},
        capacity=10_000,
        ops=(("attach", "/", "grad"),
             ("mkdir", "/grad"), ("mkdir", "/bkt"),
             ("attach", "/bkt", "bucket4"),
             ("mkdir", "/grad/s", {"high": 10}),
             ("mkdir", "/bkt/s"),             # inherits the bucket slot
             ("charge", "/bkt/s", 6, 0),      # deny: bucket holds 4
             ("charge", "/bkt/s", 3, 0),      # grant: within the bucket
             ("charge", "/grad/s", 20, 0),    # grant + graduated throttle
             ("charge", "/grad/s", 1, 1),     # deny: inside the window
             ("update_params", "/bkt", {"bucket_capacity": 50.0,
                                        "bucket_level": 50.0}),
             ("charge", "/bkt/s", 30, 5),     # grant: retuned bucket
             ("update_params", "/grad", {"base_delay_ms": 0.0,
                                         "max_delay_ms": 0.0}),
             ("charge", "/grad/s", 1, 200),   # grant: throttle retuned off
             ("usage", "/"), ("usage", "/grad"), ("usage", "/bkt"))),
    Scenario(
        "memcg_events",
        description="full memcg event counters (host-class backends)",
        requires=frozenset({"events"}),
        programs={"grad": GraduatedThrottleProgram},
        ops=(("attach", "/", "grad"),
             ("mkdir", "/s", {"high": 10, "max": 50}),
             ("charge", "/s", 20, 0),         # high breach + throttle
             ("charge", "/s", 100, 1),        # max breach
             ("read", "/s", "memory.events"))),
    Scenario(
        "intent_lease",
        description="lease lifecycle: hint-derived high, feedback "
                    "record, residual moves up on close, idempotent",
        ops=(("mkdir", "/sess"),
             ("lease_open", "tool_1", "LOW", "/sess"),
             ("exists", "/sess/tool_1"),
             ("read", "/sess/tool_1", "memory.high"),
             ("charge", "/sess/tool_1", 25),
             ("lease_feedback", "tool_1", "throttled"),
             ("lease_close", "tool_1"),
             ("exists", "/sess/tool_1"),
             ("usage", "/sess"),
             ("lease_close", "tool_1"))),     # idempotent: 0
    Scenario(
        "control_files",
        description="the cgroupfs file surface, including freeze-by-write",
        ops=(("mkdir", "/s", {"high": 100, "max": 200, "low": 10,
                              "priority": D.HIGH}),
             ("read", "/s", "memory.high"), ("read", "/s", "memory.max"),
             ("read", "/s", "memory.low"),
             ("read", "/s", "memory.priority"),
             ("write", "/s", "memory.high", 50),
             ("read", "/s", "memory.high"),
             ("write", "/s", "cgroup.freeze", 1),
             ("read", "/s", "cgroup.freeze"),
             ("charge", "/s", 1),             # deny: frozen
             ("write", "/s", "cgroup.freeze", 0),
             ("charge", "/s", 1))),           # grant
    Scenario(
        "cpu_weight_fair",
        description="weighted step scheduler: a 300/100 cpu.weight split "
                    "grants 3:1 under a 1-slot budget; a live cpu.weight "
                    "write rebalances with vruntime carried across steps",
        programs={"wfair": _weighted_fair},
        ops=(("attach", "/", "wfair"),
             ("mkdir", "/a", {"weight": 300}),
             ("mkdir", "/b", {"weight": 100}),
             ("read", "/a", "cpu.weight"), ("read", "/b", "cpu.weight"),
             ("read", "/a", "cpu.max"))
            + _sched_rounds(("/a", "/b"), (1, 1), 1, range(8))
            + (("write", "/b", "cpu.weight", 300),
               ("read", "/b", "cpu.weight"))
            + _sched_rounds(("/a", "/b"), (1, 1), 1, range(8, 16))),
    Scenario(
        "cpu_max_quota",
        description="cpu.max as a hard per-window throttle: the capped "
                    "tenant stops advancing once its window quota is "
                    "spent and resumes at the next window (never on the "
                    "root — per-shard roots make that quota diverge)",
        programs={"wfair": _weighted_fair},
        ops=(("attach", "/", "wfair"),
             ("mkdir", "/t"),
             ("mkdir", "/t/a", {"cpu_max": 3}),
             ("mkdir", "/t/b"),
             ("read", "/t/a", "cpu.max"))
            + _sched_rounds(("/t/a", "/t/b"), (1, 1), 8, range(6))
            + _sched_rounds(("/t/a", "/t/b"), (1, 1), 8, (100, 101))),
    Scenario(
        "sched_retune",
        description="update_params(sched_boost=...) retunes a tenant's "
                    "effective weight live — the zero-retrace knob — and "
                    "freeze removes a slot from the runnable set",
        programs={"wfair": _weighted_fair},
        ops=(("attach", "/", "wfair"),
             ("mkdir", "/a"), ("mkdir", "/b"))
            + _sched_rounds(("/a", "/b"), (1, 1), 1, range(4))
            + (("update_params", "/a", {"sched_boost": 2.0}),)
            + _sched_rounds(("/a", "/b"), (1, 1), 1, range(4, 14))
            + (("freeze", "/a"),)
            + _sched_rounds(("/a", "/b"), (1, 1), 1, range(14, 17))
            + (("thaw", "/a"),)
            + _sched_rounds(("/a", "/b"), (1, 1), 1, range(17, 20))),
    Scenario(
        "pressure_ramp",
        description="PSI-style pressure accounting: stall events from "
                    "throttled charges, max-wall denials and lost "
                    "scheduling rounds accumulate per domain, roll up "
                    "the hierarchy, and render identical avg10/avg60 "
                    "strings on every backend",
        programs={"wfair_t": _throttling_fair},
        pressure_windows=(200.0, 1000.0),
        ops=_pressure_ramp_ops()),
    Scenario(
        "adaptive_retune",
        description="closed loop over the public PSI surface: sustained "
                    "memory pressure bumps memory.high (never past "
                    "memory.max), decay restores it — with hysteresis "
                    "and per-domain cooldown",
        programs={"wfair_t": _throttling_fair},
        pressure_windows=(200.0, 1000.0),
        ops=_adaptive_retune_ops()),
)

_BY_NAME = {s.name: s for s in STANDARD_SCENARIOS}


def get_scenario(name: str) -> Scenario:
    return _BY_NAME[name]


# ------------------------------------------------------ factories/features

BACKEND_KINDS = ("host", "device", "sharded",
                 "async-host", "async-device", "async-sharded")


def standard_backend_factory(kind: str) -> Callable:
    """``kind -> (capacity, n_domains) -> Backend`` for the repo's four
    backend families (``async-*`` wraps the named inner backend)."""

    def make(capacity: int, n_domains: int):
        if kind == "host":
            return HostTreeBackend(capacity)
        if kind == "device":
            return DeviceTableBackend(capacity, n_domains=n_domains)
        if kind == "sharded":
            from repro.core.sharded import ShardedTableBackend
            return ShardedTableBackend(capacity, n_domains=n_domains)
        if kind.startswith("async-"):
            from repro.core.daemon import AsyncDaemonBackend
            inner = standard_backend_factory(
                kind[len("async-"):])(capacity, n_domains)
            return AsyncDaemonBackend(inner)
        raise ValueError(f"unknown backend kind {kind!r}")

    make.kind = kind
    return make


def faulty_backend_factory(kind: str, plan=None, *, auto_retry: int = 0,
                           on_spurious_kill: Optional[Callable] = None
                           ) -> Callable:
    """``FaultyBackend``-wrapped variant of a standard backend kind.
    The wrapper sits directly around the synchronous inner backend, so
    for ``async-*`` kinds injected faults fire on the daemon thread
    (a wedge there poisons the daemon — the realistic failure mode).
    With the default fault-free plan the factory must pass the
    conformance suite bit-exact — certified in ``tests/test_faults.py``.
    """

    def make(capacity: int, n_domains: int):
        from repro.core.faults import FaultyBackend
        inner_kind = kind[len("async-"):] if kind.startswith("async-") \
            else kind
        faulty = FaultyBackend(
            standard_backend_factory(inner_kind)(capacity, n_domains),
            plan, auto_retry=auto_retry, on_spurious_kill=on_spurious_kill)
        if kind.startswith("async-"):
            from repro.core.daemon import AsyncDaemonBackend
            return AsyncDaemonBackend(faulty)
        return faulty

    make.kind = f"faulty-{kind}"
    return make


def backend_features(kind: str) -> frozenset:
    """Feature flags a standard backend supports: the host tree (and the
    async daemon over it) surfaces full memcg event counters."""
    return frozenset({"events"}) if kind.endswith("host") else frozenset()


# ----------------------------------------------------------------- runner


@dataclass
class ScenarioResult:
    name: str
    ok: bool
    skipped: bool = False
    mismatches: list = field(default_factory=list)


@dataclass
class ConformanceReport:
    backend: str
    results: list = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    def summary(self) -> str:
        lines = [f"conformance[{self.backend}]:"]
        for r in self.results:
            if r.skipped:
                lines.append(f"  {r.name}: SKIPPED (missing feature)")
            elif r.ok:
                lines.append(f"  {r.name}: ok")
            else:
                lines.append(f"  {r.name}: {len(r.mismatches)} mismatch(es)")
                lines.extend(f"    {m}" for m in r.mismatches[:8])
        return "\n".join(lines)


class ConformanceSuite:
    """Replays scenarios against a backend under test and the reference
    backend, diffing observation streams.  Reference observations are
    cached per scenario, so one suite instance can certify many
    backends cheaply."""

    def __init__(self, scenarios: Optional[Sequence[Scenario]] = None,
                 reference: Optional[Callable] = None):
        self.scenarios = (list(scenarios) if scenarios is not None
                          else list(STANDARD_SCENARIOS))
        self.reference = reference or (lambda cap, n: HostTreeBackend(cap))
        self._ref_obs: dict[str, list] = {}

    def _reference_obs(self, scenario: Scenario) -> list:
        if scenario.name not in self._ref_obs:
            backend = self.reference(scenario.capacity, scenario.n_domains)
            try:
                self._ref_obs[scenario.name] = replay(AgentCgroup(backend),
                                                      scenario)
            finally:
                close = getattr(backend, "close", None)
                if close is not None:
                    close()
        return self._ref_obs[scenario.name]

    def run(self, backend_factory: Callable, *,
            features: frozenset = frozenset(),
            scenarios: Optional[Sequence[str]] = None,
            raise_on_failure: bool = False) -> ConformanceReport:
        name = getattr(backend_factory, "kind",
                       getattr(backend_factory, "__name__", "backend"))
        report = ConformanceReport(backend=name)
        for sc in self.scenarios:
            if scenarios is not None and sc.name not in scenarios:
                continue
            if not sc.requires <= frozenset(features):
                report.results.append(ScenarioResult(sc.name, True,
                                                     skipped=True))
                continue
            backend = backend_factory(sc.capacity, sc.n_domains)
            try:
                got = replay(AgentCgroup(backend), sc)
            finally:
                close = getattr(backend, "close", None)
                if close is not None:
                    close()                  # stop async daemon threads
            want = self._reference_obs(sc)
            # the full event stream includes host-only breach/throttle
            # kinds — only comparable when the backend surfaces them
            if "events" not in features:
                got = [r for r in got if r[1] != "events_all"]
                want = [r for r in want if r[1] != "events_all"]
            mism = [f"op {gi}/{gn}: got {gv!r} want {wv!r}"
                    for (gi, gn, gv), (wi, wn, wv) in zip(got, want)
                    if (gi, gn, gv) != (wi, wn, wv)]
            if len(got) != len(want):
                mism.append(f"observation count {len(got)} != {len(want)}")
            report.results.append(ScenarioResult(sc.name, not mism,
                                                 mismatches=mism))
        if raise_on_failure and not report.ok:
            raise AssertionError(report.summary())
        return report

"""Reusable test kits for the AgentCgroup control plane.

``repro.testing.conformance`` is the backend-certification kit: any
``Backend`` implementation proves itself bit-identical to the reference
host-tree semantics by replaying the standard scenario set through one
parametrized fixture.
"""
from repro.testing.conformance import (BACKEND_KINDS, ConformanceReport,
                                       ConformanceSuite, OpRecorder,
                                       Scenario, ScenarioResult,
                                       STANDARD_SCENARIOS, backend_features,
                                       get_scenario, replay,
                                       standard_backend_factory)

__all__ = [
    "BACKEND_KINDS", "ConformanceReport", "ConformanceSuite", "OpRecorder",
    "Scenario", "ScenarioResult", "STANDARD_SCENARIOS", "backend_features",
    "get_scenario", "replay", "standard_backend_factory",
]

"""Deterministic synthetic LM data pipeline.

Batches are a pure function of (seed, step): restart-safe without data-
state checkpointing — after resume, step N yields bit-identical batches.
Documents are variable-length and packed into fixed sequences with EOS
boundaries; loss weights mask padding and (for VLM) patch positions.
Audio (encoder-only) batches carry frame embeddings + a mask for
masked-prediction; vision batches carry patch embeddings.

With a mesh, ``shard_batch`` places each array under its logical
activation sharding so jit consumes pre-sharded inputs (no implicit
broadcast from host).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

EOS = 1


def _doc_lengths(rng: np.random.Generator, total: int) -> list[int]:
    """Pack variable-length 'documents' (lognormal lengths) into total."""
    out, used = [], 0
    while used < total:
        ln = int(np.clip(rng.lognormal(5.0, 1.0), 16, total - used or 16))
        ln = min(ln, total - used)
        out.append(ln)
        used += ln
    return out


def make_batch(cfg: ModelConfig, shape: ShapeConfig, *, seed: int,
               step: int, batch: Optional[int] = None,
               seq: Optional[int] = None) -> dict:
    """One training batch as numpy (host) arrays."""
    B = batch or shape.global_batch
    S = seq or shape.seq_len
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    out: dict = {}
    if cfg.frontend == "audio":
        frames = rng.standard_normal((B, S, cfg.d_model), np.float32)
        mask = rng.random((B, S)) < 0.3           # masked-prediction targets
        labels = rng.integers(0, cfg.vocab, (B, S), dtype=np.int64)
        out = {"frames": frames.astype(np.float32), "mask": mask,
               "labels": labels.astype(np.int32),
               "weights": mask.astype(np.float32)}
        return out
    # learnable documents: a SEED-fixed bigram permutation with a noise
    # floor — stable across steps, so CE falls below ln(V) within tens
    # of steps on the reduced configs (used by convergence tests)
    V = cfg.vocab - 2
    perm = np.random.default_rng(seed ^ 0x5EED).permutation(V)
    toks = np.empty((B, S + 1), np.int64)
    noise = rng.random((B, S + 1)) < 0.1
    toks[:, 0] = rng.integers(0, V, B)
    for i in range(1, S + 1):
        nxt = perm[toks[:, i - 1]]
        rnd = rng.integers(0, V, B)
        toks[:, i] = np.where(noise[:, i], rnd, nxt)
    toks += 2
    weights = np.ones((B, S), np.float32)
    for b in range(B):
        pos = 0
        for ln in _doc_lengths(rng, S + 1):
            end = pos + ln
            if end <= S:
                toks[b, end - 1] = EOS
                weights[b, end - 1] = 0.0          # no loss across doc joins
            pos = end
    out["tokens"] = toks[:, :S].astype(np.int32)
    out["labels"] = toks[:, 1:S + 1].astype(np.int32)
    out["weights"] = weights
    if cfg.frontend == "vision":
        n = min(cfg.n_frontend_tokens, S)
        out["patches"] = rng.standard_normal(
            (B, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32)
        out["weights"][:, :n] = 0.0                # no LM loss on patches
    return out


def batch_sharding(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   rules: dict) -> dict:
    from repro.models.model import batch_spec_leaves
    leaves = batch_spec_leaves(cfg, shape)
    return {k: NamedSharding(mesh, l.pspec(rules)) for k, l in leaves.items()}


def shard_batch(batch: dict, shardings: Optional[dict] = None) -> dict:
    if shardings is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    return {k: jax.device_put(jnp.asarray(v), shardings[k])
            for k, v in batch.items()}


class DataIterator:
    """Stateless-by-construction iterator: batch(step) is pure."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *, seed: int = 0,
                 batch: Optional[int] = None, seq: Optional[int] = None,
                 shardings: Optional[dict] = None):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.batch, self.seq = batch, seq
        self.shardings = shardings

    def at(self, step: int) -> dict:
        b = make_batch(self.cfg, self.shape, seed=self.seed, step=step,
                       batch=self.batch, seq=self.seq)
        return shard_batch(b, self.shardings)

"""Runtime / performance knobs, separate from architecture configs.

Arch configs (src/repro/configs) are the assignment's fixed facts; a
``PerfConfig`` holds everything the §Perf hillclimb is allowed to turn:
kernel implementation choices, block sizes, dispatch algorithms, remat
policy, sharding rule-set names.  The paper-faithful baseline is
``DEFAULT_PERF``; hillclimb iterations construct variants via
``dataclasses.replace`` and record them in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class PerfConfig:
    # attention implementation: blockwise (pure-JAX flash, CPU/dry-run),
    # naive (O(S^2) oracle, tests only), pallas (TPU kernel / interpret)
    attn_impl: str = "blockwise"
    block_q: int = 512
    block_k: int = 1024
    # MoE dispatch: a2a (shard_map all-to-all expert parallelism — the
    # shipping default; falls back to gather without a mesh), gather
    # (capacity dispatch under pure GSPMD), dense (naive comparison):
    moe_impl: str = "a2a"
    capacity_factor: float = 1.25
    # rematerialisation policy for the scanned layer groups
    remat: str = "dots"          # none | dots | full
    # sharding rule-set names (see models/schema.RULES + launch/mesh.py)
    rules_train: str = "train"
    rules_serve: str = "serve"
    # training extras
    zero1: bool = True           # shard optimizer state over data axis
    grad_compress: bool = False  # int8 all-reduce with error feedback
    microbatches: int = 1        # gradient-accumulation splits
    # ssm / xlstm chunked-scan block
    scan_chunk: int = 256


DEFAULT_PERF = PerfConfig()


def replace(perf: PerfConfig, **kw) -> PerfConfig:
    return dataclasses.replace(perf, **kw)

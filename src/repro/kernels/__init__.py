"""Pallas TPU kernels for the compute hot-spots (flash attention,
flash/paged decode, chunked SSD scan) + ops.py backend dispatch and
ref.py pure-jnp oracles.  Kernels target TPU (BlockSpec VMEM tiling,
MXU-aligned dots) and are validated in interpret mode on CPU."""

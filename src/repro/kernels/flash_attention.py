"""FlashAttention TPU kernel (pl.pallas_call + BlockSpec VMEM tiling).

Design (TPU-native, not a CUDA port):
  * grid = (B, H, nq, nk); the last axis is the sequential reduction axis
    (``arbitrary`` dimension semantics) so the fp32 accumulator scratch
    persists across kv blocks — the online-softmax state never leaves
    VMEM.
  * q/k/v blocks are (bq, dk) / (bk, dk) VMEM tiles; matmul dims are
    multiples of 128 at the production block sizes (bq=512, bk=1024,
    dk 64..192) so both dots land on the MXU.
  * causal block-skip via ``pl.when`` — blocks strictly above the
    diagonal issue no MXU work.
  * GQA without KV expansion: the k/v index_map folds the q-head index
    onto its kv head (h // g), so KV tiles are fetched once per group.

Validated in interpret mode against ``ref.attention_naive`` (tests/).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _compute():
        qb = q_ref[0, :, 0, :].astype(jnp.float32) * scale      # (bq, dk)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)              # (bk, dk)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)              # (bk, dv)
        s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]                                      # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(vb.dtype), vb, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip kv blocks strictly above this q block's diagonal
        pl.when(ik * bk <= iq * bq + bq - 1)(_compute)
    else:
        _compute()

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, :, 0, :] = (acc_ref[...] /
                             jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: Optional[float] = None,
                           block_q: int = 512, block_k: int = 1024,
                           interpret: bool = False):
    """q: (B,S,H,dk)  k/v: (B,Sk,Hkv,d)  ->  (B,S,H,dv)."""
    B, S, H, dk = q.shape
    Sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    assert H % hkv == 0
    g = H // hkv
    scale = scale or dk ** -0.5
    bq, bk = min(block_q, S), min(block_k, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, bq, Sk, bk)
    nq, nk = S // bq, Sk // bk
    grid = (B, H, nq, nk)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, dk), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, dk), lambda b, h, iq, ik: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, dv), lambda b, h, iq, ik: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dv), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),   # acc
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention_fwd",
    )(q, k, v)
    return out

"""Backend dispatch for the compute hot-spots.

Every op has three implementations:
  * ``naive``      — smallest oracle (tests only; O(S^2) memory etc.)
  * ``blockwise``  — pure-JAX production path (CPU smoke tests + dry-run
                     lowering; same math the Pallas kernel implements)
  * ``pallas``     — TPU kernel (``pl.pallas_call`` + BlockSpec).  On CPU
                     it runs in interpret mode when
                     ``REPRO_FORCE_PALLAS_INTERPRET=1`` (kernel tests).

``impl=None`` resolves to pallas on TPU, blockwise elsewhere.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ref


def _on_tpu() -> bool:
    return compat.on_tpu()


def _interpret() -> bool:
    return compat.force_interpret()


def _resolve(impl: Optional[str]) -> str:
    if impl in (None, "auto"):
        return "pallas" if (_on_tpu() or _interpret()) else "blockwise"
    if impl == "pallas" and not (_on_tpu() or _interpret()):
        # pallas requested but no TPU and no interpreter override: fall back
        return "blockwise"
    return impl


# ------------------------------------------------------------- attention


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    impl: Optional[str] = None,
                    block_q: int = 512, block_k: int = 1024):
    impl = _resolve(impl)
    if impl == "naive":
        return ref.attention_naive(q, k, v, causal=causal, scale=scale)
    if impl == "blockwise":
        return ref.flash_attention_blockwise(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k)
    if impl == "pallas":
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, interpret=not _on_tpu())
    raise ValueError(f"unknown attention impl {impl!r}")


def decode_attention(q, k_cache, v_cache, lengths, *, scale=None,
                     impl: Optional[str] = None):
    """Dense-cache single-token decode (flash-decoding split over S)."""
    impl = _resolve(impl)
    if impl in ("naive", "blockwise"):
        return ref.decode_attention_ref(q, k_cache, v_cache, lengths, scale=scale)
    if impl == "pallas":
        from repro.kernels.decode_attention import decode_attention_pallas
        return decode_attention_pallas(q, k_cache, v_cache, lengths,
                                       scale=scale, interpret=not _on_tpu())
    raise ValueError(f"unknown decode impl {impl!r}")


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths, *,
                           scale=None, impl: Optional[str] = None):
    """Paged-KV single-token decode (the serving engine's fast path)."""
    impl = _resolve(impl)
    if impl in ("naive", "blockwise"):
        return ref.paged_decode_attention_ref(
            q, k_pages, v_pages, page_table, lengths, scale=scale)
    if impl == "pallas":
        from repro.kernels.decode_attention import paged_decode_attention_pallas
        return paged_decode_attention_pallas(
            q, k_pages, v_pages, page_table, lengths, scale=scale,
            interpret=not _on_tpu())
    raise ValueError(f"unknown paged decode impl {impl!r}")


# ------------------------------------------------------------------ SSD


def ssd(x, dt, A, B, C, D, *, chunk: int = 256, h0=None,
        impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "naive":
        return ref.ssd_sequential(x, dt, A, B, C, D, h0=h0)
    if impl == "blockwise":
        return ref.ssd_chunked(x, dt, A, B, C, D, chunk=chunk, h0=h0)
    if impl == "pallas":
        from repro.kernels.mamba_scan import ssd_pallas
        return ssd_pallas(x, dt, A, B, C, D, chunk=chunk, h0=h0,
                          interpret=not _on_tpu())
    raise ValueError(f"unknown ssd impl {impl!r}")


def ssd_decode(h, x, dt, A, B, C, D):
    return ref.ssd_decode_step(h, x, dt, A, B, C, D)


# ---------------------------------------------------------------- mLSTM


def mlstm(q, k, v, i_gate, f_gate, *, chunk: int = 256, state=None,
          impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "naive":
        return ref.mlstm_sequential(q, k, v, i_gate, f_gate, state=state)
    if impl in ("blockwise", "pallas"):
        # the chunked form is already scan-over-chunks and MXU-shaped;
        # it serves as both the blockwise and the TPU production path
        return ref.mlstm_chunked(q, k, v, i_gate, f_gate, chunk=chunk,
                                 state=state)
    raise ValueError(f"unknown mlstm impl {impl!r}")


def mlstm_decode(state, q, k, v, i_gate, f_gate):
    return ref.mlstm_decode_step(state, q, k, v, i_gate, f_gate)

"""Single-token decode attention kernels (flash-decoding on TPU).

Two variants:
  * ``decode_attention_pallas``        — dense per-slot cache
    (B, S_max, Hkv, d), split-K over the sequence: grid's last axis
    walks S blocks sequentially, partial (max, sum, acc) live in VMEM
    scratch, blocks past the sequence length issue no work.
  * ``paged_decode_attention_pallas``  — vLLM-style paged cache.  The
    page table is a *scalar-prefetch* operand
    (``pltpu.PrefetchScalarGridSpec``): the k/v index_map dereferences
    ``page_table[b, j]`` so each grid step DMAs exactly one KV page
    from HBM into VMEM — the TPU analogue of paged attention's
    gather, with no host round trip.

Both are GQA-aware: q is viewed as (B, Hkv, G, dk) and each grid step
attends one kv head's G query heads at once (G x bk MXU dots).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


# ------------------------------------------------------------ dense cache


def _dense_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, bs, ns):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(j * bs < length)
    def _compute():
        qb = q_ref[0, 0].astype(jnp.float32) * scale            # (G, dk)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)              # (bs, dk)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)              # (bs, dv)
        s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bs)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(vb.dtype), vb, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == ns - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, lengths, *,
                            scale: Optional[float] = None,
                            block_s: int = 512, interpret: bool = False):
    """q: (B,H,dk)  caches: (B,S_max,Hkv,d)  lengths: (B,) -> (B,H,dv)."""
    B, H, dk = q.shape
    Smax, hkv, dv = k_cache.shape[1], k_cache.shape[2], v_cache.shape[-1]
    g = H // hkv
    scale = scale or dk ** -0.5
    bs = min(block_s, Smax)
    assert Smax % bs == 0
    ns = Smax // bs
    qg = q.reshape(B, hkv, g, dk)

    kern = functools.partial(_dense_kernel, scale=scale, bs=bs, ns=ns)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, hkv, ns),
            in_specs=[
                pl.BlockSpec((1, 1, g, dk), lambda b, h, j, lens: (b, h, 0, 0)),
                pl.BlockSpec((1, bs, 1, dk), lambda b, h, j, lens: (b, j, h, 0)),
                pl.BlockSpec((1, bs, 1, dv), lambda b, h, j, lens: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, dv),
                                   lambda b, h, j, lens: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, dv), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, hkv, g, dv), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="flash_decode",
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(B, H, dv)


# ------------------------------------------------------------ paged cache


def _paged_kernel(len_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, page, npp):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[b]

    @pl.when(j * page < length)
    def _compute():
        qb = q_ref[0, 0].astype(jnp.float32) * scale            # (G, dk)
        kb = k_ref[0, :, 0, :].astype(jnp.float32)              # (page, dk)
        vb = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
            p.astype(vb.dtype), vb, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == npp - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(q, k_pages, v_pages, page_table, lengths, *,
                                  scale: Optional[float] = None,
                                  interpret: bool = False):
    """q: (B,H,dk)  pages: (n_pages, page, Hkv, d)  page_table: (B, npp)."""
    B, H, dk = q.shape
    page, hkv, dv = k_pages.shape[1], k_pages.shape[2], v_pages.shape[-1]
    npp = page_table.shape[1]
    g = H // hkv
    scale = scale or dk ** -0.5
    qg = q.reshape(B, hkv, g, dk)

    kern = functools.partial(_paged_kernel, scale=scale, page=page, npp=npp)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,       # lengths, page_table
            grid=(B, hkv, npp),
            in_specs=[
                pl.BlockSpec((1, 1, g, dk),
                             lambda b, h, j, lens, tbl: (b, h, 0, 0)),
                # the page table drives which KV page is DMA'd each step
                pl.BlockSpec((1, page, 1, dk),
                             lambda b, h, j, lens, tbl: (tbl[b, j], 0, h, 0)),
                pl.BlockSpec((1, page, 1, dv),
                             lambda b, h, j, lens, tbl: (tbl[b, j], 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, dv),
                                   lambda b, h, j, lens, tbl: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, dv), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, hkv, g, dv), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_flash_decode",
    )(lengths.astype(jnp.int32), page_table.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, H, dv)

"""Chunked SSD (Mamba-2) scan kernel.

TPU adaptation of the SSD chunked algorithm: the grid's last axis walks
chunks sequentially, carrying the (dh, N) recurrent state in VMEM
scratch; each chunk's intra-chunk work is three dense matmuls
((c,c)x(c,dh), (c,N)x(N,dh), (c,dh)^T x (c,N)) that land on the MXU with
c=chunk (128/256) and dh a multiple of 128.

The decay products ``ldec = dt * A`` are precomputed outside the kernel
(cheap elementwise) so the kernel takes no scalar operands; the D skip
connection is likewise applied outside.

Validated in interpret mode against ``ref.ssd_sequential`` /
``ref.ssd_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat


def _kernel(x_ref, dt_ref, ldec_ref, b_ref, c_ref, y_ref, h_ref, *, c: int):
    z = pl.program_id(2)

    @pl.when(z == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xb = x_ref[0, :, 0, :].astype(jnp.float32)        # (c, dh)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (c,)
    ld = ldec_ref[0, :, 0].astype(jnp.float32)        # (c,)  = dt * A
    Bm = b_ref[0].astype(jnp.float32)                 # (c, N)
    Cm = c_ref[0].astype(jnp.float32)                 # (c, N)

    seg = jnp.cumsum(ld)                              # inclusive within-chunk
    tot = seg[-1]
    dec_to_end = jnp.exp(tot - seg)                   # (c,)
    dec_from_start = jnp.exp(seg)                     # includes own dt
    h_prev = h_ref[...]                               # (dh, N)

    # cross-chunk contribution: y_i += dec(start->i) * C_i . h_prev
    y_cross = jax.lax.dot_general(
        Cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * dec_from_start[:, None]  # (c, dh)

    # intra-chunk causal part
    rel = seg[:, None] - seg[None, :]                 # (c_i, c_j)
    causal = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    decm = jnp.where(causal, jnp.exp(rel), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    m = cb * decm * dt[None, :]
    y_intra = jax.lax.dot(m, xb, preferred_element_type=jnp.float32)

    y_ref[0, :, 0, :] = (y_intra + y_cross).astype(y_ref.dtype)

    # state update: h = exp(tot) * h_prev + sum_i dt_i dec(i->end) x_i B_i^T
    w = (dt * dec_to_end)[:, None] * xb               # (c, dh)
    states = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (dh, N)
    h_ref[...] = h_prev * jnp.exp(tot) + states


def ssd_pallas(x, dt, A, B, C, D, *, chunk: int = 256, h0=None,
               interpret: bool = False):
    """x:(b,s,nh,dh) dt:(b,s,nh) A:(nh,) B,C:(b,s,N) D:(nh,).

    Returns (y, h_final) like ``ref.ssd_chunked``.  h0 unsupported in the
    kernel path (forward/train only)."""
    assert h0 is None, "ssd_pallas is the full-sequence path; decode uses ssd_decode"
    b, s, nh, dh = x.shape
    N = B.shape[-1]
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c

    ldec = dt.astype(jnp.float32) * A.astype(jnp.float32)[None, None, :]

    kern = functools.partial(_kernel, c=c)
    # grid: (batch, head, chunk) — chunks sequential (carried state)
    y = pl.pallas_call(
        kern,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, c, 1, dh), lambda i, h, z: (i, z, h, 0)),
            pl.BlockSpec((1, c, 1), lambda i, h, z: (i, z, h)),
            pl.BlockSpec((1, c, 1), lambda i, h, z: (i, z, h)),
            pl.BlockSpec((1, c, N), lambda i, h, z: (i, z, 0)),
            pl.BlockSpec((1, c, N), lambda i, h, z: (i, z, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, 1, dh), lambda i, h, z: (i, z, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, nh, dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((dh, N), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="ssd_chunked_scan",
    )(x, dt.astype(jnp.float32), ldec, B, C)

    y = y + (D.astype(jnp.float32)[None, None, :, None]
             * x.astype(jnp.float32)).astype(y.dtype)

    # h_final is recomputed outside the kernel (cheap reduction); the
    # kernel scratch is not returned.  Serving keeps states via
    # ssd_decode; training does not need h_final.
    _, h_final = _final_state(x, dt, A, B, c)
    return y, h_final


def _final_state(x, dt, A, B, c):
    """Analytic final SSD state (matches ref.ssd_chunked's h_final)."""
    b, s, nh, dh = x.shape
    nc = s // c
    xf = x.astype(jnp.float32).reshape(b, nc, c, nh, dh)
    dtf = dt.astype(jnp.float32).reshape(b, nc, c, nh)
    Bf = B.astype(jnp.float32).reshape(b, nc, c, -1)
    Af = A.astype(jnp.float32)
    seg = jnp.cumsum(dtf, axis=2)
    tot = seg[:, :, -1:]
    dec_to_end = jnp.exp((tot - seg) * Af)
    w = dtf * dec_to_end
    states = jnp.einsum("bzch,bzchd,bzcn->bzhdn", w, xf, Bf)
    chunk_decay = jnp.exp(tot[:, :, 0] * Af)          # (b,nc,nh)

    def combine(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a1 * a2, s1 * a2[..., None, None] + s2

    a_all, h_all = jax.lax.associative_scan(
        combine, (chunk_decay.transpose(1, 0, 2),
                  states.transpose(1, 0, 2, 3, 4)), axis=0)
    return a_all, h_all[-1]

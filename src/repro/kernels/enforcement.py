"""Fused Pallas enforcement kernel — the charge/account/gate hot path.

The lax reference path (``controller.charge_batch``) serializes the
per-request decisions with ``lax.scan``, re-gathering the ancestor
chain from HBM-resident state each iteration.  This kernel fuses the
whole batch into ONE ``pl.pallas_call``: the ``(n_domains,)`` control
state table is copied into VMEM once, a sequential grid walks the
request slots (the same serialization the memcg page-counter hierarchy
applies), and the masked DEPTH-deep ancestor-chain walk, the program
dispatch (``charge_decision`` — ``lax.switch`` over the attached
registry when more than one program is attached), the hierarchical
usage scatter, the throttle-window write and the PSI stall accounting
all run on the resident copy.  Only the final table and the packed
per-slot flags leave the core.

Decision math is NOT duplicated here: the kernel body builds the same
``ChainView`` (via ``controller._chain_view``) and calls the same
``charge_decision`` / ``gate_decision`` the lax path calls, so the two
paths trace identical per-request math — conformance certifies them
bit-identical on every backend kind.  Dispatch lives in
``controller._fused_charge_or_none``: Pallas on real TPUs, interpret
mode under ``REPRO_FORCE_PALLAS_INTERPRET=1`` (the conformance
override), lax everywhere else.

This module is a decision module for tracelint purposes: the kernel
bodies and wrappers admit no host syncs, no python branches on traced
values, and no suppression pragmas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro import compat
from repro.core.controller import _chain_view, _ancestor_chain
from repro.core.pressure import charge_stall_event, saturating_count
from repro.core.progs import (Request, as_programs, charge_decision,
                              gate_decision)


def _view_state(parent_ref, high_ref, max_ref, low_ref, frozen_ref,
                priority_ref, prog_id_ref):
    """VMEM-resident chain-view columns as one value dict, shaped like
    the controller state ``_chain_view`` reads (``frozen`` travels as
    i32 — TPU VMEM wants lane-typed vectors, bools do not tile)."""
    return {"parent": parent_ref[...], "high": high_ref[...],
            "max": max_ref[...], "low": low_ref[...],
            "frozen": frozen_ref[...] != 0,
            "priority": priority_ref[...],
            "prog_id": prog_id_ref[...]}


def _full_specs(arrays):
    """Whole-array blocks pinned to the origin: every sequential grid
    step sees (and for outputs, keeps resident) the full table."""
    return [pl.BlockSpec(a.shape, lambda z, nd=a.ndim: (0,) * nd)
            for a in arrays]


def _charge_kernel(dom_ref, amt_ref, step_ref, parent_ref, high_ref,
                   max_ref, low_ref, frozen_ref, priority_ref, prog_id_ref,
                   usage0_ref, peak0_ref, tu0_ref, params0_ref, stall0_ref,
                   usage_ref, peak_ref, tu_ref, params_ref, stall_ref,
                   granted_ref, stalled_ref, *, progs):
    """One request slot per sequential grid step; the output refs ARE
    the carry (same block every step, so the table stays in VMEM)."""
    z = pl.program_id(0)

    @pl.when(z == 0)
    def _init():
        usage_ref[...] = usage0_ref[...]
        peak_ref[...] = peak0_ref[...]
        tu_ref[...] = tu0_ref[...]
        params_ref[...] = params0_ref[...]
        stall_ref[...] = stall0_ref[...]

    d = dom_ref[z]
    a = amt_ref[z]
    step = step_ref[0]
    state = _view_state(parent_ref, high_ref, max_ref, low_ref, frozen_ref,
                        priority_ref, prog_id_ref)
    usage = usage_ref[...]
    tu = tu_ref[...]
    params = params_ref[...]

    # identical decision to the lax path: same view, same dispatch
    view = _chain_view(state, usage, tu, params, d)
    verdict, delay_ms, throttle = charge_decision(progs, view,
                                                  Request(d, a, step))
    grant = (d >= 0) & verdict.grant
    stalled = (d >= 0) & verdict.stall

    chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
    cvalid = (chain >= 0) & (d >= 0)
    cidx = jnp.maximum(chain, 0)
    add = jnp.where(cvalid & grant, a, 0)
    usage = usage.at[cidx].add(add)
    peak = jnp.maximum(peak_ref[...], usage)

    di = jnp.maximum(d, 0)
    dly = jnp.ceil(delay_ms / progs[0].step_ms).astype(jnp.int32)
    tu_d = jnp.where(throttle & (d >= 0),
                     jnp.maximum(tu[di], step + dly), tu[di])
    tu = tu.at[di].set(jnp.where(d >= 0, tu_d, tu[di]))
    params = params.at[di].set(
        jnp.where(d >= 0, verdict.params, params[di]))
    stall = stall_ref[...]
    stall = stall.at[di].set(saturating_count(
        stall[di],
        jnp.where(d >= 0, charge_stall_event(stalled, (d >= 0) & throttle),
                  0)))

    usage_ref[...] = usage
    peak_ref[...] = peak
    tu_ref[...] = tu
    params_ref[...] = params
    stall_ref[...] = stall
    granted_ref[z] = grant.astype(jnp.int32)
    stalled_ref[z] = stalled.astype(jnp.int32)


def fused_charge_batch(state: dict, dom: jax.Array, amt: jax.Array, step,
                       prog=None):
    """Drop-in fused replacement for the lax ``charge_batch`` body:
    same signature, bit-identical ``(new_state, granted, stalled)``."""
    progs = as_programs(prog)
    m = dom.shape[0]
    n = state["usage"].shape[0]
    dom = dom.astype(jnp.int32)
    amt = amt.astype(jnp.int32)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)
    inputs = (dom, amt, step_arr, state["parent"], state["high"],
              state["max"], state["low"],
              state["frozen"].astype(jnp.int32), state["priority"],
              state["prog_id"], state["usage"], state["peak"],
              state["throttle_until"], state["prog"], state["mem_stall"])
    out_shape = [
        jax.ShapeDtypeStruct((n,), jnp.int32),               # usage
        jax.ShapeDtypeStruct((n,), jnp.int32),               # peak
        jax.ShapeDtypeStruct((n,), jnp.int32),               # throttle_until
        jax.ShapeDtypeStruct(state["prog"].shape, jnp.float32),
        jax.ShapeDtypeStruct((n,), jnp.int32),               # mem_stall
        jax.ShapeDtypeStruct((m,), jnp.int32),               # granted
        jax.ShapeDtypeStruct((m,), jnp.int32),               # stalled
    ]
    outs = pl.pallas_call(
        functools.partial(_charge_kernel, progs=progs),
        grid=(m,),
        in_specs=_full_specs(inputs),
        out_specs=_full_specs(out_shape),
        out_shape=out_shape,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=not compat.on_tpu(),
        name="fused_enforcement_charge",
    )(*inputs)
    usage, peak, tu, params, stall, granted, stalled = outs
    new_state = dict(state, usage=usage, peak=peak, throttle_until=tu,
                     prog=params, mem_stall=stall)
    return new_state, granted.astype(bool), stalled.astype(bool)


def _gate_kernel(dom_ref, step_ref, parent_ref, high_ref, max_ref, low_ref,
                 frozen_ref, priority_ref, prog_id_ref, usage_ref, tu_ref,
                 params_ref, gate_ref, *, progs):
    z = pl.program_id(0)
    d = dom_ref[z]
    state = _view_state(parent_ref, high_ref, max_ref, low_ref, frozen_ref,
                        priority_ref, prog_id_ref)
    view = _chain_view(state, usage_ref[...], tu_ref[...], params_ref[...],
                       d)
    ok = (d >= 0) & gate_decision(progs, view, step_ref[0])
    gate_ref[z] = ok.astype(jnp.int32)


def fused_slot_gate(state: dict, slot_dom: jax.Array, step,
                    prog=None) -> jax.Array:
    """Fused replacement for the lax ``slot_gate`` body: one pass over
    the resident table, one ``on_gate`` dispatch per slot."""
    progs = as_programs(prog)
    m = slot_dom.shape[0]
    slot_dom = slot_dom.astype(jnp.int32)
    step_arr = jnp.asarray(step, jnp.int32).reshape(1)
    inputs = (slot_dom, step_arr, state["parent"], state["high"],
              state["max"], state["low"],
              state["frozen"].astype(jnp.int32), state["priority"],
              state["prog_id"], state["usage"], state["throttle_until"],
              state["prog"])
    out_shape = [jax.ShapeDtypeStruct((m,), jnp.int32)]
    (gate,) = pl.pallas_call(
        functools.partial(_gate_kernel, progs=progs),
        grid=(m,),
        in_specs=_full_specs(inputs),
        out_specs=_full_specs(out_shape),
        out_shape=out_shape,
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=not compat.on_tpu(),
        name="fused_enforcement_gate",
    )(*inputs)
    return gate.astype(bool)

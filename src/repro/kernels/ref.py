"""Pure-jnp oracles and CPU production paths for every kernel.

Three tiers per op:
  * ``*_naive``      — smallest obviously-correct oracle (tests only).
  * ``*_blockwise``  — memory-sane pure-JAX production path (CPU / dry-run;
                       what the Pallas kernel is validated against at scale).
  * Pallas kernel    — in sibling modules, TPU target, interpret-validated.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# ======================================================================
# Attention
# ======================================================================


def _expand_kv(q, k):
    """Group-query: reshape q to (B, S, Hkv, G, d)."""
    hq, hkv = q.shape[2], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    return q.reshape(q.shape[0], q.shape[1], hkv, g, q.shape[3]), g


def attention_naive(q, k, v, *, causal: bool = True, scale: Optional[float] = None):
    """O(S^2)-memory oracle. q:(B,S,H,dk) k:(B,S,Hkv,dk) v:(B,S,Hkv,dv)."""
    scale = scale or q.shape[-1] ** -0.5
    qg, g = _expand_kv(q, k)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k.shape[1] - q.shape[1])
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bske->bqkge", p, v.astype(jnp.float32))
    return o.reshape(q.shape[0], q.shape[1], q.shape[2], v.shape[-1]).astype(q.dtype)


def flash_attention_blockwise(q, k, v, *, causal: bool = True,
                              scale: Optional[float] = None,
                              block_q: int = 1024, block_k: int = 1024):
    """Streaming (flash) attention in pure JAX, with a custom VJP.

    Forward: static python loop over q blocks; inner ``fori_loop`` over
    kv blocks with a *static causal bound* per q block (true block
    skipping — the causal flop saving is real, not masked-out).
    Backward (``_flash_bwd``): blockwise recompute from (q, k, v, lse) —
    residual memory is O(B*S*H*d), NOT O(S^2) and NOT the inner-loop
    carries autodiff-of-the-forward would save (which OOM'd train cells
    at 4k x 256 batch).  Mirrors the two-pass FlashAttention backward the
    TPU kernel implements.
    """
    out, _ = _flash_fwd_vjp(q, k, v, causal, scale, block_q, block_k)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_fwd_vjp(q, k, v, causal, scale, block_q, block_k):
    return _flash_fwd(q, k, v, causal=causal, scale=scale,
                      block_q=block_q, block_k=block_k)


def _flash_fwd_rule(q, k, v, causal, scale, block_q, block_k):
    out, lse = _flash_fwd(q, k, v, causal=causal, scale=scale,
                          block_q=block_q, block_k=block_k)
    return (out, lse), (q, k, v, out, lse)


def _flash_bwd_rule(causal, scale, block_q, block_k, res, cts):
    dout, _ = cts
    q, k, v, out, lse = res
    dq, dk, dv = _flash_bwd(q, k, v, out, lse, dout, causal=causal,
                            scale=scale, block_q=block_q, block_k=block_k)
    return dq, dk, dv


_flash_fwd_vjp.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _flash_fwd(q, k, v, *, causal, scale, block_q, block_k):
    """Returns (out (B,S,H,dv), lse (B,Hkv,G,S) fp32)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    scale = scale or dk ** -0.5
    bq, bk = min(block_q, S), min(block_k, k.shape[1])
    Sk = k.shape[1]
    nq, nk = S // bq, Sk // bk
    assert S % bq == 0 and Sk % bk == 0, (S, bq, Sk, bk)
    qg, g = _expand_kv(q, k)
    hkv = k.shape[2]

    def q_block(iq: int):
        qb = jax.lax.slice_in_dim(qg, iq * bq, (iq + 1) * bq, axis=1)
        qb = qb.astype(jnp.float32) * scale  # (B,bq,Hkv,G,dk)

        def kv_step(ik, carry):
            acc, m, l = carry
            kb = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, axis=1).astype(jnp.float32)
            vb = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, axis=1).astype(jnp.float32)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb)  # (B,Hkv,G,bq,bk)
            if causal:
                qpos = iq * bq + jnp.arange(bq)
                kpos = ik * bk + jnp.arange(bk)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bske->bkgqe", p, vb)
            return acc, m_new, l

        acc0 = jnp.zeros((B, hkv, g, bq, dv), jnp.float32)
        m0 = jnp.full((B, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, hkv, g, bq), jnp.float32)
        # causal: kv blocks beyond this q block's diagonal are skipped
        # entirely; the bound is STATIC so the loop lowers to a scan.
        hi = min(nk, (((iq + 1) * bq + bk - 1) // bk)) if causal else nk
        acc, m, l = jax.lax.fori_loop(0, hi, kv_step, (acc0, m0, l0),
                                      unroll=False)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))            # (B,Hkv,G,bq)
        return out.transpose(0, 3, 1, 2, 4).reshape(B, bq, H, dv), lse

    outs, lses = zip(*[q_block(i) for i in range(nq)])
    out = jnp.concatenate(outs, axis=1).astype(q.dtype)
    lse = jnp.concatenate(lses, axis=-1)                    # (B,Hkv,G,S)
    return out, lse


def _flash_bwd(q, k, v, out, lse, dout, *, causal, scale, block_q, block_k):
    """Two-pass blockwise FlashAttention backward (recompute p from lse)."""
    B, S, H, dkd = q.shape
    dvd = v.shape[-1]
    scale = scale or dkd ** -0.5
    bq, bk = min(block_q, S), min(block_k, k.shape[1])
    Sk = k.shape[1]
    nq, nk = S // bq, Sk // bk
    qg, g = _expand_kv(q, k)
    hkv = k.shape[2]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # D_i = rowsum(dO * O): (B,S,H) -> (B,Hkv,G,S)
    Drow = jnp.einsum("bshe,bshe->bsh", dout.astype(jnp.float32),
                      out.astype(jnp.float32))
    Drow = Drow.reshape(B, S, hkv, g).transpose(0, 2, 3, 1)
    dog = dout.reshape(B, S, hkv, g, dvd).astype(jnp.float32)

    def qslice(t, i, b):
        return jax.lax.slice_in_dim(t, i * b, (i + 1) * b, axis=1)

    # ---- pass 1: dq per q block (inner loop over kv blocks)
    def dq_block(iq: int):
        qb = qslice(qg, iq, bq).astype(jnp.float32)          # (B,bq,Hkv,G,dk)
        dob = qslice(dog, iq, bq)                            # (B,bq,Hkv,G,dv)
        lseb = jax.lax.slice_in_dim(lse, iq * bq, (iq + 1) * bq, axis=3)
        Db = jax.lax.slice_in_dim(Drow, iq * bq, (iq + 1) * bq, axis=3)

        def kv_step(ik, dqa):
            kb = jax.lax.dynamic_slice_in_dim(kf, ik * bk, bk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(vf, ik * bk, bk, axis=1)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb * scale, kb)
            if causal:
                qpos = iq * bq + jnp.arange(bq)
                kpos = ik * bk + jnp.arange(bk)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])                 # (B,Hkv,G,bq,bk)
            dp = jnp.einsum("bqkge,bske->bkgqs", dob, vb)
            ds = p * (dp - Db[..., None]) * scale
            return dqa + jnp.einsum("bkgqs,bskd->bqkgd", ds, kb)

        hi = min(nk, (((iq + 1) * bq + bk - 1) // bk)) if causal else nk
        dq0 = jnp.zeros((B, bq, hkv, g, dkd), jnp.float32)
        dqb = jax.lax.fori_loop(0, hi, kv_step, dq0, unroll=False)
        return dqb.reshape(B, bq, H, dkd)

    dq = jnp.concatenate([dq_block(i) for i in range(nq)], axis=1)

    # ---- pass 2: dk/dv per kv block (inner loop over q blocks)
    def dkv_block(ik: int):
        kb = jax.lax.slice_in_dim(kf, ik * bk, (ik + 1) * bk, axis=1)
        vb = jax.lax.slice_in_dim(vf, ik * bk, (ik + 1) * bk, axis=1)

        def q_step(iq, carry):
            dka, dva = carry
            qb = jax.lax.dynamic_slice_in_dim(qg, iq * bq, bq, axis=1)
            qb = qb.astype(jnp.float32)
            dob = jax.lax.dynamic_slice_in_dim(dog, iq * bq, bq, axis=1)
            lseb = jax.lax.dynamic_slice_in_dim(lse, iq * bq, bq, axis=3)
            Db = jax.lax.dynamic_slice_in_dim(Drow, iq * bq, bq, axis=3)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb * scale, kb)
            if causal:
                qpos = iq * bq + jnp.arange(bq)
                kpos = ik * bk + jnp.arange(bk)
                s = jnp.where(qpos[:, None] >= kpos[None, :], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])
            dva = dva + jnp.einsum("bkgqs,bqkge->bske", p, dob)
            dp = jnp.einsum("bqkge,bske->bkgqs", dob, vb)
            ds = p * (dp - Db[..., None]) * scale
            dka = dka + jnp.einsum("bkgqs,bqkgd->bskd", ds, qb)
            return dka, dva

        lo = (ik * bk) // bq if causal else 0
        dk0 = jnp.zeros((B, bk, hkv, dkd), jnp.float32)
        dv0 = jnp.zeros((B, bk, hkv, dvd), jnp.float32)
        dkb, dvb = jax.lax.fori_loop(lo, nq, q_step, (dk0, dv0),
                                     unroll=False)
        return dkb, dvb

    dks, dvs = zip(*[dkv_block(j) for j in range(nk)])
    dk = jnp.concatenate(dks, axis=1).astype(k.dtype)
    dv = jnp.concatenate(dvs, axis=1).astype(v.dtype)
    return dq.astype(q.dtype), dk, dv


def decode_attention_ref(q, k_cache, v_cache, lengths, *,
                         scale: Optional[float] = None,
                         block_s: int = 2048):
    """Single-token decode vs a contiguous cache, flash-decoding style.

    q:(B,H,dk) k_cache:(B,Smax,Hkv,dk) v_cache:(B,Smax,Hkv,dv) lengths:(B,)
    Attends to positions < lengths[b].  The sequence is processed in
    blocks with a running (max, sum, acc) — the same split-K structure
    the Pallas decode kernel uses — so scores never materialize as a
    full (B, H, S_max) tensor in HBM.
    """
    B, Smax, hkv, dk = k_cache.shape
    scale = scale or dk ** -0.5
    H = q.shape[1]
    g = H // hkv
    dv = v_cache.shape[-1]
    bs = min(block_s, Smax)
    assert Smax % bs == 0, (Smax, bs)
    ns = Smax // bs
    qg = q.reshape(B, hkv, g, dk).astype(jnp.float32) * scale

    def step(i, carry):
        acc, m, l = carry
        kb = jax.lax.dynamic_slice_in_dim(k_cache, i * bs, bs, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v_cache, i * bs, bs, axis=1)
        # cache slices stay in their storage dtype; the dot accumulates
        # fp32 (an .astype here would hoist an f32 copy of the cache)
        s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(k_cache.dtype), kb,
                       preferred_element_type=jnp.float32)
        pos = i * bs + jnp.arange(bs)
        s = jnp.where((pos[None] < lengths[:, None])[:, None, None], s,
                      NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgs,bske->bkge", p.astype(v_cache.dtype), vb,
            preferred_element_type=jnp.float32)
        return acc, m_new, l

    acc0 = jnp.zeros((B, hkv, g, dv), jnp.float32)
    m0 = jnp.full((B, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, hkv, g), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, ns, step, (acc0, m0, l0))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(B, H, dv).astype(q.dtype)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                               scale: Optional[float] = None):
    """Paged decode oracle: gathers each sequence's pages then delegates.

    q:(B,H,dk); k_pages/v_pages:(n_pages, page, Hkv, d); page_table:(B, pages_per_seq)
    """
    B = q.shape[0]
    pp = page_table.shape[1]
    page = k_pages.shape[1]
    kc = k_pages[page_table].reshape(B, pp * page, *k_pages.shape[2:])
    vc = v_pages[page_table].reshape(B, pp * page, *v_pages.shape[2:])
    return decode_attention_ref(q, kc, vc, lengths, scale=scale)


# ======================================================================
# Mamba (SSD / Mamba-2 chunked scan)
# ======================================================================


def ssd_sequential(x, dt, A, B, C, D, *, h0=None):
    """Sequential SSD oracle (lax.scan over time).

    x:(b,s,nh,dh) dt:(b,s,nh) A:(nh,) B,C:(b,s,N) D:(nh,)
    Returns y:(b,s,nh,dh), h_final:(b,nh,dh,N).
    h_t = exp(dt*A) h + dt * (x_t outer B_t);  y_t = h_t C_t + D x_t
    """
    b, s, nh, dh = x.shape
    N = B.shape[-1]
    xf, dtf, Bf, Cf = (t.astype(jnp.float32) for t in (x, dt, B, C))
    Af = A.astype(jnp.float32)
    h = jnp.zeros((b, nh, dh, N), jnp.float32) if h0 is None else h0.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp            # (b,nh,dh) (b,nh) (b,N) (b,N)
        decay = jnp.exp(dtt * Af[None])  # (b,nh)
        h = h * decay[..., None, None] + (dtt[..., None, None]
                                          * xt[..., None] * Bt[:, None, None, :])
        y = jnp.einsum("bhdn,bn->bhd", h, Ct)
        return h, y

    inps = (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
            Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h, inps)
    y = ys.transpose(1, 0, 2, 3) + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), h


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 256, h0=None):
    """Chunked SSD: sequential ``lax.scan`` over chunks carrying the
    (nh, dh, N) state — the exact structure of the Pallas kernel, so the
    intra-chunk decay matrix exists for ONE chunk at a time ((b,c,c,nh)
    instead of (b,nc,c,c,nh), which materialized ~33 GiB/device on the
    jamba train cell).  Matches ``ssd_sequential`` to fp32 tolerance.
    """
    b, s, nh, dh = x.shape
    N = B.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c
    xf = x.astype(jnp.float32).reshape(b, nc, c, nh, dh)
    dtf = dt.astype(jnp.float32).reshape(b, nc, c, nh)
    Bf = B.astype(jnp.float32).reshape(b, nc, c, N)
    Cf = C.astype(jnp.float32).reshape(b, nc, c, N)
    Af = A.astype(jnp.float32)
    causal = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(h, inp):
        xz, dtz, Bz, Cz = inp                          # (b,c,...)
        seg = jnp.cumsum(dtz, axis=1)                  # (b,c,nh)
        tot = seg[:, -1:]                              # (b,1,nh)
        dec_to_end = jnp.exp((tot - seg) * Af)
        dec_from_start = jnp.exp(seg * Af)             # includes own dt
        # cross-chunk: y_i += dec(start->i) * C_i . h
        y_cross = jnp.einsum("bcn,bch,bhdn->bchd", Cz, dec_from_start, h)
        # intra-chunk causal part
        rel = seg[:, :, None, :] - seg[:, None, :, :]  # (b,i,j,nh)
        decm = jnp.where(causal[None, :, :, None], jnp.exp(rel * Af), 0.0)
        cb = jnp.einsum("bin,bjn->bij", Cz, Bz)
        m = cb[..., None] * decm * dtz[:, None]        # (b,i,j,nh)
        y = jnp.einsum("bijh,bjhd->bihd", m, xz) + y_cross
        # state update to chunk end
        w = dtz * dec_to_end                           # (b,c,nh)
        states = jnp.einsum("bch,bchd,bcn->bhdn", w, xz, Bz)
        h = h * jnp.exp(tot[:, 0] * Af)[..., None, None] + states
        return h, y

    h_init = (jnp.zeros((b, nh, dh, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    cm = lambda t: t.transpose(1, 0, *range(2, t.ndim))  # chunk-major
    h_final, ys = jax.lax.scan(chunk_step, h_init,
                               (cm(xf), cm(dtf), cm(Bf), cm(Cf)))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, dh)
    y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), h_final


def ssd_decode_step(h, x, dt, A, B, C, D):
    """One-token SSD update. h:(b,nh,dh,N) x:(b,nh,dh) dt:(b,nh) B,C:(b,N)."""
    hf = h.astype(jnp.float32)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None])
    h_new = hf * decay[..., None, None] + (dtf[..., None, None]
                                           * xf[..., None] * B.astype(jnp.float32)[:, None, None, :])
    y = jnp.einsum("bhdn,bn->bhd", h_new, C.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), h_new


# ======================================================================
# mLSTM (xLSTM matrix-memory) — stabilized chunked linear attention
# ======================================================================


def mlstm_sequential(q, k, v, i_gate, f_gate, *, state=None):
    """Sequential mLSTM oracle (xLSTM eqs. 19-27, log-space stabilized).

    q,k,v:(b,s,nh,dh) gates:(b,s,nh) pre-activation.
    Returns y:(b,s,nh,dh) and final (C:(b,nh,dh,dh), n:(b,nh,dh), m:(b,nh)).
    """
    b, s, nh, dh = q.shape
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    kf = kf / (dh ** 0.5)
    i_f = i_gate.astype(jnp.float32)
    f_f = f_gate.astype(jnp.float32)
    if state is None:
        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = (t.astype(jnp.float32) for t in state)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        logf = jax.nn.log_sigmoid(ft)                       # (b,nh)
        m_new = jnp.maximum(logf + m, it)
        fd = jnp.exp(logf + m - m_new)
        idc = jnp.exp(it - m_new)
        C = fd[..., None, None] * C + idc[..., None, None] * (vt[..., None] * kt[..., None, :])
        n = fd[..., None] * n + idc[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), jnp.exp(-m_new))
        return (C, n, m_new), num / den[..., None]

    inps = tuple(t.transpose(1, 0, 2, 3) for t in (qf, kf, vf)) + (
        i_f.transpose(1, 0, 2), f_f.transpose(1, 0, 2))
    (C, n, m), ys = jax.lax.scan(step, (C0, n0, m0), inps)
    return ys.transpose(1, 0, 2, 3).astype(q.dtype), (C, n, m)


def mlstm_chunked(q, k, v, i_gate, f_gate, *, chunk: int = 256, state=None):
    """Chunk-parallel mLSTM matching ``mlstm_sequential``.

    Intra-chunk: attention-like with log-decay matrix; inter-chunk: carried
    state applied with prefix decays.  Chunks are processed with a scan
    whose body is dense matmuls (flop-dominant part is intra-chunk).
    """
    b, s, nh, dh = q.shape
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    rs = lambda t: t.astype(jnp.float32).reshape(b, nc, c, *t.shape[2:])
    qf, kf, vf = rs(q), rs(k) / (dh ** 0.5), rs(v)
    i_f, f_f = rs(i_gate), rs(f_gate)
    logf = jax.nn.log_sigmoid(f_f)                          # (b,nc,c,nh)
    lcum = jnp.cumsum(logf, axis=2)                         # inclusive
    ltot = lcum[:, :, -1]                                   # (b,nc,nh)

    if state is None:
        C0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = (t.astype(jnp.float32) for t in state)

    def chunk_step(carry, inp):
        C, n, m = carry
        qz, kz, vz, iz, lcz, ltz = inp                      # per-chunk slices
        # log weights: state decay to pos t: lcz_t + m ; input j to t: lcz_t - lcz_j + i_j
        a_state = lcz + m[:, None]                          # (b,c,nh)
        a_in = lcz[:, :, None] - lcz[:, None] + iz[:, None]  # (b,t,j,nh)
        causal = jnp.tril(jnp.ones((c, c), bool))
        a_in = jnp.where(causal[None, :, :, None], a_in, -jnp.inf)
        m_t = jnp.maximum(a_in.max(axis=2), a_state)        # (b,t,nh) running stabilizer
        w_state = jnp.exp(a_state - m_t)                    # (b,t,nh)
        w_in = jnp.exp(a_in - m_t[:, :, None])              # (b,t,j,nh)
        # numerator / denominator
        qk = jnp.einsum("bthd,bjhd->btjh", qz, kz)
        num = jnp.einsum("btjh,btjh,bjhd->bthd", qk, w_in, vz)
        num = num + w_state[..., None] * jnp.einsum("bhvk,bthk->bthv", C, qz)
        den_in = jnp.einsum("btjh,btjh->bth", qk, w_in)
        den = den_in + w_state * jnp.einsum("bhk,bthk->bth", n, qz)
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state update to end of chunk
        m_new = jnp.maximum(ltz + m, (ltz[:, None] - lcz + iz).max(axis=1))
        w_old = jnp.exp(ltz + m - m_new)                    # (b,nh)
        w_tok = jnp.exp(ltz[:, None] - lcz + iz - m_new[:, None])  # (b,c,nh)
        C = w_old[..., None, None] * C + jnp.einsum("bjh,bjhv,bjhk->bhvk", w_tok, vz, kz)
        n = w_old[..., None] * n + jnp.einsum("bjh,bjhk->bhk", w_tok, kz)
        return (C, n, m_new), y

    inps = tuple(t.transpose(1, 0, 2, 3, 4) for t in (qf, kf, vf)) + (
        i_f.transpose(1, 0, 2, 3), lcum.transpose(1, 0, 2, 3), ltot.transpose(1, 0, 2))
    (C, n, m), ys = jax.lax.scan(chunk_step, (C0, n0, m0), inps)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, dh)
    return y.astype(q.dtype), (C, n, m)


def mlstm_decode_step(state, q, k, v, i_gate, f_gate):
    """One-token mLSTM update. state=(C,n,m); q,k,v:(b,nh,dh); gates:(b,nh)."""
    C, n, m = (t.astype(jnp.float32) for t in state)
    dh = q.shape[-1]
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    kf = kf / (dh ** 0.5)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))
    it = i_gate.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, it)
    fd, idc = jnp.exp(logf + m - m_new), jnp.exp(it - m_new)
    C = fd[..., None, None] * C + idc[..., None, None] * (vf[..., None] * kf[..., None, :])
    n = fd[..., None] * n + idc[..., None] * kf
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), jnp.exp(-m_new))
    return (num / den[..., None]).astype(q.dtype), (C, n, m_new)

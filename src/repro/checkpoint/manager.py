"""Checkpoint lifecycle: periodic saves, keep-k GC, resume-from-latest.

The training driver (launch/train.py) uses this for fault tolerance:
on restart it resumes bit-exactly from the newest complete checkpoint
(atomicity guaranteed by ckpt.save's write-then-rename).
"""
from __future__ import annotations

import glob
import os
import re
from typing import Any, Optional

from repro.checkpoint import ckpt

_PAT = re.compile(r"ckpt_(\d+)\.npz$")


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, every: int = 50,
                 async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.every = every
        self.writer = ckpt.AsyncWriter() if async_write else None
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:08d}.npz")

    def steps(self) -> list[int]:
        out = []
        for p in glob.glob(os.path.join(self.dir, "ckpt_*.npz")):
            m = _PAT.search(p)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def maybe_save(self, step: int, tree: Any, *, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.every):
            return False
        if self.writer is not None:
            self.writer.save(self._path(step), step, tree)
        else:
            ckpt.save(self._path(step), step, tree)
        self._gc()
        return True

    def finalize(self) -> None:
        if self.writer is not None:
            self.writer.wait()
        self._gc()

    def restore_latest(self, template: Any) -> Optional[tuple[int, Any]]:
        if self.writer is not None:
            self.writer.wait()
        step = self.latest_step()
        if step is None:
            return None
        return ckpt.load(self._path(step), template)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            try:
                os.remove(self._path(s))
            except FileNotFoundError:
                pass

"""Sharded, atomic, optionally-async checkpointing.

Format: one ``.npz`` per host process (this rig has one) holding every
leaf keyed by its tree path, plus a small JSON manifest.  Writes go to a
temp file then ``os.replace`` — a checkpoint is either fully present or
absent, never torn (crash-safe restart depends on this; the failure-
injection test kills mid-write).  ``AsyncWriter`` overlaps serialization
with the next training steps (device->host copy happens synchronously,
the disk write in a background thread).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

_BF16 = "BF16::"        # numpy cannot serialize bfloat16; store u16 views


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype == ml_dtypes.bfloat16:
            flat[_BF16 + key] = arr.view(np.uint16)
        else:
            flat[key] = arr
    return flat


def _unflatten_like(template, flat: dict[str, np.ndarray]):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in leaves_p:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if _BF16 + key in flat:
            arr = flat[_BF16 + key].view(ml_dtypes.bfloat16)
        else:
            arr = flat[key]
        assert arr.shape == tuple(tmpl.shape), (key, arr.shape, tmpl.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(path: str, step: int, tree: Any) -> None:
    """Atomic synchronous save."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "wb") as f:
        np.savez(f, __step__=np.int64(step), **flat)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load(path: str, template: Any) -> tuple[int, Any]:
    """Load into the structure (and dtypes) of ``template``."""
    with np.load(path) as z:
        step = int(z["__step__"])
        flat = {k: z[k] for k in z.files if k != "__step__"}
    restored = _unflatten_like(template, flat)

    def cast(t, a):
        if hasattr(t, "dtype") and a.dtype != t.dtype:
            return np.asarray(a).astype(t.dtype)
        return a
    restored = jax.tree.map(cast, template, restored)
    return step, restored


class AsyncWriter:
    """Overlap disk writes with training: the device->host pull is
    synchronous (cheap), the serialization+fsync runs in a thread."""

    def __init__(self) -> None:
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, path: str, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(path, step, host_tree)
            except BaseException as e:       # surfaces on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

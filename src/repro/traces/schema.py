"""Agent-workload trace schema.

A ``TaskTrace`` mirrors what the paper measured per SWE-rebench task:
1-second CPU/memory samples plus per-tool-call spans with semantic
categories.  Traces are either synthesized by ``generator.py``
(calibrated to the paper's §3 statistics) or hand-built in tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

TOOLS = ("Bash", "Read", "Edit", "Write", "SubAgent", "WebSearch")
BASH_CATEGORIES = ("test", "pip", "python", "file", "git", "build")


@dataclass
class ToolCall:
    tool: str                    # one of TOOLS
    category: str                # semantic category ("test", "git", ...)
    t_start_s: float             # seconds from task start
    dur_s: float
    peak_mb: float               # peak incremental memory of the call
    retained_mb: float = 0.0     # memory NOT released on exit (retry leak)
    retry_group: int = -1        # >=0: index of the retry loop it belongs to

    @property
    def t_end_s(self) -> float:
        return self.t_start_s + self.dur_s


@dataclass
class TaskTrace:
    task_id: str
    model: str                   # "haiku" | "glm"
    duration_s: float            # active (post-init) duration
    init_s: float                # container + agent initialization
    baseline_mb: float
    tool_calls: list             # list[ToolCall], sorted by t_start_s
    mem_mb: np.ndarray           # (T,) 1-second samples, active phase
    cpu_pct: np.ndarray          # (T,) 1-second samples (100 = one core)
    seed: int = 0

    @property
    def total_s(self) -> float:
        return self.init_s + self.duration_s

    @property
    def peak_mb(self) -> float:
        return float(self.mem_mb.max())

    @property
    def avg_mb(self) -> float:
        return float(self.mem_mb.mean())

    @property
    def peak_to_avg(self) -> float:
        return self.peak_mb / max(self.avg_mb, 1e-9)

    def tool_time_s(self) -> float:
        return sum(c.dur_s for c in self.tool_calls)

    def in_tool_call(self, t_s: float) -> bool:
        return any(c.t_start_s <= t_s < c.t_end_s for c in self.tool_calls)

    def retry_groups(self) -> dict[int, list]:
        out: dict[int, list] = {}
        for c in self.tool_calls:
            if c.retry_group >= 0:
                out.setdefault(c.retry_group, []).append(c)
        return {g: cs for g, cs in out.items() if len(cs) >= 3}


@dataclass
class AllocEvent:
    """Replay-level event: signed memory delta at a simulated time."""
    t_ms: float
    delta_mb: float
    tool: Optional[ToolCall] = None     # None = framework-baseline delta


def to_alloc_events(trace: TaskTrace, *, accel: float = 50.0,
                    sample_s: float = 1.0) -> list[AllocEvent]:
    """Convert 1-second memory samples to allocation/release deltas,
    replayed at ``accel``x speed (paper §6 replays at 50x)."""
    import numpy as np
    events = []
    ms_per_sample = sample_s * 1000.0 / accel
    # integerize the PROFILE (not the deltas): per-event rounding would
    # random-walk usage away from the trace by tens of MB
    mem_int = np.rint(np.asarray(trace.mem_mb)).astype(np.int64)
    prev = 0
    calls = sorted(trace.tool_calls, key=lambda c: c.t_start_s)
    for i, m in enumerate(mem_int):
        t_s = i * sample_s
        delta = int(m) - prev
        if delta != 0:
            tool = next((c for c in calls
                         if c.t_start_s <= t_s < c.t_end_s), None)
            events.append(AllocEvent(i * ms_per_sample, float(delta), tool))
        prev = int(m)
    # final release of everything at end
    if prev > 0:
        events.append(AllocEvent(len(mem_int) * ms_per_sample,
                                 float(-prev), None))
    return events

"""Synthetic agent-workload traces calibrated to the paper's §3 stats.

Calibration targets (paper values in brackets):
  * framework baseline ~185 MB (Haiku 183 / GLM 188), stable first half;
  * task duration 5-11 min (GLM mean 10.8, Haiku 5.8, median 8.1);
  * init phase 31-48 % of total time; tool execution ~26 % of total;
    => OS-level time 56-74 %;
  * tool mix: Haiku = Bash 47.8 % + SubAgent 43.2 % of tool time;
    GLM = Bash 98.1 %;
  * Bash category time: test (Haiku 72.9 % / GLM 43.7 %), pip ~10 %,
    python (GLM 26.9 %), file/git remainder;
  * burst sizes: test P95 518 MB (Haiku) / 234 MB (GLM); pip P95 233 MB;
    file 4.5 MB; git 13.5 MB mean;
  * burst shape: 1-2 s rise (up to ~3 GB/s), fall back to baseline;
  * retry loops: 85 % (Haiku) / 97 % (GLM) of tasks, GLM mean 3.9
    groups/task (up to dozens of consecutive retries), progressive
    accumulation up to ~500 MB;
  * memory peaks concentrate around ~65 % progress;
  * cross-task peak range ~197 MB - 4 GB (CV ~147 %), peak/avg up to
    15.4x (pydicom#2022: peak 4060 MB vs avg 264 MB);
  * non-determinism: ~1.8x duration variance across runs of one task;
  * CPU: low average (Haiku 13.2 % / GLM 7.6 % of one core), spikes
    during tool calls; GLM keeps a small steady load outside calls.

``benchmarks/characterization.py`` re-measures all of these from
generated datasets and prints them next to the paper's numbers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

from repro.traces.schema import TaskTrace, ToolCall

# --------------------------------------------------------- category params

# (mean_mb, sigma_of_log, p95_target_mb) per bash category and model
BURST_MB = {
    "haiku": {"test": (180.0, 0.85, 518.0), "pip": (90.0, 0.8, 233.0),
              "python": (60.0, 0.8, 200.0), "file": (4.5, 0.5, 10.0),
              "git": (13.5, 0.5, 30.0), "build": (250.0, 0.7, 600.0)},
    "glm": {"test": (90.0, 0.8, 234.0), "pip": (90.0, 0.8, 233.0),
            "python": (80.0, 0.8, 250.0), "file": (4.5, 0.5, 10.0),
            "git": (13.5, 0.5, 30.0), "build": (250.0, 0.7, 600.0)},
    # a third burst-shape class between the two measured ones: bash-heavy
    # like GLM but with Haiku-class test bursts — lets the benchmarks
    # compare one policy across trace classes, not just across policies
    "qwen": {"test": (130.0, 0.9, 400.0), "pip": (90.0, 0.8, 233.0),
             "python": (70.0, 0.8, 220.0), "file": (4.5, 0.5, 10.0),
             "git": (13.5, 0.5, 30.0), "build": (250.0, 0.7, 600.0)},
}

# share of bash *time* per category
BASH_TIME_SHARE = {
    "haiku": {"test": 0.729, "pip": 0.10, "python": 0.05, "file": 0.06,
              "git": 0.04, "build": 0.021},
    "glm": {"test": 0.437, "pip": 0.10, "python": 0.269, "file": 0.10,
            "git": 0.074, "build": 0.02},
    "qwen": {"test": 0.58, "pip": 0.12, "python": 0.17, "file": 0.08,
             "git": 0.04, "build": 0.01},
}

# share of total tool time per tool
TOOL_TIME_SHARE = {
    "haiku": {"Bash": 0.478, "SubAgent": 0.432, "Read": 0.04, "Edit": 0.03,
              "Write": 0.01, "WebSearch": 0.01},
    "glm": {"Bash": 0.981, "Read": 0.01, "Edit": 0.007, "Write": 0.002},
    "qwen": {"Bash": 0.86, "SubAgent": 0.06, "Read": 0.04, "Edit": 0.03,
             "Write": 0.01},
}

DURATION_MEAN_S = {"haiku": 5.8 * 60, "glm": 10.8 * 60, "qwen": 7.5 * 60}
BASELINE_MB = {"haiku": 183.0, "glm": 188.0, "qwen": 176.0}
RETRY_TASK_FRAC = {"haiku": 0.85, "glm": 0.97, "qwen": 0.92}
RETRY_GROUPS_MEAN = {"haiku": 1.8, "glm": 3.9, "qwen": 2.8}
# % of one core outside calls / mean % during tool calls
CPU_IDLE = {"haiku": 8.0, "glm": 4.0, "qwen": 6.0}
CPU_BURST = {"haiku": 120.0, "glm": 90.0, "qwen": 105.0}


def _lognormal(rng, mean, sigma):
    """Lognormal with the given *mean* and log-space sigma."""
    mu = math.log(mean) - 0.5 * sigma * sigma
    return float(rng.lognormal(mu, sigma))


def _task_scale(rng) -> float:
    """Per-task memory-appetite multiplier: the 20x cross-task spread.
    Heavy-tailed so a few tasks are pydicom-like (multi-GB)."""
    return float(np.exp(rng.normal(0.0, 0.9)))


def generate_task(task_id: str, model: str, seed: int, *,
                  scale: Optional[float] = None,
                  duration_s: Optional[float] = None,
                  peak_override_mb: Optional[float] = None,
                  sustain_frac: float = 0.0) -> TaskTrace:
    rng = np.random.default_rng(seed)
    model = model.lower()
    baseline = float(rng.normal(BASELINE_MB[model], 12.0))
    dur = duration_s if duration_s is not None else float(np.clip(
        _lognormal(rng, DURATION_MEAN_S[model], 0.25), 120, 1500))
    init_frac = float(rng.uniform(0.31, 0.48))
    init_s = dur * init_frac / (1 - init_frac)
    scale = scale if scale is not None else _task_scale(rng)

    # --- schedule tool calls until the tool-time budget is consumed
    tool_budget = dur * float(rng.uniform(0.30, 0.46))
    calls: list[ToolCall] = []
    t_share = TOOL_TIME_SHARE[model]
    b_share = BASH_TIME_SHARE[model]
    budgets = {tool: tool_budget * fr for tool, fr in t_share.items()}

    retry_target = (int(rng.poisson(RETRY_GROUPS_MEAN[model]))
                    if rng.random() < RETRY_TASK_FRAC[model] else 0)
    retry_target = max(retry_target, 1) if retry_target else 0
    group_id = 0

    def burst_for(cat: str) -> float:
        mean, sig, _ = BURST_MB[model][cat]
        return _lognormal(rng, mean * scale, sig)

    def sample_start(frac_lo, frac_hi):
        return float(rng.uniform(frac_lo, frac_hi)) * dur

    pending: list[ToolCall] = []
    for tool, budget in budgets.items():
        used = 0.0
        while used < budget:
            if tool == "Bash":
                cat = rng.choice(list(b_share), p=np.array(
                    list(b_share.values())) / sum(b_share.values()))
                d = float(np.clip(_lognormal(rng, 5.0, 1.0), 0.3, 120.0))
                # bash concentrates in 40-80 % of progress
                t0 = sample_start(0.25, 0.95)
                peak = burst_for(cat)
                if cat == "test" and retry_target and group_id < retry_target:
                    # retry loop: >=3 consecutive same-command calls with
                    # progressive accumulation (total retained capped at
                    # the paper's worst case ~502 MB per task)
                    n_retry = int(rng.integers(3, 9))
                    leak_budget = 502.0 / max(retry_target, 1)
                    leak_total = float(min(rng.uniform(30, 160) * scale,
                                           leak_budget))
                    leak = leak_total / n_retry
                    tt = t0
                    for _ in range(n_retry):
                        dd = float(np.clip(d * rng.uniform(0.7, 1.3), 0.3, 120))
                        pending.append(ToolCall("Bash", "test", tt, dd,
                                                peak_mb=peak * rng.uniform(0.8, 1.2),
                                                retained_mb=leak,
                                                retry_group=group_id))
                        used += dd
                        tt += dd + float(rng.uniform(0.5, 4.0))
                    group_id += 1
                    continue
                pending.append(ToolCall("Bash", cat, t0, d, peak_mb=peak))
                used += d
            elif tool == "SubAgent":
                d = float(np.clip(_lognormal(rng, 100.0, 0.5), 20, 300))
                pending.append(ToolCall("SubAgent", "subagent",
                                        sample_start(0.3, 0.8), d,
                                        peak_mb=burst_for("test") * 0.8))
                used += d
            elif tool in ("Read",):
                d = float(np.clip(rng.exponential(0.3), 0.05, 0.5))
                pending.append(ToolCall("Read", "read",
                                        sample_start(0.0, 0.35), d,
                                        peak_mb=float(rng.uniform(1, 6))))
                used += d
            elif tool in ("Edit", "Write"):
                d = float(np.clip(rng.exponential(0.3), 0.05, 0.5))
                pending.append(ToolCall(tool, "edit",
                                        sample_start(0.0, 1.0), d,
                                        peak_mb=float(rng.uniform(1, 8))))
                used += d
            else:  # WebSearch
                d = float(np.clip(rng.exponential(2.0), 0.5, 10.0))
                pending.append(ToolCall(tool, "web",
                                        sample_start(0.1, 0.9), d,
                                        peak_mb=float(rng.uniform(5, 30))))
                used += d

    # de-overlap: sort by start, push overlapping calls later (agent loop
    # is sequential — one tool call at a time)
    pending.sort(key=lambda c: c.t_start_s)
    t_cursor = 0.0
    for c in pending:
        c.t_start_s = max(c.t_start_s, t_cursor)
        t_cursor = c.t_start_s + c.dur_s
    dur = max(dur, t_cursor + 5.0)
    calls = pending

    # --- render 1-second samples
    T = int(math.ceil(dur)) + 1
    mem = np.full(T, baseline, np.float64)
    cpu = np.full(T, CPU_IDLE[model], np.float64)
    mem += rng.normal(0, 3.0, T)
    cpu += np.abs(rng.normal(0, 2.0, T))
    retained = 0.0
    for c in calls:
        i0, i1 = int(c.t_start_s), min(int(c.t_end_s) + 1, T)
        if i0 >= T:
            continue
        rise = max(1, min(2, i1 - i0))            # 1-2 s rise (>=1 GB/s poss.)
        for j in range(i0, i1):
            frac = min(1.0, (j - i0 + 1) / rise)
            mem[j] = max(mem[j], baseline + retained + c.peak_mb * frac)
            # CPU bursts are SPIKES at call start (paper: avg CPU stays
            # <13% of one core; peaks >100% are brief)
            if j - i0 < 2:
                cpu[j] = max(cpu[j], float(
                    rng.normal(CPU_BURST[model], 30.0)))
        retained += c.retained_mb
        if i1 < T:
            mem[i1:] += c.retained_mb              # progressive accumulation
    if sustain_frac > 0.0:
        # progressive-accumulation plateau (paper Fig 5/6: memory builds
        # through retry loops and stays elevated through the second half)
        peak_now = float(mem.max())
        floor = np.full(T, baseline)
        ramp_end = int(0.45 * T)
        hold_end = int(0.95 * T)
        tgt = baseline + sustain_frac * (peak_now - baseline)
        floor[:ramp_end] = np.linspace(baseline, tgt, ramp_end)
        floor[ramp_end:hold_end] = tgt
        floor[hold_end:] = np.linspace(tgt, baseline, T - hold_end)
        mem = np.maximum(mem, floor)

    np.clip(cpu, 0.5, 2400.0, out=cpu)
    np.clip(mem, 30.0, None, out=mem)

    if peak_override_mb is not None:
        # rescale the burst component so the trace peak matches the
        # paper's measured peak for this named task
        cur_peak = float(mem.max())
        if cur_peak > baseline + 1.0:
            k = (peak_override_mb - baseline) / (cur_peak - baseline)
            mem = baseline + (mem - baseline) * k
            for c in calls:
                c.peak_mb *= k
                c.retained_mb *= k

    return TaskTrace(task_id=task_id, model=model, duration_s=float(dur),
                     init_s=float(init_s), baseline_mb=baseline,
                     tool_calls=calls, mem_mb=mem, cpu_pct=cpu, seed=seed)


# ------------------------------------------------------------- datasets


def generate_dataset(model: str, n: int, seed: int = 0) -> list[TaskTrace]:
    return [generate_task(f"{model}-task-{i:03d}", model, seed * 10007 + i)
            for i in range(n)]


def generate_spike_corpus(n: int, seed: int = 0, *, model: str = "haiku",
                          duration_s: float = 180.0,
                          peak_to_avg: float = 15.4) -> list[TaskTrace]:
    """Heavy-tailed corpus for the escalation benchmark.

    ``n`` bursty traces; the last slot is re-generated so the corpus
    reproduces the paper's measured 15.4x peak-to-average spike
    (pydicom#2022: 4060 MB peak vs 264 MB average).  The ratio ceiling
    of a trace is fixed by its burst *shape* — ``(peak-b)/(avg-b)``
    over the baseline ``b`` — so we scan a deterministic seed window
    for a shape whose ceiling clears the target, then solve the burst
    amplitude in closed form:  (b + k*dp)/(b + k*da) = target.
    Deterministic in ``(n, seed)``."""
    traces = [generate_task(f"spike-{i:03d}", model, seed * 20011 + i,
                            scale=1.0 + 0.15 * (i % 4),
                            duration_s=duration_s)
              for i in range(n)]
    spike_dur = max(duration_s, 900.0)   # long tail keeps the avg low
    best = None
    for probe in range(32):
        s = seed * 20011 + n + probe
        tr = generate_task(f"spike-{n - 1:03d}", model, s, scale=1.2,
                           duration_s=spike_dur)
        b = tr.baseline_mb
        dp, da = tr.peak_mb - b, tr.avg_mb - b
        if da > 0 and (best is None or dp / da > best[0]):
            best = (dp / da, s, b, dp, da)
    ceiling, s, b, dp, da = best
    if ceiling <= peak_to_avg * 1.05:
        raise RuntimeError(
            f"no burst shape reached {peak_to_avg}x in the probe window")
    # the spikiest shape needs the least amplification -> a realistic peak
    k = b * (peak_to_avg - 1.0) / (dp - peak_to_avg * da)
    traces[n - 1] = generate_task(f"spike-{n - 1:03d}", model, s, scale=1.2,
                                  duration_s=spike_dur,
                                  peak_override_mb=b + k * dp)
    return traces


# named traces matching the paper's exemplars (used by Fig-8 replay).
# the fig-8 traces carry a sustained accumulation plateau (paper Fig 5/6)
# so three concurrent sessions genuinely contend: 421+406+406 ~ 1233 MB
# combined demand against the 1100 MB tight scenario.
NAMED = {
    # task_id: (model, scale, duration_s, peak_mb, sustain_frac)
    "dask/dask#11628": ("glm", 0.9, 420.0, 421.0, 0.80),
    "sigmavirus24/github3.py#673": ("glm", 0.9, 500.0, 406.0, 0.85),
    "pydicom/pydicom#2022": ("haiku", 1.2, 600.0, 4060.0, 0.0),
    "streamlink/streamlink#2160": ("glm", 0.5, 400.0, 291.0, 0.0),
    "iterative/dvc#777": ("glm", 1.0, 402.0, None, 0.0),
    "pre-commit/pre-commit#2524": ("haiku", 1.0, 380.0, None, 0.0),
}


def named_trace(name: str, seed: int = 0) -> TaskTrace:
    import zlib
    model, scale, dur, peak, sustain = NAMED[name]
    stable = zlib.crc32(f"{name}:{seed}".encode()) % (2 ** 31)
    return generate_task(name, model, seed=stable,
                         scale=scale, duration_s=dur, peak_override_mb=peak,
                         sustain_frac=sustain)

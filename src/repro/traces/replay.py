"""Multi-tenant trace replay under a resource-control policy (paper §6).

Deterministic discrete-time simulation: each task's 1-second memory
samples become allocation/release deltas replayed at ``accel``x speed
(the paper replays at 50x).  The simulator provides the allocation
"physics" — base cost, direct-reclaim cost under pressure — and the
policy mediates every allocation (grant / throttle-delay / stall /
freeze / feedback / kill).

Measured outputs match Fig 8: per-task survival & completion, per-
priority allocation-latency P50/P95, throttle trigger counts, and
completion-time overhead vs an uncontended solo run.

Enforcement decisions run in the ``PolicyProgram`` attached to
``sim.cg`` — the literal same decision code the serving engine traces
on device — so replay results and in-step enforcement cannot drift.
Attach a custom program via ``Replay(..., program=...)`` (or let the
policy's ``setup`` do it); graduated delays arrive on the
``ChargeTicket`` and feed the backpressure physics below.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core import domains as D
from repro.core.accounting import Accounting
from repro.core.cgroup import AgentCgroup, HostTreeBackend
from repro.core.escalation import Escalator, EscalationExhausted, WasteLedger
from repro.core.events import Ev, EventLog
from repro.core.policy import AllocOutcome, BasePolicy
from repro.traces.schema import AllocEvent, TaskTrace, ToolCall, to_alloc_events


@dataclass
class ReplayConfig:
    capacity_mb: int
    accel: float = 50.0
    tick_ms: float = 2.0
    base_alloc_ms: float = 0.05
    # direct-reclaim stall: proportional to how far the pool sits over the
    # watermark when the allocation happens (scan work ~ deficit)
    reclaim_ms_per_deficit_mb: float = 0.30
    pressure_floor: float = 0.80        # watermark fraction of capacity
    # memory.low protection biases reclaim away from the protected cgroup
    # but does not eliminate the allocator's stall share
    protection_discount: float = 0.65
    max_sim_ms: float = 600_000.0
    max_events_per_tick: int = 64


@dataclass
class SimTask:
    key: str
    trace: TaskTrace
    priority: int
    events: list = field(default_factory=list)
    spans: list = field(default_factory=list)    # (start_ms, end_ms, call)
    idx: int = 0
    span_done: int = 0                           # spans fully closed
    open_span: int = -1                          # currently open span or -1
    next_due_ms: float = 0.0
    stall_since_ms: Optional[float] = None
    pending_mb: Optional[int] = None
    usage_mb: int = 0
    frozen: bool = False
    frozen_total_ms: float = 0.0
    frozen_since: float = 0.0
    done: bool = False
    killed: bool = False
    kill_reason: str = ""
    finish_ms: float = 0.0
    ideal_ms: float = 0.0
    scale_rest_of_tool: float = 1.0
    frozen_mb: int = 0                           # pages offloaded at freeze

    @property
    def running(self) -> bool:
        return not (self.done or self.killed)


@dataclass
class TaskResult:
    completed: bool
    killed: bool
    kill_reason: str
    finish_ms: float
    ideal_ms: float
    frozen_ms: float

    @property
    def overhead(self) -> float:
        if not self.completed or self.ideal_ms <= 0:
            return float("nan")
        return self.finish_ms / self.ideal_ms - 1.0


@dataclass
class ReplayResult:
    policy: str
    tasks: dict
    latency: Accounting
    log: EventLog
    peak_pool_mb: int
    escalation: Optional[dict] = None    # WasteLedger.summary() when active

    @property
    def survival(self) -> float:
        n = len(self.tasks)
        return sum(1 for r in self.tasks.values() if r.completed) / max(n, 1)

    def latency_of(self, priority: int):
        return self.latency.latency(f"prio{priority}")

    @property
    def throttle_count(self) -> int:
        return self.log.count(Ev.THROTTLE)

    def summary(self) -> dict:
        hi = self.latency_of(D.HIGH)
        return {
            "policy": self.policy,
            "survival": round(self.survival, 4),
            "high_p50_ms": round(hi.p50, 3),
            "high_p95_ms": round(hi.p95, 3),
            "throttles": self.throttle_count,
            "oom_kills": self.log.count(Ev.OOM_KILL),
            "freezes": self.log.count(Ev.FREEZE),
            "peak_pool_mb": self.peak_pool_mb,
        }


class Replay:
    def __init__(self, traces: list, priorities: list, policy: BasePolicy,
                 cfg: ReplayConfig, *, program=None, backend=None):
        """``backend``: any ``Backend`` to drive the replay through
        (default: a fresh host tree).  Lets the chaos harness run the
        whole simulation over a ``FaultyBackend`` — with a transient-
        only plan and auto-retry the results must be bit-identical to
        the default run."""
        assert len(traces) == len(priorities)
        self.cfg = cfg
        self.policy = policy
        self.cg = AgentCgroup(backend if backend is not None
                              else HostTreeBackend(cfg.capacity_mb))
        if program is not None:
            self.cg.attach("/", program)
        self.log = self.cg.log
        self.accounting = Accounting()
        self.now_ms = 0.0
        self.peak_pool = 0
        self.tasks: list[SimTask] = []
        for i, (tr, prio) in enumerate(zip(traces, priorities)):
            key = f"t{i}_{tr.task_id.replace('/', '_').replace('#', '_')}"
            ev = to_alloc_events(tr, accel=cfg.accel)
            spans = [(c.t_start_s * 1000.0 / cfg.accel,
                      c.t_end_s * 1000.0 / cfg.accel, c)
                     for c in sorted(tr.tool_calls, key=lambda c: c.t_start_s)]
            t = SimTask(key=key, trace=tr, priority=prio, events=ev,
                        spans=spans,
                        ideal_ms=(ev[-1].t_ms if ev else 0.0))
            t.next_due_ms = ev[0].t_ms if ev else 0.0
            self.tasks.append(t)
        # semantic OOM escalation: active only when the policy opts in
        # (baselines have no ``escalation`` attribute — nothing changes)
        esc_policy = getattr(policy, "escalation", None)
        self._escalator = (Escalator(self.cg, esc_policy, WasteLedger())
                           if esc_policy is not None else None)
        policy.setup(self, self.tasks)

    @property
    def waste_ledger(self) -> Optional[WasteLedger]:
        return self._escalator.ledger if self._escalator else None

    # ------------------------------------------------- policy-facing API

    def running_tasks(self) -> list:
        return [t for t in self.tasks if t.running and not t.frozen]

    def stall_ms(self, task: SimTask) -> float:
        return (self.now_ms - task.stall_since_ms
                if task.stall_since_ms is not None else 0.0)

    def current_call(self, task: SimTask) -> Optional[ToolCall]:
        if task.open_span >= 0:
            return task.spans[task.open_span][2]
        return None

    def kill_task(self, task: SimTask, reason: str, *,
                  allow_escalation: bool = True) -> None:
        """Kill the task's session domain.  With escalation active and
        an open tool lease, the kill is absorbed at tool-call
        granularity first: the lease is killed and retried at a
        negotiated limit, and only exhaustion kills the session."""
        if not task.running:
            return
        if (allow_escalation and self._escalator is not None
                and getattr(self.policy, "open_lease",
                            lambda t: None)(task) is not None):
            if self.escalate_tool_call(task):
                return                   # retry scheduled; task survives
            return                       # exhausted: task already killed
        path = self.policy.domain_for(task)
        if self.cg.exists(path):
            self.cg.kill(path)
        task.killed = True
        task.kill_reason = reason
        task.finish_ms = self.now_ms
        task.stall_since_ms = None
        task.pending_mb = None

    def escalate_tool_call(self, task: SimTask) -> bool:
        """Kill the task's open tool lease (delivering the typed
        ``OomEvent``) and retry the call at the negotiated limit:
        rewind the event cursor to the span start, schedule the retry
        after the jittered backoff.  Returns False when the attempt
        budget is exhausted — the task is then killed for real."""
        lease = self.policy.open_lease(task)
        if self._escalator is None or lease is None:
            self.kill_task(task, "memcg_max", allow_escalation=False)
            return False
        call_key = f"{task.key}:{lease.tool_id}"
        freed = self.cg.kill(lease.path) if not lease.killed else 0
        self._escalator.ledger.record_kill(
            call_key, attempt_pages=freed, baseline_pages=task.usage_mb)
        task.usage_mb = max(0, task.usage_mb - freed)
        try:
            new_lease, neg = self._escalator.escalate(lease)
        except EscalationExhausted:
            self.policy.replace_lease(task, None)
            self.kill_task(task, "escalation_exhausted",
                           allow_escalation=False)
            return False
        self.policy.replace_lease(task, new_lease)
        # rewind to the span start: the retry replays the tool call's
        # allocations under the new limit (the kill released them all)
        if task.open_span >= 0:
            s, _, _ = task.spans[task.open_span]
            while task.idx > 0 and task.events[task.idx - 1].t_ms >= s:
                task.idx -= 1
        task.pending_mb = None
        task.stall_since_ms = None
        task.next_due_ms = self.now_ms + neg.backoff_ms
        return True

    def frozen_tasks(self) -> list:
        return [t for t in self.tasks if t.running and t.frozen]

    def freeze_task(self, task: SimTask) -> None:
        """Freeze = cgroup.freeze + OFFLOAD: the session's pool pages move
        to host swap (core/freezer semantics), releasing the contended
        resource while preserving the session's context."""
        if task.frozen:
            return
        path = self.policy.domain_for(task)
        usage = self.cg.usage(path)
        task.frozen_mb = usage
        if usage:
            self.cg.uncharge(path, usage)
        self.cg.freeze(path)
        task.frozen = True
        task.frozen_since = self.now_ms

    def thaw_task(self, task: SimTask) -> bool:
        """Thaw = re-charge the offloaded pages + resume.  Fails (stays
        frozen) if the pool cannot host the pages again yet."""
        if not task.frozen:
            return True
        if task.frozen_mb > self.cg.free():
            return False            # no headroom yet; stay frozen quietly
        path = self.policy.domain_for(task)
        self.cg.thaw(path)
        if task.frozen_mb:
            ticket = self.cg.try_charge(path, task.frozen_mb)
            if not ticket.granted:
                self.cg.freeze(path)
                return False
        task.frozen_mb = 0
        task.frozen = False
        task.frozen_total_ms += self.now_ms - task.frozen_since
        task.next_due_ms = max(task.next_due_ms, self.now_ms)
        return True

    # ------------------------------------------------------------ physics

    def _grant_latency(self, mb: int, protected: bool) -> float:
        """Allocation physics: base cost + direct-reclaim under pressure.

        ``protected`` = the domain is under below-``low`` protection and
        the policy already did the reclaim work proactively (by
        throttling siblings) — the allocation skips direct reclaim, the
        mechanism behind Fig 8(b)'s HIGH-priority latency win."""
        cfg = self.cfg
        floor_mb = cfg.pressure_floor * self.cg.capacity
        deficit = self.cg.usage("/") - floor_mb
        lat = cfg.base_alloc_ms
        if deficit > 0:
            scale = cfg.protection_discount if protected else 1.0
            lat += scale * cfg.reclaim_ms_per_deficit_mb * deficit
        return lat

    # --------------------------------------------------------------- run

    def _sync_spans(self, task: SimTask, t_local_ms: float) -> None:
        """Open/close tool spans as the task's local clock passes them."""
        if task.open_span >= 0:
            s, e, call = task.spans[task.open_span]
            if t_local_ms >= e:
                self.policy.on_tool_end(self, task, call)
                task.scale_rest_of_tool = 1.0
                task.span_done = task.open_span + 1
                task.open_span = -1
        while task.open_span < 0 and task.span_done < len(task.spans):
            s, e, call = task.spans[task.span_done]
            if t_local_ms < s:
                break
            self.policy.on_tool_start(self, task, call)
            if t_local_ms < e:
                task.open_span = task.span_done
                break
            # span passed entirely between two events: fire start+end
            self.policy.on_tool_end(self, task, call)
            task.scale_rest_of_tool = 1.0
            task.span_done += 1

    def _process_event(self, task: SimTask) -> bool:
        """Try the task's next event.  True if it was consumed."""
        ev: AllocEvent = task.events[task.idx]
        self._sync_spans(task, ev.t_ms)
        if ev.delta_mb >= 0:
            mb = task.pending_mb
            if mb is None:
                mb = max(0, int(round(ev.delta_mb * task.scale_rest_of_tool)))
            if mb == 0:
                task.idx += 1
                task.pending_mb = None
                task.stall_since_ms = None
                if task.idx < len(task.events):
                    gap = task.events[task.idx].t_ms - ev.t_ms
                    task.next_due_ms = self.now_ms + gap
                return True
            out: AllocOutcome = self.policy.on_alloc(self, task, mb)
            if out.granted:
                stall = self.stall_ms(task)
                phys = self._grant_latency(mb, out.protected)
                lat = stall + out.delay_ms + phys
                self.accounting.record_alloc(f"prio{task.priority}",
                                             self.now_ms, lat)
                self.accounting.record_alloc("root", self.now_ms,
                                             lat if lat > 1e-3 else 0.0)
                task.usage_mb += mb
                task.stall_since_ms = None
                task.pending_mb = None
                task.idx += 1
                # backpressure: the task itself is delayed by its stall
                delay = out.delay_ms + phys
                if task.idx < len(task.events):
                    gap = task.events[task.idx].t_ms - ev.t_ms
                    task.next_due_ms = self.now_ms + gap + delay
                return True
            # not granted
            if task.killed or out.kill:
                # killed outright, or the call was escalated: the event
                # cursor/backoff were already reset — don't stall
                return False
            task.pending_mb = mb
            if task.stall_since_ms is None:
                task.stall_since_ms = self.now_ms
            if out.feedback is not None:
                # strategy reconstruction: retry with reduced scope
                agent = getattr(self.policy, "agent_model", None)
                if agent is not None:
                    adj = agent.on_feedback(
                        getattr(ev.tool, "category", "unknown"), out.feedback)
                    task.scale_rest_of_tool = adj["scale"]
                    task.pending_mb = max(1, int(mb * adj["scale"]))
            return False
        # release
        mb = min(int(round(-ev.delta_mb)), task.usage_mb)
        if mb > 0:
            self.policy.on_release(self, task, mb)
            task.usage_mb -= mb
        task.idx += 1
        task.pending_mb = None
        task.stall_since_ms = None
        if task.idx < len(task.events):
            gap = task.events[task.idx].t_ms - ev.t_ms
            task.next_due_ms = self.now_ms + gap
        return True

    def run(self) -> ReplayResult:
        cfg = self.cfg
        while any(t.running for t in self.tasks) and self.now_ms < cfg.max_sim_ms:
            self.now_ms += cfg.tick_ms
            self.cg.set_time(self.now_ms)
            for task in self.tasks:
                if not task.running or task.frozen:
                    continue
                n = 0
                while (task.running and not task.frozen
                       and task.idx < len(task.events)
                       and task.next_due_ms <= self.now_ms
                       and n < cfg.max_events_per_tick):
                    if not self._process_event(task):
                        # stalled: PSI sees the ongoing stall this tick
                        self.accounting.record_alloc("root", self.now_ms,
                                                     cfg.tick_ms)
                        break
                    n += 1
                if task.running and task.idx >= len(task.events):
                    task.done = True
                    task.finish_ms = self.now_ms
                    self.policy.on_task_end(self, task)
                    self.log.emit(self.now_ms, Ev.DONE, task.key)
            self.peak_pool = max(self.peak_pool, self.cg.usage("/"))
            self.policy.tick(self)
        results = {
            t.key: TaskResult(completed=t.done, killed=t.killed,
                              kill_reason=t.kill_reason,
                              finish_ms=t.finish_ms, ideal_ms=t.ideal_ms,
                              frozen_ms=t.frozen_total_ms)
            for t in self.tasks
        }
        return ReplayResult(self.policy.name, results, self.accounting,
                            self.log, self.peak_pool,
                            escalation=(self._escalator.ledger.summary()
                                        if self._escalator else None))


def replay(traces: list, priorities: list, policy: BasePolicy,
           cfg: ReplayConfig) -> ReplayResult:
    return Replay(traces, priorities, policy, cfg).run()

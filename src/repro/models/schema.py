"""Single-source parameter schemas with *logical* sharding axes.

Each module declares its parameters once as a nested dict of ``Leaf``
entries (shape + logical partition spec + init kind).  From that one
schema we derive: random initialization, ``jax.ShapeDtypeStruct`` trees
(for the dry-run's allocation-free lowering), and ``NamedSharding``
trees.

Logical axes (resolved to mesh axes by a rules dict, MaxText-style):

  ``tp``     tensor-parallel dim (attention heads / ffn hidden / vocab)
  ``fsdp``   weight-sharded dim (ZeRO-3-style, usually d_model)
  ``ep``     expert dim of MoE expert stacks
  ``ep2``    inner dim of MoE expert stacks (sharded to fit HBM at serve)
  ``layers`` stacked scan-group dim (never mesh-sharded)

Default rule sets live in ``RULES`` — ``train`` shards weights over
(fsdp=data, tp=model); ``serve`` keeps weights replicated over data
except expert stacks (which would not fit one chip's HBM otherwise).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# mesh-axis rule sets; entries may be a mesh axis name, a tuple of mesh
# axes, or None (replicated).
RULES: dict[str, dict[str, Any]] = {
    "train": {"tp": "model", "fsdp": "data", "ep": "model", "ep2": "data",
              "layers": None},
    "serve": {"tp": "model", "fsdp": None, "ep": "model", "ep2": "data",
              "layers": None},
    "replicated": {"tp": None, "fsdp": None, "ep": None, "ep2": None,
                   "layers": None},
}


@dataclass(frozen=True)
class Leaf:
    shape: tuple[int, ...]
    spec: tuple = ()            # logical-axis entries, padded with None to rank
    init: str = "normal"        # normal | zeros | ones | small
    dtype: Optional[str] = None  # None -> model default
    scale: float = 0.02

    def pspec(self, rules: dict | None = None) -> P:
        rules = rules or RULES["replicated"]
        ent = tuple(self.spec) + (None,) * (len(self.shape) - len(self.spec))
        resolved = []
        for e in ent:
            if e is None:
                resolved.append(None)
            elif isinstance(e, tuple):  # composite logical axes
                axes = tuple(a for x in e for a in _as_tuple(rules.get(x, x)))
                resolved.append(axes if axes else None)
            else:
                r = rules.get(e, e)
                resolved.append(r)
        return P(*resolved)


def _as_tuple(x):
    if x is None:
        return ()
    return x if isinstance(x, tuple) else (x,)


def stack_leaf(leaf: Leaf, n: int) -> Leaf:
    """Add a leading stacked-layers axis (for scan-over-groups)."""
    return Leaf((n,) + tuple(leaf.shape), ("layers",) + tuple(leaf.spec),
                leaf.init, leaf.dtype, leaf.scale)


def is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def tree_map_schema(fn, schema):
    """Map ``fn`` over Leaf entries of a nested-dict schema."""
    if is_leaf(schema):
        return fn(schema)
    if isinstance(schema, dict):
        return {k: tree_map_schema(fn, v) for k, v in schema.items()}
    if isinstance(schema, (list, tuple)):
        return type(schema)(tree_map_schema(fn, v) for v in schema)
    raise TypeError(type(schema))


def _flatten(schema, path=()):
    if is_leaf(schema):
        yield path, schema
        return
    if isinstance(schema, dict):
        items = schema.items()
    else:
        items = enumerate(schema)
    for k, v in items:
        yield from _flatten(v, path + (str(k),))


def flatten_schema(schema) -> list[tuple[tuple[str, ...], Leaf]]:
    return list(_flatten(schema))


def shape_structs(schema, default_dtype: str = "bfloat16", mesh=None,
                  rules: dict | None = None):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation).

    With ``mesh`` the structs carry shardings so ``jit.lower`` sees the
    production layout without allocating anything.
    """
    def mk(l: Leaf):
        dt = jnp.dtype(l.dtype or default_dtype)
        if mesh is None:
            return jax.ShapeDtypeStruct(l.shape, dt)
        return jax.ShapeDtypeStruct(
            l.shape, dt, sharding=NamedSharding(mesh, l.pspec(rules)))
    return tree_map_schema(mk, schema)


def pspecs(schema, rules: dict | None = None):
    return tree_map_schema(lambda l: l.pspec(rules), schema)


def shardings(schema, mesh, rules: dict | None = None):
    return tree_map_schema(lambda l: NamedSharding(mesh, l.pspec(rules)), schema)


def _stable_hash(s: str) -> int:
    return zlib.crc32(s.encode())


def init_params(schema, key: jax.Array, default_dtype: str = "bfloat16"):
    """Deterministic per-path initialization (fold stable path hash into key)."""
    flat = flatten_schema(schema)

    def leaf_init(path, leaf: Leaf):
        dt = jnp.dtype(leaf.dtype or default_dtype)
        if leaf.init == "zeros":
            return jnp.zeros(leaf.shape, dt)
        if leaf.init == "ones":
            return jnp.ones(leaf.shape, dt)
        k = jax.random.fold_in(key, _stable_hash("/".join(path)))
        scale = leaf.scale if leaf.init != "small" else leaf.scale * 0.1
        return (jax.random.normal(k, leaf.shape, jnp.float32) * scale).astype(dt)

    out: dict = {}
    for path, leaf in flat:
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = leaf_init(path, leaf)
    return _restructure(schema, out)


def _restructure(schema, flat_dict):
    """Rebuild lists/tuples that were dict-ified by path insertion."""
    if is_leaf(schema):
        return flat_dict
    if isinstance(schema, dict):
        return {k: _restructure(v, flat_dict[k]) for k, v in schema.items()}
    if isinstance(schema, (list, tuple)):
        return type(schema)(_restructure(v, flat_dict[str(i)]) for i, v in enumerate(schema))
    raise TypeError(type(schema))


def param_bytes(schema, default_dtype: str = "bfloat16") -> int:
    total = 0
    for _, leaf in flatten_schema(schema):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n * jnp.dtype(leaf.dtype or default_dtype).itemsize
    return total

"""Mixture-of-experts FFN (routed top-k + optional shared experts).

Two dispatch implementations, selectable via ``PerfConfig.moe_impl``:

  * ``dense``  — masked all-experts einsum, token-blocked with ``lax.map``
    so peak memory stays bounded.  Every expert processes every token and
    the router gate zeroes the unused results.  Simple, sharding-robust —
    and wasteful by a factor of E/k FLOPs.  This is the *baseline* the
    roofline's MODEL_FLOPS/HLO_FLOPs ratio exposes.
  * ``gather`` — capacity-based dispatch (Switch/GShard): tokens are
    ranked per expert, dropped beyond capacity, gathered into (E, C, d)
    buffers, processed by grouped matmuls, and combined with gates.
    FLOPs scale with k, not E — the §Perf hillclimb step.

Expert stacks are sharded E over ``ep`` (model axis) and d over ``ep2``
(data axis) so the 236B/400B configs fit per-chip HBM at serve time.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig
from repro.models.schema import Leaf
from repro.perf import PerfConfig, DEFAULT_PERF
from repro.sharding_ctx import constrain


def moe_schema(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_ff_expert, m.n_experts
    # experts: E over ep (model axis), f over ep2 (data axis).  Sharding
    # the FF dim (not d) lets the a2a dispatch run both GEMMs locally
    # with a single psum on the down-projection.
    sch = {
        "router": Leaf((d, E), dtype="float32"),
        "wg": Leaf((E, d, f), spec=("ep", None, "ep2")),
        "wu": Leaf((E, d, f), spec=("ep", None, "ep2")),
        "wd": Leaf((E, f, d), spec=("ep", "ep2"), init="small"),
    }
    if m.n_shared:
        fs = f * m.n_shared
        sch["shared"] = {
            "wg": Leaf((d, fs), spec=("fsdp", "tp")),
            "wu": Leaf((d, fs), spec=("fsdp", "tp")),
            "wd": Leaf((fs, d), spec=("tp", "fsdp"), init="small"),
        }
    return sch


def _router(cfg: ModelConfig, p, xf):
    """xf: (T, d) -> (probs (T,E) fp32, top-k ids (T,k), top-k gates (T,k))."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return probs, ids, gates


def _aux_loss(cfg: ModelConfig, probs, ids):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    m = cfg.moe
    E = m.n_experts
    # fraction of (token, slot) assignments routed to each expert
    fe = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    fe = fe / jnp.maximum(ids.size, 1)
    pe = probs.mean(axis=0)
    return m.aux_coef * E * jnp.sum(fe * pe)


def _swiglu(x, wg, wu, wd):
    g = jnp.einsum("...td,edf->...tef", x, wg)
    u = jnp.einsum("...td,edf->...tef", x, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...tef,efd->...ted", h, wd)


def _dense_dispatch(cfg: ModelConfig, p, xf, ids, gates, *, token_block: int):
    """All-experts masked compute, token-blocked to bound peak memory."""
    m = cfg.moe
    T, d = xf.shape
    E = m.n_experts
    tb = min(token_block, T)
    pad = (-T) % tb
    xp = jnp.pad(xf, ((0, pad), (0, 0))).reshape(-1, tb, d)
    # per-token combine weights over experts (T, E)
    comb = jnp.zeros((T, E), xf.dtype)
    comb = comb.at[jnp.arange(T)[:, None], ids].add(gates.astype(xf.dtype))
    comb = jnp.pad(comb, ((0, pad), (0, 0))).reshape(-1, tb, E)

    def block(args):
        xb, cb = args                         # (tb, d), (tb, E)
        yb = _swiglu(xb, p["wg"], p["wu"], p["wd"])   # (tb, E, d)
        return jnp.einsum("ted,te->td", yb, cb)

    y = jax.lax.map(block, (xp, comb))
    return y.reshape(-1, d)[:T]


def _gather_dispatch(cfg: ModelConfig, p, xf, ids, gates, *,
                     capacity_factor: float):
    """Capacity-based dispatch: FLOPs scale with top_k, not n_experts."""
    m = cfg.moe
    T, d = xf.shape
    E, k = m.n_experts, m.top_k
    Tk = T * k
    cap = max(int(capacity_factor * Tk / E) + 1, 4)

    eid = ids.reshape(-1)                              # (Tk,)
    gate = gates.reshape(-1)
    tok = jnp.repeat(jnp.arange(T), k)
    # xrep replaces xf[tok]: the row pattern is static (each token row
    # repeated k times), so GSPMD shards it like xf instead of treating
    # it as a data-dependent gather (which it would replicate)
    xrep = jnp.repeat(xf, k, axis=0)                   # (Tk, d)

    # position of each assignment within its expert (stable rank)
    order = jnp.argsort(eid, stable=True)
    counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
    starts = jnp.cumsum(counts) - counts
    rank_sorted = jnp.arange(Tk, dtype=jnp.int32) - starts[eid[order]]
    pos = jnp.zeros((Tk,), jnp.int32).at[order].set(rank_sorted)
    keep = pos < cap
    posc = jnp.minimum(pos, cap - 1)

    # dispatch into (E, cap, d) buffers; constrain the expert buffers to
    # the expert-parallel layout (E over ep, d over ep2) — without this
    # GSPMD replicates the scatter result on every device
    buf = jnp.zeros((E, cap, d), xf.dtype)
    contrib = jnp.where(keep[:, None], xrep, 0).astype(xf.dtype)
    buf = buf.at[eid, posc].add(contrib)
    buf = constrain(buf, ("ep",))

    # grouped expert GEMMs: each expert sees only its (cap, d) buffer
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["wu"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(xf.dtype) * u
    h = constrain(h, ("ep",))
    yb = jnp.einsum("ecf,efd->ecd", h, p["wd"])        # (E, cap, d)
    yb = constrain(yb, ("ep",))

    gathered = yb[eid, posc] * (gate * keep)[:, None].astype(xf.dtype)
    y = jnp.zeros((T, d), xf.dtype).at[tok].add(gathered)
    return constrain(y, ("act_batch",))


def _a2a_dispatch(cfg: ModelConfig, p, x, *, capacity_factor: float,
                  mesh, rules):
    """Expert-parallel dispatch with explicit all_to_all (shard_map).

    Per device: route LOCAL tokens, pack them into (E, c_loc, d) buffers
    (local scatter — no cross-device scatter for GSPMD to replicate),
    all_to_all over the expert axis so each device receives its own
    experts' tokens from every peer, run the expert GEMMs locally
    (f sharded over the data axis; one psum on the down-projection),
    reverse the all_to_all, and combine with gates.

    This is the production EP pattern; the pure-GSPMD 'gather' dispatch
    all-reduces whole (E, cap, d) buffers per layer instead (see
    EXPERIMENTS.md §Perf).
    """
    m = cfg.moe
    d = x.shape[-1]
    E, k = m.n_experts, m.top_k
    ep_axis = rules.get("ep")                     # mesh axis holding E
    ep2_axis = rules.get("ep2")                   # mesh axis holding f
    n_ep = mesh.shape[ep_axis]
    assert E % n_ep == 0
    e_loc = E // n_ep
    batch_axes = rules.get("act_batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    seq_axis = rules.get("act_seq")
    other = tuple(a for a in mesh.axis_names
                  if a not in (*batch_axes, seq_axis, ep_axis, ep2_axis))

    from jax.sharding import PartitionSpec as P
    x_spec = P(tuple(batch_axes) or None, seq_axis, None)
    w_up_spec = P(ep_axis, None, ep2_axis)
    w_dn_spec = P(ep_axis, ep2_axis, None)
    out_specs = (x_spec, P())

    def body(xl, router, wg, wu, wd):
        Tl = xl.shape[0] * xl.shape[1]
        xf = xl.reshape(Tl, d)
        probs, ids, gates = _router(cfg, {"router": router}, xf)
        aux = _aux_loss(cfg, probs, ids)
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        c_loc = max(int(capacity_factor * Tl * k / E) + 1, 4)
        eid = ids.reshape(-1)
        gate = gates.reshape(-1)
        tok = jnp.repeat(jnp.arange(Tl), k)
        order = jnp.argsort(eid)
        counts = jnp.zeros((E,), jnp.int32).at[eid].add(1)
        starts = jnp.cumsum(counts) - counts
        rank = jnp.zeros((Tl * k,), jnp.int32).at[order].set(
            jnp.arange(Tl * k, dtype=jnp.int32) - starts[eid[order]])
        keep = rank < c_loc
        pos = jnp.minimum(rank, c_loc - 1)
        buf = jnp.zeros((E, c_loc, d), xl.dtype)
        buf = buf.at[eid, pos].add(
            jnp.where(keep[:, None], jnp.repeat(xf, k, axis=0), 0))
        # all_to_all over the expert axis: block j of my buffer goes to
        # peer j; I receive every peer's block for MY local experts
        recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        rows = (recv.reshape(n_ep, e_loc, c_loc, d)
                .transpose(1, 0, 2, 3).reshape(e_loc, n_ep * c_loc, d))
        # FSDP-style expert-weight gather over ep2 (tokens differ across
        # that axis, so f-partials cannot be psummed; gathering the
        # weights keeps the GEMMs fully local — grads reduce-scatter
        # automatically through the all_gather VJP)
        if ep2_axis is not None:
            wg = jax.lax.all_gather(wg, ep2_axis, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, ep2_axis, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, ep2_axis, axis=1, tiled=True)
        g = jnp.einsum("ecd,edf->ecf", rows, wg)
        u = jnp.einsum("ecd,edf->ecf", rows, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(rows.dtype) * u
        yd = jnp.einsum("ecf,efd->ecd", h, wd)
        # reverse exchange back to the token owners
        back = (yd.reshape(e_loc, n_ep, c_loc, d)
                .transpose(1, 0, 2, 3).reshape(E, c_loc, d))
        sent = jax.lax.all_to_all(back, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        gathered = sent[eid, pos] * (gate * keep)[:, None].astype(xl.dtype)
        y = jnp.zeros((Tl, d), xl.dtype).at[tok].add(gathered)
        return y.reshape(xl.shape), aux

    fn = compat.shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, P(), w_up_spec, w_up_spec, w_dn_spec),
        out_specs=out_specs, check_rep=False)
    y, aux = fn(x, p["router"], p["wg"], p["wu"], p["wd"])
    return y, aux


def moe_forward(cfg: ModelConfig, p, x, *, perf: PerfConfig = DEFAULT_PERF):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar fp32)."""
    from repro.sharding_ctx import current_mesh, current_rules
    m = cfg.moe
    B, S, d = x.shape
    impl = perf.moe_impl
    mesh, rules = current_mesh(), current_rules()
    if impl == "a2a" and (mesh is None or rules is None
                          or rules.get("ep") is None
                          or rules.get("act_seq") is None):
        # a2a pays an FSDP-style expert-weight gather per layer — right
        # for full-sequence cells (train/prefill), wrong for decode's
        # handful of tokens; decode keeps the capacity-gather path
        impl = "gather"
    if impl == "a2a":
        y, aux = _a2a_dispatch(cfg, p, x, mesh=mesh, rules=rules,
                               capacity_factor=perf.capacity_factor)
        if m.n_shared:
            s = p["shared"]
            xf = x.reshape(-1, d)
            g = jnp.einsum("td,df->tf", xf, s["wg"])
            u = jnp.einsum("td,df->tf", xf, s["wu"])
            h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
            y = y + jnp.einsum("tf,fd->td", h, s["wd"]).reshape(B, S, d)
        return y, aux
    xf = x.reshape(-1, d)
    probs, ids, gates = _router(cfg, p, xf)
    if impl == "dense":
        y = _dense_dispatch(cfg, p, xf, ids, gates, token_block=1024)
    elif impl == "gather":
        y = _gather_dispatch(cfg, p, xf, ids, gates,
                             capacity_factor=perf.capacity_factor)
    else:
        raise ValueError(f"unknown moe impl {perf.moe_impl!r}")
    if m.n_shared:
        s = p["shared"]
        g = jnp.einsum("td,df->tf", xf, s["wg"])
        u = jnp.einsum("td,df->tf", xf, s["wu"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        y = y + jnp.einsum("tf,fd->td", h, s["wd"])
    return y.reshape(B, S, d), _aux_loss(cfg, probs, ids)

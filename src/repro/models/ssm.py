"""Mamba-2 (SSD) sequence-mixer block — jamba's non-attention layers.

Forward uses the chunk-parallel SSD scan (``kernels/ops.ssd``); decode
keeps a tiny O(1) recurrent state per layer:
  conv state (B, d_in, d_conv-1)  +  SSD state (B, nh, dh, N).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import Leaf
from repro.kernels import ops
from repro.perf import PerfConfig, DEFAULT_PERF


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    assert d_in % s.n_ssm_heads == 0
    return s, d_in, s.n_ssm_heads, d_in // s.n_ssm_heads


def mamba_schema(cfg: ModelConfig) -> dict:
    s, d_in, nh, dh = _dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": Leaf((d, 2 * d_in), spec=("fsdp", "tp")),
        "conv_w": Leaf((d_in, s.d_conv), spec=("tp", None)),
        "conv_b": Leaf((d_in,), init="zeros"),
        "x_to_dt": Leaf((d_in, nh), spec=("tp", None)),
        "dt_bias": Leaf((nh,), init="zeros"),
        "x_to_bc": Leaf((d_in, 2 * s.d_state), spec=("tp", None)),
        "a_log": Leaf((nh,), init="zeros", dtype="float32"),   # A = -exp(a_log)
        "d_skip": Leaf((nh,), init="ones", dtype="float32"),
        "norm": Leaf((d_in,), init="ones"),
        "out_proj": Leaf((d_in, d), spec=("tp", "fsdp"), init="small"),
    }


def _causal_conv(w, b, x, *, init_state=None):
    """Depthwise causal conv over S via shifted adds.  x: (B, S, d_in);
    w: (d_in, k).  init_state: (B, k-1, d_in) previous inputs or None."""
    k = w.shape[1]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+k-1, d_in)
    S = x.shape[1]
    out = sum(xp[:, j:j + S] * w[:, j][None, None] for j in range(k))
    return out + b[None, None]


def _split_heads(x, nh):
    b, s, d_in = x.shape
    return x.reshape(b, s, nh, d_in // nh)


def mamba_forward(cfg: ModelConfig, p, x, *, perf: PerfConfig = DEFAULT_PERF):
    """x: (B, S, d) -> (B, S, d)."""
    s, d_in, nh, dh = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :d_in], xz[..., d_in:]
    xc = jax.nn.silu(_causal_conv(p["conv_w"], p["conv_b"], xi)
                     .astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(
        jnp.einsum("bse,eh->bsh", xc, p["x_to_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    bc = jnp.einsum("bse,en->bsn", xc, p["x_to_bc"])
    Bm, Cm = bc[..., :s.d_state], bc[..., s.d_state:]
    A = -jnp.exp(p["a_log"])
    y, _ = ops.ssd(_split_heads(xc, nh), dt, A, Bm, Cm, p["d_skip"],
                   chunk=min(perf.scan_chunk, s.chunk))
    y = y.reshape(*x.shape[:2], d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    # gated RMSNorm (Mamba-2 style)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def mamba_state_schema(cfg: ModelConfig, batch: int) -> dict:
    s, d_in, nh, dh = _dims(cfg)
    return {
        "conv": Leaf((batch, s.d_conv - 1, d_in), spec=("act_batch", None, "tp"),
                     init="zeros"),
        "h": Leaf((batch, nh, dh, s.d_state), spec=("act_batch", None, "tp"),
                  init="zeros", dtype="float32"),
    }


def mamba_decode(cfg: ModelConfig, p, x, state, *,
                 perf: PerfConfig = DEFAULT_PERF):
    """x: (B, 1, d); state {conv, h}.  Returns (out (B,1,d), new_state)."""
    s, d_in, nh, dh = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :d_in], xz[..., d_in:]
    xc = _causal_conv(p["conv_w"], p["conv_b"], xi, init_state=state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    new_conv = jnp.concatenate([state["conv"][:, 1:], xi.astype(state["conv"].dtype)],
                               axis=1)
    dt = jax.nn.softplus(
        jnp.einsum("bse,eh->bsh", xc, p["x_to_dt"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))
    bc = jnp.einsum("bse,en->bsn", xc, p["x_to_bc"])
    Bm, Cm = bc[..., :s.d_state], bc[..., s.d_state:]
    A = -jnp.exp(p["a_log"])
    y, h_new = ops.ssd_decode(state["h"], _split_heads(xc, nh)[:, 0], dt[:, 0],
                              A, Bm[:, 0], Cm[:, 0], p["d_skip"])
    y = y.reshape(x.shape[0], 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": new_conv, "h": h_new.astype(state["h"].dtype)}

"""xLSTM blocks: mLSTM (matrix memory, chunk-parallel) and sLSTM
(scalar memory, sequential recurrence with exponential gating).

Simplifications vs the paper, recorded in DESIGN.md:
  * sLSTM's block-diagonal recurrent matrices -> diagonal (per-unit)
    recurrent weights.
  * both blocks share the mLSTM pre-up-projection structure
    (proj_factor 2.0) instead of sLSTM's post-MLP variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import Leaf
from repro.kernels import ops
from repro.perf import PerfConfig, DEFAULT_PERF


def _dims(cfg: ModelConfig):
    x = cfg.xlstm
    d_in = int(x.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    assert d_in % nh == 0
    return x, d_in, nh, d_in // nh


# ==================================================================== mLSTM


def mlstm_schema(cfg: ModelConfig) -> dict:
    x, d_in, nh, dh = _dims(cfg)
    d = cfg.d_model
    return {
        "up": Leaf((d, 2 * d_in), spec=("fsdp", "tp")),
        "conv_w": Leaf((d_in, x.conv_kernel), spec=("tp", None)),
        "conv_b": Leaf((d_in,), init="zeros"),
        "wq": Leaf((d_in, d_in), spec=("tp", None)),
        "wk": Leaf((d_in, d_in), spec=("tp", None)),
        "wv": Leaf((d_in, d_in), spec=("tp", None)),
        "w_i": Leaf((d_in, nh), spec=("tp", None), init="small"),
        "b_i": Leaf((nh,), init="zeros", dtype="float32"),
        "w_f": Leaf((d_in, nh), spec=("tp", None), init="small"),
        "b_f": Leaf((nh,), init="ones", dtype="float32", scale=3.0),
        "norm": Leaf((d_in,), init="ones"),
        "down": Leaf((d_in, d), spec=("tp", "fsdp"), init="small"),
    }


def _causal_conv(w, b, x, init_state=None):
    k = w.shape[1]
    if init_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    S = x.shape[1]
    out = sum(xp[:, j:j + S] * w[:, j][None, None] for j in range(k))
    return out + b[None, None]


def _heads(t, nh):
    b, s, d_in = t.shape
    return t.reshape(b, s, nh, d_in // nh)


def _mlstm_qkvif(cfg, p, xi):
    x, d_in, nh, dh = _dims(cfg)
    xc = jax.nn.silu(_causal_conv(p["conv_w"], p["conv_b"], xi)
                     .astype(jnp.float32)).astype(xi.dtype)
    q = _heads(jnp.einsum("bse,ef->bsf", xc, p["wq"]), nh)
    k = _heads(jnp.einsum("bse,ef->bsf", xc, p["wk"]), nh)
    v = _heads(jnp.einsum("bse,ef->bsf", xi, p["wv"]), nh)
    ig = jnp.einsum("bse,eh->bsh", xi, p["w_i"]).astype(jnp.float32) + p["b_i"]
    fg = jnp.einsum("bse,eh->bsh", xi, p["w_f"]).astype(jnp.float32) + p["b_f"]
    return xc, q, k, v, ig, fg


def _mlstm_out(cfg, p, y, z, shape):
    d_in = y.shape[-1] * y.shape[-2] if y.ndim == 4 else y.shape[-1]
    y = y.reshape(*shape[:2], d_in)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(z.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["down"])


def mlstm_forward(cfg: ModelConfig, p, x, *, perf: PerfConfig = DEFAULT_PERF):
    xcfg, d_in, nh, dh = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xi, z = xz[..., :d_in], xz[..., d_in:]
    _, q, k, v, ig, fg = _mlstm_qkvif(cfg, p, xi)
    y, _ = ops.mlstm(q, k, v, ig, fg, chunk=min(perf.scan_chunk, xcfg.chunk))
    return _mlstm_out(cfg, p, y, z, x.shape)


def mlstm_state_schema(cfg: ModelConfig, batch: int) -> dict:
    x, d_in, nh, dh = _dims(cfg)
    ab = ("act_batch",)
    return {
        "C": Leaf((batch, nh, dh, dh), spec=ab + (None, "tp"), init="zeros",
                  dtype="float32"),
        "n": Leaf((batch, nh, dh), spec=ab + (None, "tp"), init="zeros",
                  dtype="float32"),
        "m": Leaf((batch, nh), spec=ab, init="zeros", dtype="float32"),
        "conv": Leaf((batch, x.conv_kernel - 1, d_in), spec=ab + (None, "tp"),
                     init="zeros"),
    }


def mlstm_decode(cfg: ModelConfig, p, x, state, *,
                 perf: PerfConfig = DEFAULT_PERF):
    xcfg, d_in, nh, dh = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xi, z = xz[..., :d_in], xz[..., d_in:]
    xc = _causal_conv(p["conv_w"], p["conv_b"], xi, init_state=state["conv"])
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    new_conv = jnp.concatenate(
        [state["conv"][:, 1:], xi.astype(state["conv"].dtype)], axis=1)
    q = _heads(jnp.einsum("bse,ef->bsf", xc, p["wq"]), nh)[:, 0]
    k = _heads(jnp.einsum("bse,ef->bsf", xc, p["wk"]), nh)[:, 0]
    v = _heads(jnp.einsum("bse,ef->bsf", xi, p["wv"]), nh)[:, 0]
    ig = (jnp.einsum("be,eh->bh", xi[:, 0], p["w_i"]).astype(jnp.float32)
          + p["b_i"])
    fg = (jnp.einsum("be,eh->bh", xi[:, 0], p["w_f"]).astype(jnp.float32)
          + p["b_f"])
    y, (C, n, m) = ops.mlstm_decode(
        (state["C"], state["n"], state["m"]), q, k, v, ig, fg)
    out = _mlstm_out(cfg, p, y[:, None], z, x.shape)
    return out, {"C": C, "n": n, "m": m, "conv": new_conv}


# ==================================================================== sLSTM


def slstm_schema(cfg: ModelConfig) -> dict:
    x, d_in, nh, dh = _dims(cfg)
    d = cfg.d_model
    sch = {
        "up": Leaf((d, 2 * d_in), spec=("fsdp", "tp")),
        "norm": Leaf((d_in,), init="ones"),
        "down": Leaf((d_in, d), spec=("tp", "fsdp"), init="small"),
    }
    for g in ("i", "f", "z", "o"):
        sch[f"w_{g}"] = Leaf((d_in, d_in), spec=("tp", None), init="small")
        sch[f"r_{g}"] = Leaf((d_in,), init="small")     # diagonal recurrence
        sch[f"b_{g}"] = Leaf((d_in,), init="ones" if g == "f" else "zeros",
                             dtype="float32")
    return sch


def _slstm_scan(p, xi, state, *, time_chunk: int = 128):
    """Sequential sLSTM over S.  xi: (B, S, d_in) pre-activations source.

    The recurrence is inherently sequential (h feeds the gates), but the
    backward pass need not save every step's carry: the time axis is
    scanned in ``time_chunk`` blocks with ``jax.checkpoint`` on the
    inner scan, so only chunk-boundary states are saved and each chunk
    is recomputed during backprop (gradient checkpointing over time —
    cuts the train-cell's saved-state memory by ~time_chunk x)."""
    pre = {g: jnp.einsum("bse,ef->bsf", xi, p[f"w_{g}"]).astype(jnp.float32)
           + p[f"b_{g}"] for g in ("i", "f", "z", "o")}
    r = {g: p[f"r_{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    def step(carry, inp):
        c, n, m, h = carry
        pi, pf, pz, po = inp
        it = pi + r["i"] * h
        ft = pf + r["f"] * h
        zt = jnp.tanh(pz + r["z"] * h)
        ot = jax.nn.sigmoid(po + r["o"] * h)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m, it)
        fd = jnp.exp(logf + m - m_new)
        idc = jnp.exp(it - m_new)
        c = fd * c + idc * zt
        n = fd * n + idc
        h = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    S = xi.shape[1]
    inps = tuple(pre[g].transpose(1, 0, 2) for g in ("i", "f", "z", "o"))
    if S % time_chunk or S <= time_chunk:
        (c, n, m, h), ys = jax.lax.scan(step, state, inps)
        return ys.transpose(1, 0, 2), (c, n, m, h)

    nc = S // time_chunk
    inps_c = tuple(t.reshape(nc, time_chunk, *t.shape[1:]) for t in inps)

    @jax.checkpoint
    def chunk(carry, ci):
        return jax.lax.scan(step, carry, ci)

    (c, n, m, h), ys = jax.lax.scan(chunk, state, inps_c)
    ys = ys.reshape(S, *ys.shape[2:])
    return ys.transpose(1, 0, 2), (c, n, m, h)


def slstm_forward(cfg: ModelConfig, p, x, *, perf: PerfConfig = DEFAULT_PERF):
    xcfg, d_in, nh, dh = _dims(cfg)
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xi, z = xz[..., :d_in], xz[..., d_in:]
    zeros = jnp.zeros((B, d_in), jnp.float32)
    ys, _ = _slstm_scan(p, xi, (zeros, zeros, zeros, zeros))
    y = ys.astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bse,ed->bsd", y, p["down"])


def slstm_state_schema(cfg: ModelConfig, batch: int) -> dict:
    x, d_in, nh, dh = _dims(cfg)
    mk = lambda: Leaf((batch, d_in), spec=("act_batch", "tp"), init="zeros",
                      dtype="float32")
    return {"c": mk(), "n": mk(), "m": mk(), "h": mk()}


def slstm_decode(cfg: ModelConfig, p, x, state, *,
                 perf: PerfConfig = DEFAULT_PERF):
    xcfg, d_in, nh, dh = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["up"])
    xi, z = xz[..., :d_in], xz[..., d_in:]
    st = (state["c"], state["n"], state["m"], state["h"])
    ys, (c, n, m, h) = _slstm_scan(p, xi, st)
    y = ys.astype(x.dtype)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + cfg.norm_eps)
         * p["norm"].astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["down"])
    return out, {"c": c, "n": n, "m": m, "h": h}

"""Model assembly: embedding -> scanned layer groups -> norm -> head.

One assembly serves all 10 assigned architectures.  Layers are grouped
into ``cfg.n_groups`` identical groups of ``cfg.group_size`` layers
(parameters stacked on a leading axis, ``jax.lax.scan`` over groups);
within a group the (attention | mamba | mlstm | slstm) x (dense | moe |
none) pattern may be heterogeneous (jamba: 7 mamba + 1 attention, MoE
every other layer).

Three entry points:
  * ``forward``      — full-sequence logits (train / prefill cells).
  * ``loss_fn``      — CE (or masked-prediction CE for encoder-only).
  * ``decode_step``  — one token against per-layer caches/states (serve).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import attention, moe as moe_mod, ssm, xlstm
from repro.models.layers import (cross_entropy, embed_tokens, embedding_schema,
                                 lm_head, mlp, mlp_schema, rmsnorm,
                                 rmsnorm_schema, rope_table)
from repro.models.schema import Leaf, stack_leaf, tree_map_schema
from repro.perf import PerfConfig, DEFAULT_PERF
from repro.sharding_ctx import constrain

# ------------------------------------------------------------- schemas

_MIXER_SCHEMA = {
    "attn": attention.attn_schema,
    "mamba": ssm.mamba_schema,
    "mlstm": xlstm.mlstm_schema,
    "slstm": xlstm.slstm_schema,
}

_MIXER_STATE_SCHEMA = {
    "attn": lambda cfg, b, s_max: attention.attn_cache_schema(cfg, b, s_max),
    "mamba": lambda cfg, b, s_max: ssm.mamba_state_schema(cfg, b),
    "mlstm": lambda cfg, b, s_max: xlstm.mlstm_state_schema(cfg, b),
    "slstm": lambda cfg, b, s_max: xlstm.slstm_state_schema(cfg, b),
}


def group_schema(cfg: ModelConfig) -> list:
    """Per-position schemas for one layer group (not yet stacked)."""
    out = []
    for kind, ffn in zip(cfg.layer_kinds(), cfg.ffn_kinds()):
        ent = {"ln1": rmsnorm_schema(cfg.d_model),
               "mixer": _MIXER_SCHEMA[kind](cfg)}
        if ffn == "dense":
            ent["ln2"] = rmsnorm_schema(cfg.d_model)
            ent["ffn"] = mlp_schema(cfg.d_model, cfg.d_ff)
        elif ffn == "moe":
            ent["ln2"] = rmsnorm_schema(cfg.d_model)
            ent["ffn"] = moe_mod.moe_schema(cfg)
        out.append(ent)
    return out


def param_schema(cfg: ModelConfig) -> dict:
    stacked = tree_map_schema(lambda l: stack_leaf(l, cfg.n_groups),
                              group_schema(cfg))
    return {"embed": embedding_schema(cfg),
            "groups": stacked,
            "out_norm": rmsnorm_schema(cfg.d_model)}


def decode_state_schema(cfg: ModelConfig, batch: int, s_max: int) -> list:
    """Stacked (n_groups, ...) per-position mixer states for decode."""
    states = []
    for kind in cfg.layer_kinds():
        sch = _MIXER_STATE_SCHEMA[kind](cfg, batch, s_max)
        states.append(tree_map_schema(lambda l: stack_leaf(l, cfg.n_groups), sch))
    return states


# -------------------------------------------------------------- forward


def _apply_ffn(cfg, ffn_kind, p, x, perf):
    if ffn_kind == "none":
        return x, jnp.zeros((), jnp.float32)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if ffn_kind == "dense":
        return x + mlp(p["ffn"], h), jnp.zeros((), jnp.float32)
    y, aux = moe_mod.moe_forward(cfg, p["ffn"], h, perf=perf)
    return x + y, aux


def _apply_mixer(cfg, kind, p, x, cos, sin, causal, perf):
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        y = attention.attn_forward(cfg, p["mixer"], h, cos, sin,
                                   causal=causal, perf=perf)
    elif kind == "mamba":
        y = ssm.mamba_forward(cfg, p["mixer"], h, perf=perf)
    elif kind == "mlstm":
        y = xlstm.mlstm_forward(cfg, p["mixer"], h, perf=perf)
    elif kind == "slstm":
        y = xlstm.slstm_forward(cfg, p["mixer"], h, perf=perf)
    else:
        raise ValueError(kind)
    return x + y


def _embed(cfg: ModelConfig, params, batch):
    """Token / frontend embedding fusion -> (B, S, d) activations."""
    p = params["embed"]
    if cfg.frontend == "audio":
        # encoder-only audio: precomputed frame embeddings + mask
        x = jnp.einsum("bsd,de->bse", batch["frames"], p["frame_proj"])
        if "mask" in batch:
            m = batch["mask"][..., None]
            x = jnp.where(m, p["mask_emb"][None, None].astype(x.dtype), x)
        return x.astype(cfg.dtype)
    x = embed_tokens(cfg, p, batch["tokens"])
    if cfg.frontend == "vision" and "patches" in batch:
        pe = jnp.einsum("bnd,de->bne", batch["patches"], p["patch_proj"])
        n = pe.shape[1]
        x = jnp.concatenate([pe.astype(x.dtype), x[:, n:]], axis=1)
    return x


def _remat_wrap(fn, perf: PerfConfig):
    if perf.remat == "none":
        return fn
    if perf.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)


def forward(cfg: ModelConfig, params, batch, *,
            perf: PerfConfig = DEFAULT_PERF, causal: Optional[bool] = None):
    """Full-sequence forward -> (logits (B,S,V) fp32, aux_loss scalar)."""
    causal = (not cfg.encoder_only) if causal is None else causal
    x = _embed(cfg, params, batch)
    x = constrain(x, ("act_batch", "act_seq"))
    S = x.shape[1]
    cos, sin = (rope_table(S, _rope_dim(cfg), cfg.rope_theta)
                if cfg.rope_theta else (None, None))
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()

    def group_body(carry, gparams):
        h = carry
        aux = jnp.zeros((), jnp.float32)
        for i, (kind, ffn) in enumerate(zip(kinds, ffns)):
            h = _apply_mixer(cfg, kind, gparams[i], h, cos, sin, causal, perf)
            h, a = _apply_ffn(cfg, ffn, gparams[i], h, perf)
            # sequence-parallel residual stream: the carry (and anything
            # remat saves) lives sequence-sharded between layers
            h = constrain(h, ("act_batch", "act_seq"))
            aux = aux + a
        return h, aux

    body = _remat_wrap(group_body, perf)
    x, auxs = jax.lax.scan(body, x, params["groups"])
    x = rmsnorm(params["out_norm"], x, cfg.norm_eps)
    logits = lm_head(cfg, params["embed"], x)
    logits = constrain(logits, ("act_batch", None, "tp"))
    return logits, auxs.sum()


def loss_fn(cfg: ModelConfig, params, batch, *,
            perf: PerfConfig = DEFAULT_PERF):
    """Scalar loss + metrics.  batch: tokens/frames, labels, weights."""
    logits, aux = forward(cfg, params, batch, perf=perf)
    weights = batch["weights"].astype(jnp.float32)
    ce = cross_entropy(logits, batch["labels"], weights)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "loss": loss}


# ---------------------------------------------------------------- decode


def _rope_dim(cfg: ModelConfig) -> int:
    return (cfg.mla.qk_rope_head_dim if cfg.mla is not None else cfg.head_dim_)


def _mixer_decode(cfg, kind, p, x, state, lengths, perf):
    h = x  # pre-norm applied by caller
    if kind == "attn":
        return attention.attn_decode(cfg, p["mixer"], h, state, lengths,
                                     perf=perf)
    if kind == "mamba":
        return ssm.mamba_decode(cfg, p["mixer"], h, state, perf=perf)
    if kind == "mlstm":
        return xlstm.mlstm_decode(cfg, p["mixer"], h, state, perf=perf)
    if kind == "slstm":
        return xlstm.slstm_decode(cfg, p["mixer"], h, state, perf=perf)
    raise ValueError(kind)


def decode_step(cfg: ModelConfig, params, state, tokens, lengths, *,
                perf: PerfConfig = DEFAULT_PERF):
    """One decode step.

    tokens: (B,) int32 current input token per slot.
    lengths: (B,) int32 tokens already in cache (i.e. this token's position).
    Returns (logits (B, V) fp32, new_state).
    """
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only; no decode step")
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    x = constrain(x, ("act_batch",))
    kinds, ffns = cfg.layer_kinds(), cfg.ffn_kinds()

    def group_body(carry, inp):
        h = carry
        gparams, gstate = inp
        new_states = []
        for i, (kind, ffn) in enumerate(zip(kinds, ffns)):
            hn = rmsnorm(gparams[i]["ln1"], h, cfg.norm_eps)
            y, st = _mixer_decode(cfg, kind, gparams[i], hn, gstate[i],
                                  lengths, perf)
            h = h + y
            h, _ = _apply_ffn(cfg, ffn, gparams[i], h, perf)
            new_states.append(st)
        return h, new_states

    x, new_state = jax.lax.scan(group_body, x, (params["groups"], state))
    x = rmsnorm(params["out_norm"], x, cfg.norm_eps)
    logits = lm_head(cfg, params["embed"], x)[:, 0]
    return logits, new_state


def serve_step(cfg: ModelConfig, params, state, tokens, lengths, *,
               perf: PerfConfig = DEFAULT_PERF):
    """Closed serving step: decode + greedy next-token (dry-run target)."""
    logits, new_state = decode_step(cfg, params, state, tokens, lengths,
                                    perf=perf)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return nxt, new_state


# ------------------------------------------------------------ input specs


def batch_spec_leaves(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Logical-axis Leaf description of every model input for a cell.

    Used by ``input_specs`` (dry-run ShapeDtypeStructs) and by the data
    pipeline (real allocation for smoke runs).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        leaves: dict = {}
        if cfg.frontend == "audio":
            leaves["frames"] = Leaf((B, S, cfg.d_model), spec=("act_batch",),
                                    dtype=cfg.dtype)
            leaves["mask"] = Leaf((B, S), spec=("act_batch",), dtype="bool")
        else:
            leaves["tokens"] = Leaf((B, S), spec=("act_batch",), dtype="int32")
            if cfg.frontend == "vision":
                leaves["patches"] = Leaf((B, cfg.n_frontend_tokens, cfg.d_model),
                                         spec=("act_batch",), dtype=cfg.dtype)
        if shape.kind == "train":
            leaves["labels"] = Leaf((B, S), spec=("act_batch",), dtype="int32")
            leaves["weights"] = Leaf((B, S), spec=("act_batch",), dtype="float32")
        return leaves
    # decode: one token per slot + cache lengths
    return {"tokens": Leaf((B,), spec=("act_batch",), dtype="int32"),
            "lengths": Leaf((B,), spec=("act_batch",), dtype="int32")}

"""Shared layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, LM head.

All parameter specs use *logical* axes (tp / fsdp — see models/schema.py);
the launcher resolves them to mesh axes per mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.schema import Leaf

# ---------------------------------------------------------------- RMSNorm


def rmsnorm_schema(d: int) -> dict:
    return {"scale": Leaf((d,), init="ones")}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- RoPE


def rope_table(seq_len: int, head_dim: int, theta: float, positions=None):
    """(S, hd/2) cos/sin tables in fp32.  positions overrides arange."""
    if positions is None:
        positions = jnp.arange(seq_len, dtype=jnp.float32)
    else:
        positions = positions.astype(jnp.float32)
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None] * freqs  # (..., S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch and heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:  # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- MLP


def mlp_schema(d: int, f: int) -> dict:
    return {
        "w_gate": Leaf((d, f), spec=("fsdp", "tp")),
        "w_up": Leaf((d, f), spec=("fsdp", "tp")),
        "w_down": Leaf((f, d), spec=("tp", "fsdp"), init="small"),
    }


def mlp(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------- Embedding / head


def embedding_schema(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    # the table is d-sharded (vocab replicated): a token gather from a
    # vocab-sharded table makes GSPMD replicate the gathered activations
    # ("involuntary full rematerialization"); the tied LM head re-shards
    # the (small) table to vocab-parallel instead — see lm_head.
    sch = {"tok": Leaf((cfg.padded_vocab, d), spec=(None, "tp"))}
    if cfg.frontend == "vision":
        sch["patch_proj"] = Leaf((d, d), spec=("fsdp", "tp"))
    if cfg.frontend == "audio":
        sch["frame_proj"] = Leaf((d, d), spec=("fsdp", "tp"))
        sch["mask_emb"] = Leaf((d,), init="normal")
    if not cfg.tie_embeddings:
        sch["head"] = Leaf((d, cfg.padded_vocab), spec=("fsdp", "tp"))
    return sch


def embed_tokens(cfg: ModelConfig, p, tokens):
    return p["tok"].at[tokens].get(mode="clip")  # wait-free clip gather


def lm_head(cfg: ModelConfig, p, x):
    from repro.sharding_ctx import constrain
    if cfg.tie_embeddings:
        # re-shard the (small) table to vocab-parallel for the head: a
        # one-off all-to-all on ~MBs of weights instead of partial-sum
        # all-reduces on GBs of logits
        w = constrain(p["tok"].T, (None, "tp"))
    else:
        w = p["head"]
    return jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)


def cross_entropy(logits, labels, weights):
    """Mean CE over weighted positions. logits fp32 (B,S,V).

    The gold logit is extracted with a one-hot mask (not
    ``take_along_axis``): a gather over the vocab axis would force GSPMD
    to replicate vocab-sharded logits."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = (logz - gold) * weights
    denom = jnp.maximum(weights.sum(), 1.0)
    # small z-loss for stability (MaxText-style)
    zloss = 1e-4 * (logz * weights) ** 2
    return (nll.sum() + zloss.sum()) / denom

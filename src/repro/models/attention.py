"""Attention mixers: GQA (all dense archs) and MLA (DeepSeek-V2).

Two entry points per variant:
  * ``*_forward``  — full-sequence (train / prefill), flash attention.
  * ``*_decode``   — one new token against a per-slot cache (serve path).

Decode caches are dense per-slot tensors ``(B, S_max, ...)`` whose
sequence axis is shardable (flash-decoding style): the score/softmax
reductions over a sharded S lower to the same partial-max/partial-sum
collectives a split-K decode kernel performs on real hardware.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, rope_table
from repro.models.schema import Leaf
from repro.kernels import ops
from repro.perf import PerfConfig, DEFAULT_PERF
from repro.sharding_ctx import constrain, current_rules


def _sp_attention_layout(q, k, v, S: int, perf: PerfConfig):
    """Sequence-parallel attention layout.

    With the residual stream sequence-sharded (act_seq rules), slicing q
    into python-level blocks would fight GSPMD (per-block resharding
    permutes).  Instead: q STAYS sequence-sharded (the shards are the q
    blocks), k/v are gathered once per layer, and the kv-block loop runs
    over the replicated k/v.  Costs one all-gather of k/v per layer and
    the causal block-skip on scores (masking only); saves the per-block
    reshard storm."""
    rules = current_rules()
    if rules and rules.get("act_seq"):
        k = constrain(k, ("act_batch", None))
        v = constrain(v, ("act_batch", None))
        return q, k, v, max(perf.block_q, S)
    return q, k, v, perf.block_q

# ====================================================================== GQA


def gqa_schema(cfg: ModelConfig) -> dict:
    """Projections stored FLATTENED (d, H*hd): head counts (24/36/40...)
    need not divide the 16-way tp axis — H*hd always does."""
    d, hd = cfg.d_model, cfg.head_dim_
    return {
        "wq": Leaf((d, cfg.n_heads * hd), spec=("fsdp", "tp")),
        "wk": Leaf((d, cfg.n_kv_heads * hd), spec=("fsdp", "tp")),
        "wv": Leaf((d, cfg.n_kv_heads * hd), spec=("fsdp", "tp")),
        "wo": Leaf((cfg.n_heads * hd, d), spec=("tp", "fsdp"), init="small"),
    }


def _heads(t, hd):
    return t.reshape(*t.shape[:-1], t.shape[-1] // hd, hd)


def gqa_forward(cfg: ModelConfig, p, x, cos, sin, *, causal: bool = True,
                perf: PerfConfig = DEFAULT_PERF):
    """x: (B, S, d) -> (B, S, d)."""
    hd = cfg.head_dim_
    q = _heads(jnp.einsum("bsd,df->bsf", x, p["wq"]), hd)
    k = _heads(jnp.einsum("bsd,df->bsf", x, p["wk"]), hd)
    v = _heads(jnp.einsum("bsd,df->bsf", x, p["wv"]), hd)
    if cfg.rope_theta:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q, k, v, bq = _sp_attention_layout(q, k, v, x.shape[1], perf)
    o = ops.flash_attention(q, k, v, causal=causal, impl=perf.attn_impl,
                            block_q=bq, block_k=perf.block_k)
    return jnp.einsum("bsf,fd->bsd", o.reshape(*x.shape[:2], -1), p["wo"])


def gqa_cache_schema(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    hd = cfg.head_dim_
    spec = ("act_batch", "cache_seq")
    return {
        "k": Leaf((batch, s_max, cfg.n_kv_heads, hd), spec=spec, init="zeros"),
        "v": Leaf((batch, s_max, cfg.n_kv_heads, hd), spec=spec, init="zeros"),
    }


def gqa_decode(cfg: ModelConfig, p, x, cache, lengths, *,
               perf: PerfConfig = DEFAULT_PERF):
    """x: (B, 1, d); cache {k,v}: (B, S_max, Hkv, hd); lengths: (B,) tokens
    already cached.  Returns (out (B,1,d), new_cache)."""
    B = x.shape[0]
    hd = cfg.head_dim_
    q = _heads(jnp.einsum("bsd,df->bsf", x, p["wq"]), hd)   # (B,1,H,hd)
    k = _heads(jnp.einsum("bsd,df->bsf", x, p["wk"]), hd)
    v = _heads(jnp.einsum("bsd,df->bsf", x, p["wv"]), hd)
    if cfg.rope_theta:
        cos, sin = rope_table(1, cfg.head_dim_, cfg.rope_theta,
                              positions=lengths[:, None])
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    bidx = jnp.arange(B)
    kc = cache["k"].at[bidx, lengths].set(k[:, 0].astype(cache["k"].dtype))
    vc = cache["v"].at[bidx, lengths].set(v[:, 0].astype(cache["v"].dtype))
    o = ops.decode_attention(q[:, 0], kc, vc, lengths + 1)
    out = jnp.einsum("bf,fd->bd", o.reshape(B, -1), p["wo"])[:, None]
    return out, {"k": kc, "v": vc}


# ====================================================================== MLA


def mla_schema(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": Leaf((d, m.q_lora_rank), spec=("fsdp", None)),
        "q_norm": Leaf((m.q_lora_rank,), init="ones"),
        "w_uq": Leaf((m.q_lora_rank, H, qk), spec=(None, "tp")),
        "w_dkv": Leaf((d, m.kv_lora_rank + m.qk_rope_head_dim), spec=("fsdp", None)),
        "kv_norm": Leaf((m.kv_lora_rank,), init="ones"),
        "w_uk": Leaf((m.kv_lora_rank, H, m.qk_nope_head_dim), spec=(None, "tp")),
        "w_uv": Leaf((m.kv_lora_rank, H, m.v_head_dim), spec=(None, "tp")),
        "wo": Leaf((H, m.v_head_dim, d), spec=("tp", None, "fsdp"), init="small"),
    }


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _mla_q(cfg, p, x, cos, sin):
    """Shared q path: returns (q_nope (B,S,H,nd), q_rope (B,S,H,rd))."""
    m = cfg.mla
    ql = _rms(jnp.einsum("bsd,dq->bsq", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", ql, p["w_uq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], cos, sin)
    return q_nope, q_rope


def mla_forward(cfg: ModelConfig, p, x, cos, sin, *, causal: bool = True,
                perf: PerfConfig = DEFAULT_PERF):
    """Prefill/train MLA: latent expanded to per-head K/V, flash attention."""
    m = cfg.mla
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(cfg, p, x, cos, sin)
    dkv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"])
    ckv = _rms(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, m.kv_lora_rank:], cos, sin)  # (B,S,1,rd)
    k_nope = jnp.einsum("bsl,lhk->bshk", ckv, p["w_uk"])
    v = jnp.einsum("bsl,lhv->bshv", ckv, p["w_uv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, cfg.n_heads, m.qk_rope_head_dim))],
        axis=-1)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q, k, v, bq = _sp_attention_layout(q, k, v, S, perf)
    o = ops.flash_attention(q, k, v, causal=causal, scale=qk_dim ** -0.5,
                            impl=perf.attn_impl,
                            block_q=bq, block_k=perf.block_k)
    return jnp.einsum("bshv,hvd->bsd", o, p["wo"])


def mla_cache_schema(cfg: ModelConfig, batch: int, s_max: int) -> dict:
    m = cfg.mla
    spec = ("act_batch", "cache_seq")
    return {
        "ckv": Leaf((batch, s_max, m.kv_lora_rank), spec=spec, init="zeros"),
        "krope": Leaf((batch, s_max, m.qk_rope_head_dim), spec=spec, init="zeros"),
    }


def mla_decode(cfg: ModelConfig, p, x, cache, lengths, *,
               perf: PerfConfig = DEFAULT_PERF):
    """Absorbed-matrices MLA decode against the latent cache.

    The KV cache stores only (kv_lora + rope) floats per token — ~9x
    smaller than GQA at kv=128 — and W_UK/W_UV are *absorbed* into the
    query/output transforms so the latent is attended to directly.
    """
    m = cfg.mla
    B = x.shape[0]
    cos, sin = rope_table(1, m.qk_rope_head_dim, cfg.rope_theta,
                          positions=lengths[:, None])
    q_nope, q_rope = _mla_q(cfg, p, x, cos, sin)          # (B,1,H,*)
    dkv = jnp.einsum("bsd,dl->bsl", x, p["w_dkv"])
    ckv_new = _rms(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    krope_new = apply_rope(dkv[..., None, m.kv_lora_rank:], cos, sin)[:, :, 0]

    bidx = jnp.arange(B)
    ckv = cache["ckv"].at[bidx, lengths].set(ckv_new[:, 0].astype(cache["ckv"].dtype))
    krope = cache["krope"].at[bidx, lengths].set(
        krope_new[:, 0].astype(cache["krope"].dtype))

    # absorb W_UK into q:  q_abs (B,H,l); attend the latent cache in
    # sequence blocks (flash-decoding) so scores never hit HBM whole.
    # NOTE: params are never .astype()'d here — XLA hoists such converts
    # out of the layer scan into stacked f32 copies of the weights/cache.
    q_abs = jnp.einsum("bhk,lhk->bhl", q_nope[:, 0], p["w_uk"])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    qf = (q_abs * scale).astype(ckv.dtype)
    qr = (q_rope[:, 0] * scale).astype(krope.dtype)
    Smax = ckv.shape[1]
    H = cfg.n_heads
    bs = min(2048, Smax)
    ns = Smax // bs

    def step(i, carry):
        acc, mx, l = carry
        cb = jax.lax.dynamic_slice_in_dim(ckv, i * bs, bs, axis=1)
        rb = jax.lax.dynamic_slice_in_dim(krope, i * bs, bs, axis=1)
        # keep cache slices in bf16 and let the MXU accumulate in fp32:
        # an .astype on the slice gets hoisted by XLA into an f32 copy of
        # the WHOLE cache (3.75 GiB on the deepseek decode cell)
        s = (jnp.einsum("bhl,bsl->bhs", qf, cb,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhr,bsr->bhs", qr, rb,
                          preferred_element_type=jnp.float32))
        pos = i * bs + jnp.arange(bs)
        s = jnp.where((pos[None] < (lengths + 1)[:, None])[:, None], s, -1e30)
        m_new = jnp.maximum(mx, s.max(-1))
        alpha = jnp.exp(mx - m_new)
        pr = jnp.exp(s - m_new[..., None])
        l = l * alpha + pr.sum(-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhs,bsl->bhl", pr.astype(ckv.dtype), cb,
            preferred_element_type=jnp.float32)
        return acc, m_new, l

    acc0 = jnp.zeros((B, H, m.kv_lora_rank), jnp.float32)
    m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, H), jnp.float32)
    acc, mx, l = jax.lax.fori_loop(0, ns, step, (acc0, m0, l0))
    o_lat = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(x.dtype)
    o = jnp.einsum("bhl,lhv->bhv", o_lat, p["w_uv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bhv,hvd->bd", o, p["wo"])[:, None]
    return out, {"ckv": ckv, "krope": krope}


# ================================================================ dispatch


def attn_schema(cfg: ModelConfig) -> dict:
    return mla_schema(cfg) if cfg.mla is not None else gqa_schema(cfg)


def attn_forward(cfg, p, x, cos, sin, *, causal=True, perf=DEFAULT_PERF):
    fn = mla_forward if cfg.mla is not None else gqa_forward
    return fn(cfg, p, x, cos, sin, causal=causal, perf=perf)


def attn_cache_schema(cfg, batch, s_max):
    fn = mla_cache_schema if cfg.mla is not None else gqa_cache_schema
    return fn(cfg, batch, s_max)


def attn_decode(cfg, p, x, cache, lengths, *, perf=DEFAULT_PERF):
    fn = mla_decode if cfg.mla is not None else gqa_decode
    return fn(cfg, p, x, cache, lengths, perf=perf)

"""Sharded multi-tenant backend: the domain table across an N-device mesh.

Third implementation of the ``Backend`` protocol (after the host tree
and the single-device table): domain state lives as ``(n_shards,
n_domains)`` arrays sharded over a 1-axis ``("shard",)`` mesh, one
independent local table per device.  Placement is by *tenant subtree* —
the first path component below ``/`` picks a shard (round-robin), and
every descendant (sessions, tool-call leases) inherits it — so one
tenant's burst is charged, throttled, and frozen entirely on its own
device group, the multi-host analogue of the paper's per-tenant
hierarchical cgroups.

Enforcement runs in two modes, mirroring ``DeviceTableBackend``:

  * host-driven (lifecycle, replay, cross-validation): ``try_charge``
    routes the request to the owning shard's slice and additionally
    enforces the *global* root capacity (sum of shard-root usage), so
    grants match ``HostTreeBackend`` exactly;
  * in-step (serving engine): ``device_view()`` returns pure functions
    that take *global* handles, scatter the per-slot requests into a
    ``(n_shards, m)`` matrix, and run ``controller.charge_batch`` on
    every shard simultaneously inside ``shard_map`` — per-device
    enforcement with no cross-device traffic on the hot path.

Host-side reads reconcile across shards: ``/`` ``memory.current`` is
the sum of shard-root usage, ``memory.peak`` the sum of shard-root
peaks, and ``memory.events`` sums per-shard throttle state.  The root
peak is what provisioning needs — each device group's high-water is
what its HBM must actually hold — but note it is an *upper bound* on
the instantaneous global peak whenever different groups peak at
different times (exact for traffic confined to one shard, which is
what the cross-backend parity sequence replays).
"""
from __future__ import annotations

import heapq
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import controller as C
from repro.core import domains as D
from repro.core.cgroup import ChargeTicket, DomainSpec, parent_path
from repro.core.events import Ev, EventLog
from repro.core.pressure import saturating_count
from repro.core.progs import (PolicyProgram, as_program, as_programs,
                              check_registry, pad_row, path_in_scope,
                              registry_unknown_params, registry_width)

UNLIMITED = D.UNLIMITED


def _stacked_state(capacity: int, n_shards: int, n_domains: int,
                   prog=None) -> dict:
    """Per-shard local tables: every shard's local index 0 is that device
    group's root, capped at the full pool capacity."""
    one = C.new_state(capacity, n_domains, prog)
    return {k: jnp.broadcast_to(v[None], (n_shards,) + v.shape)
            for k, v in one.items()}


class ShardedDeviceView:
    """Jit-safe slice of the sharded backend: the live ``(S, n)`` state
    pytree plus pure enforcement functions over *global* handles.  Each
    function scatters its flat per-slot requests to the owning shards,
    applies the single-device controller kernel per shard under
    ``shard_map``, and gathers flat results — so the engine's jitted
    step is backend-agnostic."""

    def __init__(self, backend: "ShardedTableBackend"):
        self._backend = backend
        self.cfg = backend.cfg
        self.mesh = backend.mesh
        self.n_shards = backend.n_shards
        self.per_shard = backend.per_shard_domains

    @property
    def state(self) -> dict:
        return self._backend.state

    @property
    def prog(self) -> PolicyProgram:
        return self._backend.prog

    @property
    def progs(self) -> tuple:
        return self._backend.progs

    # ------------------------------------------------------------- helpers

    def _split(self, dom):
        dom = dom.astype(jnp.int32)
        valid = dom >= 0
        shard = jnp.where(valid, dom // self.per_shard, 0)
        local = jnp.where(valid, dom % self.per_shard, -1)
        sel = shard[None, :] == jnp.arange(self.n_shards)[:, None]
        sel = sel & valid[None, :]
        return valid, shard, jnp.where(sel, local[None, :], -1)

    def _shard_specs(self, n_in, n_out):
        return ((P("shard"),) * n_in, (P("shard"),) * n_out)

    def _run(self, fn, state, *operands, n_out):
        """shard_map ``fn`` over the per-shard slices of state+operands."""
        def local(st, *ops):
            st1 = jax.tree.map(lambda x: x[0], st)
            ops1 = [o[0] for o in ops]
            outs = fn(st1, *ops1)
            return tuple(jax.tree.map(lambda x: x[None], o) for o in outs)
        in_specs, out_specs = self._shard_specs(1 + len(operands), n_out)
        return compat.shard_map(local, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs)(state, *operands)

    # ------------------------------------------------------------ the ops

    def charge(self, state, dom, amt, step):
        """In-step hierarchical charge: (state, granted, stalled); every
        shard serves its own tenants' requests in the same program."""
        m = dom.shape[0]
        valid, shard, dom2 = self._split(dom)
        amt2 = jnp.broadcast_to(amt.astype(jnp.int32)[None, :],
                                (self.n_shards, m))
        step2 = jnp.broadcast_to(jnp.asarray(step, jnp.int32)[None],
                                 (self.n_shards,))

        def local(st, d, a, s):
            return C.charge_batch(st, d, a, s[()], self.progs)

        new_state, g2, s2 = self._run(local, state, dom2, amt2, step2,
                                      n_out=3)
        rows = jnp.arange(m)
        granted = g2[shard, rows] & valid
        stalled = s2[shard, rows] & valid
        return new_state, granted, stalled

    def account(self, state, dom, amt):
        """Post-hoc unconditional charge (user-space baseline path)."""
        return self.uncharge(state, dom, -amt)

    def uncharge(self, state, dom, amt):
        m = dom.shape[0]
        _, _, dom2 = self._split(dom)
        amt2 = jnp.broadcast_to(amt.astype(jnp.int32)[None, :],
                                (self.n_shards, m))

        def local(st, d, a):
            return (C.uncharge_batch(st, d, a),)

        (new_state,) = self._run(local, state, dom2, amt2, n_out=1)
        return new_state

    def gate(self, state, dom, step):
        """Per-slot advance gate (no frozen/throttled ancestor)."""
        m = dom.shape[0]
        valid, shard, dom2 = self._split(dom)
        step2 = jnp.broadcast_to(jnp.asarray(step, jnp.int32)[None],
                                 (self.n_shards,))

        def local(st, d, s):
            return (C.slot_gate(st, d, s[()], self.progs),)

        (g2,) = self._run(local, state, dom2, step2, n_out=1)
        return g2[shard, jnp.arange(m)] & valid

    def schedule(self, state, dom, cost, step, budget):
        """In-step weighted scheduling: every shard runs the shared
        ``schedule_decision`` over its own tenants' slots with a
        *per-shard* budget (the per-device-group convention, like
        ``pool_pages``) — no cross-device traffic on the hot path."""
        from repro.core import sched as S
        m = dom.shape[0]
        valid, shard, dom2 = self._split(dom)
        cost2 = jnp.broadcast_to(cost.astype(jnp.int32)[None, :],
                                 (self.n_shards, m))
        step2 = jnp.broadcast_to(jnp.asarray(step, jnp.int32)[None],
                                 (self.n_shards,))

        def local(st, d, c, s):
            return S.schedule_decision(self.progs, st, d, c, s[()], budget)

        new_state, a2 = self._run(local, state, dom2, cost2, step2, n_out=2)
        return new_state, a2[shard, jnp.arange(m)] & valid

    def commit(self, state: dict) -> None:
        self._backend.state = state


class ShardedTableBackend:
    """Device-sharded backend: per-tenant device-group placement,
    per-shard in-step enforcement, host-side reconciliation."""

    def __init__(self, capacity: int, n_domains: int = 64, cfg=None,
                 log: Optional[EventLog] = None, *,
                 n_shards: Optional[int] = None, mesh=None,
                 prog: Optional[PolicyProgram] = None):
        self.cfg = cfg or C.ControllerConfig()
        self.capacity = capacity
        self.progs = as_programs(prog if prog is not None else self.cfg)
        self.scopes = ["/"] * len(self.progs)
        if mesh is None:
            devs = jax.devices()
            n_shards = n_shards or len(devs)
            mesh = compat.make_auto_mesh((n_shards,), ("shard",),
                                         devices=devs[:n_shards])
        self.mesh = mesh
        self.n_shards = mesh.devices.size
        self.per_shard_domains = n_domains
        st = _stacked_state(capacity, self.n_shards, n_domains, self.progs)
        sh = NamedSharding(mesh, P("shard"))
        self.state = {k: jax.device_put(v, sh) for k, v in st.items()}
        # path -> (shard, local idx); "/" is every shard's local root but
        # addressed through shard 0
        self.index: dict[str, tuple[int, int]] = {"/": (0, 0)}
        self._free = [list(range(1, n_domains))    # heaps: lowest index first
                      for _ in range(self.n_shards)]
        self._tenant_shard: dict[str, int] = {}
        self._next_shard = 0
        self.log = log if log is not None else EventLog()
        self._now = 0.0
        self._host_charge = None       # jitted host-path charge, per program

    # ------------------------------------------------------------- programs

    @property
    def prog(self) -> PolicyProgram:
        """The primary (slot 0) program — the registry's trace constants
        (``step_ms`` etc.) and the single-program compatibility surface."""
        return self.progs[0]

    @property
    def attach_scope(self) -> str:
        return self.scopes[0]

    def _in_scope(self, path: str) -> bool:
        return path_in_scope(self.attach_scope, path)

    def attach(self, scope: str, prog: PolicyProgram) -> None:
        """Same compose semantics as ``DeviceDomainTable.attach``: a root
        attach resets the registry; a subtree attach takes (or replaces)
        a registry slot and moves only in-scope domains to it — rows
        padded to the registry width, per-shard."""
        prog = as_program(prog)
        self._host_charge = None
        S, n = self.n_shards, self.per_shard_domains
        sh = NamedSharding(self.mesh, P("shard"))
        if scope == "/":
            self.progs = (prog,)
            self.scopes = ["/"]
            rows = np.broadcast_to(prog.default_row(),
                                   (S, n, prog.n_params)).copy()
            self.state = dict(
                self.state, prog=jax.device_put(jnp.asarray(rows), sh),
                prog_id=jax.device_put(jnp.zeros((S, n), jnp.int32), sh))
            return
        if scope in self.scopes:
            k = self.scopes.index(scope)
            self.progs = self.progs[:k] + (prog,) + self.progs[k + 1:]
        else:
            k = len(self.progs)
            self.progs = self.progs + (prog,)
            self.scopes.append(scope)
        check_registry(self.progs)
        width = registry_width(self.progs)
        old = np.asarray(self.state["prog"])
        rows = np.zeros((S, n, width), np.float32)
        keep = min(width, old.shape[2])
        rows[:, :, :keep] = old[:, :, :keep]
        ids = np.asarray(self.state["prog_id"]).copy()
        for path, (s, i) in self.index.items():
            if path_in_scope(scope, path):
                ids[s, i] = k
                rows[s, i] = pad_row(prog.default_row(), width)
        self.state = dict(self.state,
                          prog=jax.device_put(jnp.asarray(rows), sh),
                          prog_id=jax.device_put(jnp.asarray(ids), sh))

    def update_params(self, path: str, kv: dict) -> None:
        unknown = registry_unknown_params(self.progs, kv)
        if unknown:
            raise KeyError(
                f"no registered program has param(s) {sorted(unknown)}; "
                f"knobs: {sorted(set().union(*(p.param_names for p in self.progs)))}")
        ids = np.asarray(self.state["prog_id"])
        prog = self.state["prog"]
        for p in self._subtree(path):
            s, i = self.index[p]
            pr = self.progs[int(ids[s, i])]
            cols = {pr.col(k): float(v) for k, v in kv.items()
                    if k in pr.param_names}
            for c, v in cols.items():
                if p == "/":            # root params on every shard's root
                    prog = prog.at[:, 0, c].set(v)
                else:
                    prog = prog.at[s, i, c].set(v)
        self.state = dict(self.state, prog=prog)

    def _recompute_flat(self) -> None:
        """Re-flatten hierarchical weights across the *global* logical
        tree (lifecycle rate).  Same host math as every other backend —
        ``flat_weights_by_path`` — so the per-shard rows hold the exact
        values the host reference computes even though each shard only
        sees a slice of the tree.  Every shard's local root mirrors the
        global root (flat 1.0)."""
        from repro.core.sched import flat_weights_by_path
        w = np.asarray(self.state["weight"])
        flat = flat_weights_by_path(
            {p: int(w[s, i]) for p, (s, i) in self.index.items()})
        arr = np.zeros((self.n_shards, self.per_shard_domains), np.float32)
        arr[:, 0] = 1.0
        for p, (s, i) in self.index.items():
            if p != "/":
                arr[s, i] = flat[p]
        sh = NamedSharding(self.mesh, P("shard"))
        self.state = dict(self.state,
                          flat_weight=jax.device_put(jnp.asarray(arr), sh))

    # ------------------------------------------------------------ placement

    @property
    def n_domains(self) -> int:
        """Global handle space (shard-major), for flat consumers."""
        return self.n_shards * self.per_shard_domains

    def placement(self) -> dict:
        """tenant path -> shard (device group) — the paper's
        tenant-subtree-to-device mapping, for tests and benchmarks."""
        return dict(self._tenant_shard)

    def _shard_for(self, path: str) -> int:
        if path == "/":
            return 0
        tenant = "/" + path.strip("/").split("/")[0]
        if tenant not in self._tenant_shard:
            self._tenant_shard[tenant] = self._next_shard % self.n_shards
            self._next_shard += 1
        return self._tenant_shard[tenant]

    def _handle(self, shard: int, idx: int) -> int:
        return shard * self.per_shard_domains + idx

    def device_view(self) -> ShardedDeviceView:
        return ShardedDeviceView(self)

    # ---------------------------------------------------- per-shard slices

    def _slice(self, shard: int) -> dict:
        return {k: v[shard] for k, v in self.state.items()}

    def _adopt(self, shard: int, sub: dict, keys=None) -> None:
        keys = keys if keys is not None else sub.keys()
        self.state = dict(self.state, **{
            k: self.state[k].at[shard].set(sub[k]) for k in keys})

    # ------------------------------------------------------------ lifecycle

    def mkdir(self, path: str, spec: DomainSpec) -> int:
        from repro.core.cgroup import ancestor_paths
        assert len(ancestor_paths(path)) <= C.DEPTH, f"{path}: deeper than DEPTH"
        assert path not in self.index, path
        shard = self._shard_for(path)
        pshard, pidx = self.index[parent_path(path)]
        if parent_path(path) != "/":
            assert pshard == shard, (path, "crosses its tenant's shard")
        else:
            pidx = 0                       # this shard's local root
        idx = heapq.heappop(self._free[shard])
        self.index[path] = (shard, idx)
        st = self.state
        upd = {
            "high": spec.high, "max": spec.max, "low": spec.low,
            "parent": pidx, "priority": spec.priority, "usage": 0,
            "peak": 0, "frozen": False, "active": True, "throttle_until": 0,
            "weight": spec.weight, "cpu_max": spec.cpu_max,
            "vruntime": 0.0, "cpu_used": 0, "cpu_stamp": -1,
            "mem_stall": 0, "cpu_stall": 0,
        }
        # children inherit their parent's live row AND program slot, so a
        # domain created after a subtree attach runs the subtree's program
        row = np.asarray(st["prog"][shard, pidx])
        pid = int(st["prog_id"][shard, pidx])
        self.state = dict(st, **{
            k: st[k].at[shard, idx].set(v) for k, v in upd.items()},
            prog=st["prog"].at[shard, idx].set(jnp.asarray(row)),
            prog_id=st["prog_id"].at[shard, idx].set(pid))
        self._recompute_flat()
        self.log.emit(self._now, Ev.CREATE, path, high=spec.high,
                      max=spec.max, shard=shard)
        return self._handle(shard, idx)

    def rmdir(self, path: str, transfer_residual: bool) -> int:
        shard, idx = self.index[path]
        residual = int(self.state["usage"][shard, idx])
        parent = parent_path(path)
        if residual:
            sub = self._slice(shard)
            sub = C.uncharge_batch(sub, jnp.array([idx], jnp.int32),
                                   jnp.array([residual], jnp.int32))
            self._adopt(shard, sub, keys=("usage",))
        st = self.state
        self.state = dict(
            st,
            active=st["active"].at[shard, idx].set(False),
            frozen=st["frozen"].at[shard, idx].set(False),
            parent=st["parent"].at[shard, idx].set(-1),
            weight=st["weight"].at[shard, idx].set(D.DEFAULT_WEIGHT),
            cpu_max=st["cpu_max"].at[shard, idx].set(UNLIMITED),
            vruntime=st["vruntime"].at[shard, idx].set(0.0),
            cpu_used=st["cpu_used"].at[shard, idx].set(0),
            cpu_stamp=st["cpu_stamp"].at[shard, idx].set(-1),
            mem_stall=st["mem_stall"].at[shard, idx].set(0),
            cpu_stall=st["cpu_stall"].at[shard, idx].set(0),
            prog_id=st["prog_id"].at[shard, idx].set(0))
        del self.index[path]
        heapq.heappush(self._free[shard], idx)
        self._recompute_flat()
        if transfer_residual and residual and parent is not None:
            self.charge_unchecked(parent, residual)
        self.log.emit(self._now, Ev.REMOVE, path)
        return residual

    def exists(self, path: str) -> bool:
        return path in self.index

    def paths(self) -> list[str]:
        return list(self.index)

    def handle(self, path: str) -> int:
        return self._handle(*self.index[path])

    def path_of(self, handle: int) -> str:
        key = (handle // self.per_shard_domains,
               handle % self.per_shard_domains)
        for p, si in self.index.items():
            if si == key:
                return p
        raise KeyError(handle)

    # --------------------------------------------------- charging (host path)

    def _root_total(self) -> int:
        return int(jnp.sum(self.state["usage"][:, 0]))

    def _host_charge_fn(self):
        """One jitted program for the whole host-driven charge: global
        root-capacity check, owning-shard charge, scatter-back — so a
        ``try_charge`` costs a single dispatch plus ONE device->host
        gather (the packed flags vector) instead of per-key slice syncs
        (the ROADMAP open item)."""
        if self._host_charge is None:
            progs = self.progs

            def fn(state, shard, idx, pages, step):
                cap = state["max"][0, 0]
                root_total = jnp.sum(state["usage"][:, 0])
                root_ok = (cap >= UNLIMITED) | (root_total + pages <= cap)
                sub = jax.tree.map(lambda v: v[shard], state)
                dom = jnp.where(root_ok, idx, -1).reshape(1)
                sub, granted, stalled = C.charge_batch(
                    sub, dom, pages.reshape(1).astype(jnp.int32), step, progs)
                # a global-root-capacity denial is a stall event at the
                # charged domain, exactly as the host reference (where
                # the root max sits on the ancestor chain) counts it —
                # charge_batch never saw the request (dom = -1); the
                # counter saturates at INT32_MAX like every other site
                sub = dict(sub, mem_stall=sub["mem_stall"].at[idx].set(
                    saturating_count(sub["mem_stall"][idx],
                                     jnp.where(root_ok, 0, 1))))
                out = {k: state[k].at[shard].set(sub[k]) for k in state}
                window = jnp.maximum(0, sub["throttle_until"][idx] - step)
                flags = jnp.stack([granted[0].astype(jnp.int32),
                                   stalled[0].astype(jnp.int32),
                                   root_ok.astype(jnp.int32),
                                   window.astype(jnp.int32)])
                return out, flags

            self._host_charge = jax.jit(fn)
        return self._host_charge

    def try_charge(self, path: str, pages: int,
                   step: Optional[int]) -> ChargeTicket:
        if step is None:
            step = int(self._now)
        shard, idx = self.index[path]
        # global root capacity: shard-local tables each cap at the full
        # pool, so the cross-shard sum is enforced in the same jitted
        # program, from the live root max — the HostTreeBackend
        # root-max contract with write("/", "memory.max", v) honored.
        state, flags = self._host_charge_fn()(
            self.state, jnp.int32(shard), jnp.int32(idx), jnp.int32(pages),
            jnp.int32(step))
        granted, stalled, root_ok, window = (int(x) for x in
                                             np.asarray(flags))
        self.state = state
        if not root_ok:
            return ChargeTicket(granted=False, stalled=True, blocked_by="/")
        return ChargeTicket(granted=bool(granted), stalled=bool(stalled),
                            delay_ms=window * self.prog.step_ms)

    def uncharge(self, path: str, pages: int) -> None:
        shard, idx = self.index[path]
        sub = C.uncharge_batch(self._slice(shard),
                               jnp.array([idx], jnp.int32),
                               jnp.array([pages], jnp.int32))
        self._adopt(shard, sub, keys=("usage",))

    def charge_unchecked(self, path: str, pages: int) -> None:
        shard, idx = self.index[path]
        sub = C.host_charge(self._slice(shard), idx, pages)
        self._adopt(shard, sub, keys=("usage", "peak"))

    # ------------------------------------------------ scheduling (host path)

    def schedule(self, paths: list, costs: list, step: int,
                 budget: int) -> list:
        """Host-driven weighted scheduling round, bit-exact with the
        host reference: the per-shard tables are flattened to one
        global view (parents rebased, like ``snapshot``) and run
        through the shared jitted ``schedule_decision`` with the global
        budget; the updated accounts scatter back per shard.  The
        in-step path (``device_view().schedule``) instead runs per
        shard with a per-shard budget — the per-device-group
        convention."""
        from repro.core.sched import jit_schedule
        st = {k: np.asarray(v) for k, v in self.state.items()}
        S, n = self.n_shards, self.per_shard_domains
        base = (np.arange(S) * n)[:, None]
        parent = np.where(st["parent"] >= 0, st["parent"] + base, -1)
        flat = {k: jnp.asarray(st[k].reshape(-1))
                for k in ("usage", "high", "max", "low", "priority",
                          "frozen", "active", "throttle_until", "weight",
                          "cpu_max", "flat_weight", "vruntime", "cpu_used",
                          "cpu_stamp", "cpu_stall", "prog_id")}
        flat["parent"] = jnp.asarray(parent.reshape(-1))
        flat["prog"] = jnp.asarray(st["prog"].reshape(S * n, -1))
        dom = jnp.asarray([self._handle(*self.index[p]) for p in paths],
                          jnp.int32)
        cost = jnp.asarray(list(costs), jnp.int32)
        new, advance = jit_schedule(self.progs, flat, dom, cost, int(step),
                                    int(budget))
        sh = NamedSharding(self.mesh, P("shard"))
        self.state = dict(self.state, **{
            k: jax.device_put(
                jnp.asarray(np.asarray(new[k]).reshape(S, n)), sh)
            for k in ("vruntime", "cpu_used", "cpu_stamp", "cpu_stall")})
        return [bool(a) for a in np.asarray(advance)]

    # ------------------------------------------------------ subtree control

    def _subtree(self, path: str) -> list[str]:
        return [p for p in self.index if path_in_scope(path, p)]

    def _set_frozen(self, path: str, flag: bool) -> None:
        st = self.state
        frozen = st["frozen"]
        for p in self._subtree(path):
            shard, idx = self.index[p]
            if p == "/":               # freeze every device group's root
                frozen = frozen.at[:, 0].set(flag)
            else:
                frozen = frozen.at[shard, idx].set(flag)
        self.state = dict(st, frozen=frozen)

    def freeze(self, path: str) -> None:
        self._set_frozen(path, True)
        self.log.emit(self._now, Ev.FREEZE, path)

    def thaw(self, path: str) -> None:
        self._set_frozen(path, False)
        self.log.emit(self._now, Ev.THAW, path)

    def kill(self, path: str) -> int:
        """Atomic subtree kill, same semantics as ``DeviceTableBackend``:
        usage released from the owning shard's chain, every node retired
        in place (still registered, denying charges via frozen)."""
        shard, idx = self.index[path]
        freed = int(self.state["usage"][shard, idx])
        if freed:
            self.uncharge(path, freed)
        st = self.state
        usage, active, frozen = st["usage"], st["active"], st["frozen"]
        for p in self._subtree(path):
            s, i = self.index[p]
            usage = usage.at[s, i].set(0)
            active = active.at[s, i].set(False)
            frozen = frozen.at[s, i].set(True)
        self.state = dict(st, usage=usage, active=active, frozen=frozen)
        self.log.emit(self._now, Ev.OOM_KILL, path, freed=freed)
        return freed

    # --------------------------------------------------------- control files

    _FILE_KEY = {"memory.current": "usage", "memory.peak": "peak",
                 "memory.high": "high", "memory.max": "max",
                 "memory.low": "low", "memory.priority": "priority",
                 "cgroup.freeze": "frozen", "cpu.weight": "weight",
                 "cpu.max": "cpu_max"}

    def reconcile(self) -> dict:
        """Host-side reconciliation of the global root across device
        groups, gathered shard by shard: usage and peak sum over the
        shard-local roots, throttle is a flag (any group throttled).
        This is the seam the chaos harness targets — the optional
        ``reconcile_hook(shard)`` attribute runs between per-shard
        gathers, where fault injection (or a concurrent lifecycle op)
        can land mid-reconciliation."""
        hook = getattr(self, "reconcile_hook", None)
        usage = peak = 0
        throttled = False
        for s in range(self.n_shards):
            if hook is not None:
                hook(s)
            usage += int(self.state["usage"][s, 0])
            peak += int(self.state["peak"][s, 0])
            throttled |= bool(self.state["throttle_until"][s, 0] > 0)
        return {"usage": usage, "peak": peak, "throttled": throttled}

    def read(self, path: str, file: str):
        from repro.core import pressure as PSI
        if file in PSI.STALL_FILES:
            # stall counters are local per domain; roll the subtree up
            # host-side over the logical path tree, gathering each
            # registered path's row from its owning shard
            key = "mem_stall" if file == "memory.stall" else "cpu_stall"
            col = np.asarray(self.state[key])
            return PSI.subtree_counts_by_path(
                {p: int(col[s, i]) for p, (s, i) in self.index.items()
                 if path_in_scope(path, p)})[path]
        if path == "/":
            # reconcile the global root across device groups
            if file == "memory.current":
                return self.reconcile()["usage"]
            if file == "memory.peak":
                return self.reconcile()["peak"]
            if file == "memory.events":
                # flag, not a shard count — DeviceTableBackend semantics
                return {"high": 0, "max": 0,
                        "throttle": int(self.reconcile()["throttled"]),
                        "oom_kill": 0}
            return int(self.state[self._FILE_KEY[file]][0, 0])
        shard, idx = self.index[path]
        if file == "memory.events":
            tu = int(self.state["throttle_until"][shard, idx])
            return {"high": 0, "max": 0, "throttle": int(tu > 0),
                    "oom_kill": 0}
        return int(self.state[self._FILE_KEY[file]][shard, idx])

    def write(self, path: str, file: str, value) -> None:
        if file == "cgroup.freeze":
            (self.freeze if int(value) else self.thaw)(path)
            return
        if file == "cpu.weight":
            from repro.core.sched import check_weight
            value = check_weight(value)
        key = self._FILE_KEY[file]
        st = self.state
        if path == "/":                # root limits apply to every group
            if file == "memory.max":
                self.capacity = int(value)
            self.state = dict(st, **{
                key: st[key].at[:, 0].set(int(value))})
        else:
            shard, idx = self.index[path]
            self.state = dict(st, **{
                key: st[key].at[shard, idx].set(int(value))})
        if file == "cpu.weight":
            self._recompute_flat()

    # --------------------------------------------------------------- queries

    def snapshot(self) -> dict:
        """One host sync; rows addressable by global handle
        (``shard * n_domains + local``), parent pointers rebased to
        global handles, plus the reconciled root usage."""
        st = {k: np.asarray(v) for k, v in self.state.items()}
        S, n = self.n_shards, self.per_shard_domains
        base = (np.arange(S) * n)[:, None]
        parent = st["parent"]
        parent = np.where(parent >= 0, parent + base, -1).reshape(-1)
        return {"paths": list(self.index),
                "index": {p: self._handle(*si)
                          for p, si in self.index.items()},
                "usage": st["usage"].reshape(-1),
                "high": st["high"].reshape(-1),
                "max": st["max"].reshape(-1),
                "parent": parent,
                "active": st["active"].reshape(-1),
                "peak": st["peak"].reshape(-1),
                "low": st["low"].reshape(-1),
                "priority": st["priority"].reshape(-1),
                "frozen": st["frozen"].reshape(-1),
                "throttle_until": st["throttle_until"].reshape(-1),
                "params": st["prog"].reshape(S * n, -1),
                "weight": st["weight"].reshape(-1),
                "cpu_max": st["cpu_max"].reshape(-1),
                "flat_weight": st["flat_weight"].reshape(-1),
                "vruntime": st["vruntime"].reshape(-1),
                "cpu_used": st["cpu_used"].reshape(-1),
                "cpu_stamp": st["cpu_stamp"].reshape(-1),
                "mem_stall": st["mem_stall"].reshape(-1),
                "cpu_stall": st["cpu_stall"].reshape(-1),
                "prog_id": st["prog_id"].reshape(-1),
                "root_usage": int(st["usage"][:, 0].sum()),
                "root_handles": [s * n for s in range(S)],
                "placement": dict(self._tenant_shard),
                "next_shard": self._next_shard}

    def restore(self, snap: dict) -> None:
        """Rebuild placement, index, and the stacked device state from a
        ``snapshot()`` dict — crash recovery onto a freshly constructed
        backend with the same mesh shape and ``n_domains`` (see
        ``HostTreeBackend.restore``).  Call after ``attach``."""
        S, n = self.n_shards, self.per_shard_domains
        assert len(snap["usage"]) == S * n, "snapshot/mesh shape mismatch"
        self.index = {p: divmod(h, n) for p, h in snap["index"].items()}
        self.index["/"] = (0, 0)
        used = {s: {0} for s in range(S)}
        for s, i in self.index.values():
            used.setdefault(s, {0}).add(i)
        self._free = [[i for i in range(1, n) if i not in used[s]]
                      for s in range(S)]
        for heap in self._free:
            heapq.heapify(heap)
        self._tenant_shard = dict(snap.get("placement", {}))
        self._next_shard = int(snap.get("next_shard", 0))
        base = (np.arange(S) * n)[:, None]
        parent = np.asarray(snap["parent"]).reshape(S, n)
        parent = np.where(parent >= 0, parent - base, -1)
        sh = NamedSharding(self.mesh, P("shard"))
        new = dict(self.state)
        for key, src, dtype in (
                ("usage", "usage", jnp.int32), ("peak", "peak", jnp.int32),
                ("high", "high", jnp.int32), ("max", "max", jnp.int32),
                ("low", "low", jnp.int32),
                ("priority", "priority", jnp.int32),
                ("frozen", "frozen", jnp.bool_),
                ("active", "active", jnp.bool_),
                ("throttle_until", "throttle_until", jnp.int32),
                ("weight", "weight", jnp.int32),
                ("cpu_max", "cpu_max", jnp.int32),
                ("flat_weight", "flat_weight", jnp.float32),
                ("vruntime", "vruntime", jnp.float32),
                ("cpu_used", "cpu_used", jnp.int32),
                ("cpu_stamp", "cpu_stamp", jnp.int32),
                ("mem_stall", "mem_stall", jnp.int32),
                ("cpu_stall", "cpu_stall", jnp.int32),
                ("prog_id", "prog_id", jnp.int32)):
            if src in snap:
                arr = np.asarray(snap[src]).reshape(S, n)
                new[key] = jax.device_put(jnp.asarray(arr, dtype), sh)
        new["parent"] = jax.device_put(jnp.asarray(parent, jnp.int32), sh)
        params = np.asarray(snap["params"]).reshape(S, n, -1)
        new["prog"] = jax.device_put(jnp.asarray(params, jnp.float32), sh)
        self.state = new
        if "flat_weight" not in snap:      # older snapshot: re-flatten
            self._recompute_flat()

    def set_time(self, t: float) -> None:
        self._now = t

"""Async lifecycle daemon: the fourth ``Backend`` (paper §4.2 / §5).

The paper's responsiveness mismatch splits resource control in two:
per-allocation *enforcement* must stay on the sub-second hot path (here:
inside the jitted engine step, via ``device_view()``), while *lifecycle*
work — domain creation/removal, limit writes, freeze/thaw, program
attach/retune, intent-lease open/close — belongs to a user-space daemon
that must never block that path.  ``AsyncDaemonBackend`` is that daemon:
a wrapper around any inner ``Backend`` (host / device / sharded) that
moves every lifecycle op onto a dedicated daemon thread behind a FIFO
command queue.

Semantics — chosen so the wrapper is *bit-exact* with its inner backend
run synchronously:

  * **FIFO epochs.**  Commands apply strictly in submission order, in
    batches ("epochs").  In the default *deferred* mode an epoch runs
    only when something demands it — an explicit ``flush()`` /
    ``barrier()`` (the engine calls one per step, at the step boundary),
    a read, or a result-bearing op.  With ``eager=True`` the daemon
    drains the queue as soon as commands arrive (same order, same
    results, different wall-clock).
  * **Fire-and-forget ops** (``write``, ``freeze``, ``thaw``,
    ``uncharge``, ``charge_unchecked``, ``update_params``, ``attach``,
    ``set_time``) enqueue and return immediately — the caller never
    waits for the inner backend's (possibly device-dispatching) work.
    An op that fails on the daemon thread is held and re-raised as
    ``DaemonError`` at the next ``flush()``.
  * **Result-bearing ops** (``mkdir``, ``rmdir``, ``kill``,
    ``try_charge``) enqueue, fence the queue up to themselves, and wait
    for their own completion — the work still runs on the daemon
    thread, after everything queued before it, so e.g. an rmdir racing
    an in-flight charge batch transfers the residual exactly once.
  * **Reads are snapshot-consistent**: ``read``/``exists``/``paths``/
    ``snapshot`` first flush, then delegate, so they always observe a
    whole number of epochs; ``snapshot()`` is tagged with the ``epoch``
    it reflects.
  * **Deadlocks fail fast**: waits carry a liveness check plus a
    ``flush_timeout_s`` ceiling and raise ``DaemonError`` instead of
    hanging the caller (CI pairs this with pytest-timeout).  A
    timed-out wait also *poisons* the backend — the stuck command
    cannot be cancelled and may still apply once the daemon unwedges,
    so every later submit/flush raises until the backend is closed and
    rebuilt.

The enforcement hot path is untouched: ``device_view()`` returns the
*inner* backend's view, whose pure ``charge``/``account``/``gate``
functions the jitted step closes over — the daemon only ever mutates
state between epochs, which the engine aligns with step boundaries.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Optional

from repro.core.cgroup import ChargeTicket, DomainSpec
from repro.core.events import EventLog
from repro.core.progs import PolicyProgram


class DaemonError(RuntimeError):
    """A queued lifecycle op failed, the daemon thread died, or a wait
    exceeded ``flush_timeout_s`` (wedged daemon)."""


@dataclass
class _Cmd:
    seq: int
    name: str
    args: tuple
    done: Optional[threading.Event]          # set for result-bearing ops
    result: Any = None
    error: Optional[BaseException] = None


class AsyncDaemonBackend:
    """Wraps any inner ``Backend``; lifecycle ops run on a daemon thread
    in FIFO epochs.  See module docstring for the exact semantics."""

    _POLL_S = 0.05                           # liveness-check granularity

    def __init__(self, inner, *, eager: bool = False,
                 flush_timeout_s: float = 60.0):
        self.inner = inner
        self.eager = bool(eager)
        self.flush_timeout_s = float(flush_timeout_s)
        self.epoch = 0                       # completed apply batches
        self._cv = threading.Condition()
        # held by the daemon while a batch applies and by flushing
        # reads while they observe the inner backend: reads see whole
        # epochs even with concurrent submitters (eager mode, threads)
        self._apply_lock = threading.Lock()
        self._queue: deque[_Cmd] = deque()
        self._submitted = 0                  # seq of last enqueued command
        self._applied = 0                    # seq of last applied command
        self._fence = 0                      # daemon may apply seq <= fence
        self._errors: list[tuple[str, BaseException]] = []
        self._closed = False
        self._wedged = False                 # a wait timed out: state unknown
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="agentcgroup-daemon")
        self._thread.start()

    # ------------------------------------------------------------ the daemon

    def _runnable(self) -> bool:
        return bool(self._queue) and self._queue[0].seq <= self._fence

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._closed and not self._runnable():
                    self._cv.wait()
                if self._closed and not self._runnable():
                    return
                batch = []
                while self._queue and self._queue[0].seq <= self._fence:
                    batch.append(self._queue.popleft())
            with self._apply_lock:           # outside _cv: real work
                for cmd in batch:
                    try:
                        cmd.result = getattr(self.inner, cmd.name)(*cmd.args)
                    except BaseException as e:  # noqa: BLE001 — repost
                        cmd.error = e
                        if cmd.done is None:
                            with self._cv:
                                self._errors.append((cmd.name, e))
                    finally:
                        if cmd.done is not None:
                            cmd.done.set()
                # bookkeeping inside the apply lock: a reader holding it
                # sees state and epoch tag move together, never state of
                # epoch N+1 stamped as epoch N
                with self._cv:
                    self._applied = batch[-1].seq
                    self.epoch += 1          # one epoch per drained batch
                    self._cv.notify_all()

    def _submit(self, name: str, *args, want_result: bool = False):
        done = threading.Event() if want_result else None
        with self._cv:
            if self._closed:
                raise DaemonError("backend is closed")
            if self._wedged:
                raise DaemonError("daemon previously timed out; state is "
                                  "unknown — close and rebuild the backend")
            if not self._thread.is_alive():
                raise DaemonError("daemon thread died")
            self._submitted += 1
            cmd = _Cmd(self._submitted, name, args, done)
            self._queue.append(cmd)
            if self.eager or want_result:
                self._fence = self._submitted
            self._cv.notify_all()
        if not want_result:
            return None
        deadline = time.monotonic() + self.flush_timeout_s
        while not done.wait(timeout=self._POLL_S):
            if not self._thread.is_alive():
                raise DaemonError(f"daemon thread died applying {name!r}")
            if time.monotonic() > deadline:
                # the command cannot be safely cancelled (it may apply
                # later, once the daemon unwedges) — poison the backend
                # so no caller keeps using state it can no longer trust
                self._wedged = True
                raise DaemonError(
                    f"{name!r} timed out after {self.flush_timeout_s}s "
                    "(wedged daemon?); backend poisoned — close and "
                    "rebuild")
        if cmd.error is not None:
            raise cmd.error
        return cmd.result

    # ------------------------------------------------------- epoch control

    def flush(self) -> int:
        """Apply every command queued so far (one epoch), re-raise any
        deferred-op failure, and return the epoch now reflected."""
        with self._cv:
            if self._closed:
                raise DaemonError("backend is closed")
            if self._wedged:
                raise DaemonError("daemon previously timed out; state is "
                                  "unknown — close and rebuild the backend")
            target = self._submitted
            if self._fence < target:
                self._fence = target
                self._cv.notify_all()
            deadline = time.monotonic() + self.flush_timeout_s
            while self._applied < target:
                if not self._thread.is_alive():
                    raise DaemonError("daemon thread died with work queued")
                if time.monotonic() > deadline:
                    self._wedged = True      # queued work may apply later
                    raise DaemonError(
                        f"flush timed out after {self.flush_timeout_s}s "
                        "(wedged daemon?); backend poisoned — close and "
                        "rebuild")
                self._cv.wait(timeout=self._POLL_S)
            errors, self._errors = self._errors, []
            epoch = self.epoch
        if errors:
            name, first = errors[0]
            raise DaemonError(
                f"{len(errors)} deferred lifecycle op(s) failed; "
                f"first: {name}: {first!r}") from first
        return epoch

    barrier = flush                          # deterministic-replay alias

    def close(self, *, flush: bool = True) -> None:
        """Stop the daemon thread.  By default drains the queue first;
        ``flush=False`` drops whatever is still queued."""
        if self._closed:
            return
        try:
            if flush and not self._wedged and self._thread.is_alive():
                self.flush()             # may raise a deferred DaemonError
        finally:                         # ...but the daemon always stops
            with self._cv:
                self._closed = True
                if not flush:
                    self._queue.clear()
                self._cv.notify_all()
            self._thread.join(timeout=self.flush_timeout_s)

    def __enter__(self) -> "AsyncDaemonBackend":
        return self

    def __exit__(self, *exc) -> None:
        self.close(flush=exc == (None, None, None))

    # ------------------------------------------------- Backend: lifecycle

    def attach(self, scope: str, prog: PolicyProgram) -> None:
        self._submit("attach", scope, prog)

    def update_params(self, path: str, kv: dict) -> None:
        self._submit("update_params", path, kv)

    def mkdir(self, path: str, spec: DomainSpec) -> int:
        return self._submit("mkdir", path, spec, want_result=True)

    def rmdir(self, path: str, transfer_residual: bool) -> int:
        return self._submit("rmdir", path, transfer_residual,
                            want_result=True)

    def kill(self, path: str) -> int:
        return self._submit("kill", path, want_result=True)

    def freeze(self, path: str) -> None:
        self._submit("freeze", path)

    def thaw(self, path: str) -> None:
        self._submit("thaw", path)

    def write(self, path: str, file: str, value) -> None:
        self._submit("write", path, file, value)

    def set_time(self, t: float) -> None:
        self._submit("set_time", t)

    # -------------------------------------------------- Backend: charging

    def try_charge(self, path: str, pages: int,
                   step: Optional[int]) -> ChargeTicket:
        return self._submit("try_charge", path, pages, step,
                            want_result=True)

    def schedule(self, paths: list, costs: list, step: int,
                 budget: int) -> list:
        """Result-bearing like ``try_charge``: the round runs on the
        daemon after everything queued before it (a weight write queued
        earlier lands before the slots are ranked)."""
        return self._submit("schedule", paths, costs, step, budget,
                            want_result=True)

    def uncharge(self, path: str, pages: int) -> None:
        self._submit("uncharge", path, pages)

    def charge_unchecked(self, path: str, pages: int) -> None:
        self._submit("charge_unchecked", path, pages)

    # ------------------------------------------- Backend: reads (flushing)

    def _observe(self, fn, *args):
        """Flush, then observe the inner backend under the apply lock:
        even with concurrent submitters (eager mode, other threads) a
        read never sees a batch mid-application — always a whole number
        of epochs."""
        self.flush()
        with self._apply_lock:
            return fn(*args)

    def exists(self, path: str) -> bool:
        return self._observe(lambda: self.inner.exists(path))

    def paths(self) -> list[str]:
        return self._observe(lambda: self.inner.paths())

    def handle(self, path: str) -> int:
        return self._observe(lambda: self.inner.handle(path))

    def path_of(self, handle: int) -> str:
        return self._observe(lambda: self.inner.path_of(handle))

    def read(self, path: str, file: str):
        return self._observe(lambda: self.inner.read(path, file))

    def snapshot(self) -> dict:
        """Inner snapshot tagged with the epoch it reflects."""

        def take():
            snap = self.inner.snapshot()
            snap["epoch"] = self.epoch
            return snap

        return self._observe(take)

    @property
    def log(self) -> EventLog:
        return self._observe(lambda: self.inner.log)

    @property
    def prog(self) -> PolicyProgram:
        return self._observe(lambda: self.inner.prog)

    @property
    def progs(self) -> tuple:
        return self._observe(lambda: self.inner.progs)

    def device_view(self):
        """The INNER backend's jit-safe view: in-step enforcement never
        goes through the queue (the daemon only mutates between epochs,
        which the engine aligns with step boundaries)."""
        return self._observe(lambda: self.inner.device_view())

    def __getattr__(self, name: str):
        # backend-specific read-only extras (placement, index, tree,
        # table, n_shards, throttle_delay_ms, ...): the attribute fetch
        # observes a whole number of epochs; invoking a returned bound
        # method runs outside the epoch lock (single-writer callers
        # only, like everything engine-facing)
        if name.startswith("_") or "inner" not in self.__dict__:
            raise AttributeError(name)
        return self._observe(lambda: getattr(self.inner, name))

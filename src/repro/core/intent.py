"""Intent-driven bidirectional coordination (paper §5).

Upward (agent -> system): before each tool call the agent declares an
expected resource need (``AGENT_RESOURCE_HINT`` analogue).  Hints are
*advisory* — they set per-tool-call ``memory.high`` so a mis-declared
call throttles early instead of starving siblings; the feedback loop
corrects underestimates.

Downward (system -> agent): when a tool call is throttled beyond
recovery or killed, the controller emits a structured feedback record
(peak pages, limit, suggestion).  The agent model in the replay harness
reacts by *reconstructing its strategy* — retrying the call with reduced
scope (the paper's key exploitable property of agent workloads).
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class Hint(enum.Enum):
    LOW = "memory:low"
    MEDIUM = "memory:medium"
    HIGH = "memory:high"


# default per-hint soft limits, in pages (1 page ~ 1 MB in trace replay,
# calibrated to the paper's category statistics: file ops ~4.5 MB, git
# ~13.5 MB, pkg install P95 ~233 MB, test execution P95 ~518 MB).
HINT_HIGH_PAGES = {
    Hint.LOW: 32,
    Hint.MEDIUM: 256,
    Hint.HIGH: 768,
}

# tool-call semantic category -> hint an intent-aware agent would declare
CATEGORY_HINT = {
    "test": Hint.HIGH,
    "pip": Hint.MEDIUM,
    "python": Hint.MEDIUM,
    "build": Hint.HIGH,
    "file": Hint.LOW,
    "git": Hint.LOW,
    "read": Hint.LOW,
    "edit": Hint.LOW,
    "subagent": Hint.HIGH,
}


def parse_hint(s: Optional[str]) -> Optional[Hint]:
    if not s:
        return None
    try:
        return Hint(s)
    except ValueError:
        return None


def hint_to_high(hint: Optional[Hint], *, headroom: float = 1.5) -> int:
    """Map a declared hint to a per-tool-call ``memory.high`` (pages)."""
    if hint is None:
        return HINT_HIGH_PAGES[Hint.MEDIUM]
    return int(HINT_HIGH_PAGES[hint] * headroom)


@dataclass
class Feedback:
    """Structured downward feedback (stderr-injection analogue)."""
    tool_id: str
    reason: str                 # "throttled" | "oom" | "frozen"
    peak_pages: int
    limit_pages: int
    suggestion: str

    def render(self) -> str:
        return (f"[agentcgroup] tool {self.tool_id} {self.reason}: "
                f"peak {self.peak_pages} pages vs limit {self.limit_pages}. "
                f"{self.suggestion}")


def make_feedback(tool_id: str, reason: str, peak: int, limit: int) -> Feedback:
    if reason == "oom":
        sug = ("Reduce the scope of this command (e.g. run a subset of the "
               "test suite, or split the workload) and retry.")
    elif reason == "oom_kill":
        sug = ("This call was killed by its memcg hard limit; it will be "
               "retried at a negotiated higher limit if headroom allows.")
    elif reason == "throttled":
        sug = ("This call exceeded its declared memory hint; declare "
               "memory:high or reduce working-set size.")
    else:
        sug = "Session was frozen under memory pressure; it will resume."
    return Feedback(tool_id, reason, peak, limit, sug)


def feedback_from_oom(ev) -> Feedback:
    """Bridge a typed ``OomEvent`` (events.py) into the downward
    feedback record the replayed agent model consumes — the semantic
    half of the kill -> feedback -> retry loop."""
    return make_feedback(ev.path.rsplit("/", 1)[-1], "oom_kill",
                         ev.peak_pages, ev.limit_pages)


@dataclass
class AdaptiveAgentModel:
    """How the replayed agent reacts to downward feedback.

    ``scope_scale`` models strategy reconstruction: on OOM/throttle
    feedback, the retried tool call's memory burst shrinks by this
    factor (e.g. running half the test suite).  ``learns_hints``: after
    one correction the agent declares the right hint for that category.
    """
    scope_scale: float = 0.5
    max_retries: int = 2
    learns_hints: bool = True
    learned: dict = field(default_factory=dict)    # category -> Hint

    def on_feedback(self, category: str, fb: Feedback) -> dict:
        """Returns the retry adjustment for the failed tool call."""
        if self.learns_hints and fb.reason in ("throttled", "oom"):
            self.learned[category] = Hint.HIGH
        return {"scale": self.scope_scale if fb.reason == "oom" else 1.0,
                "hint": self.learned.get(category)}

    def hint_for(self, category: str, declared: Optional[Hint]) -> Optional[Hint]:
        return self.learned.get(category, declared)

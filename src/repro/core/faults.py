"""Deterministic fault injection for the control plane.

``FaultyBackend`` wraps any ``Backend`` (host/device/sharded, and —
composed inside ``AsyncDaemonBackend`` — the async kinds) and injects
faults from a seeded ``FaultPlan``:

  * transient op errors   — ``TransientBackendError`` raised *before*
    the inner op applies, so a retry is always safe;
  * delayed applies       — the op sleeps before applying (off the
    critical path on async backends, visible latency on sync ones);
  * spurious memcg kills  — an out-of-band ``kill`` on a live domain,
    the "kernel OOM-killed the tool" case escalation must absorb;
  * daemon wedges         — the op blocks until ``unwedge()`` (or the
    wedge timeout); inside an ``AsyncDaemonBackend`` this wedges the
    daemon thread, so ``flush`` times out and poisons the backend —
    exactly the failure the engine's rebuild path recovers from;
  * kills mid-freeze      — the kernel OOM killer fires while the
    freezer is quiescing the subtree: the domain is killed first, then
    the freeze applies to the dead subtree (``p_kill_mid_freeze``);
  * offload transients    — the device->host state offload fails
    partway (``p_offload_transient``): ``offload_fault`` plugs into
    ``FrozenStore.offload_hook``, which raises BEFORE the entry
    commits — never a partial frozen entry, so a retry is safe.

All randomness comes from one ``numpy`` generator seeded by the plan
and advanced a fixed four draws per intercepted op, so a given plan +
op sequence always injects the same faults: every chaos failure is
replayable from the plan alone (CI uploads it as an artifact).  The
freeze/offload chaos points draw from a SEPARATE stream (seeded
``seed ^ _CHAOS_SEED``, fixed one draw per event, only when their
probability is nonzero) so enabling them never shifts the original
four-draw schedule of an existing plan.

The wrapper is conformance-certifiable: with the default (fault-free)
plan it is bit-exact with its inner backend, which
``testing.conformance.faulty_backend_factory`` certifies for all six
backend kinds.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

# lifecycle/mutating ops eligible for fault injection (reads stay
# clean so observation never perturbs the run)
MUTATING_OPS = ("mkdir", "rmdir", "write", "try_charge", "uncharge",
                "charge_unchecked", "freeze", "thaw", "kill",
                "attach", "update_params", "schedule")


class TransientBackendError(RuntimeError):
    """Injected transient failure: the op did NOT apply; retrying it is
    safe (and, with ``auto_retry``, automatic)."""


# XOR'd into the plan seed for the freeze/offload chaos stream, so the
# new fault points never advance the original four-draw-per-op schedule
_CHAOS_SEED = 0x5EED


@dataclass(frozen=True)
class FaultPlan:
    """Seeded fault schedule.  The default plan injects nothing."""
    seed: int = 0
    p_transient: float = 0.0
    p_delay: float = 0.0
    delay_s: float = 0.001
    p_spurious_kill: float = 0.0
    p_wedge: float = 0.0
    wedge_s: float = 5.0
    # freeze/offload chaos (separate RNG stream; see module docstring)
    p_kill_mid_freeze: float = 0.0
    p_offload_transient: float = 0.0
    ops: tuple = MUTATING_OPS

    def to_json(self) -> str:
        d = dataclasses.asdict(self)
        d["ops"] = list(d["ops"])
        return json.dumps(d, indent=2)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        d["ops"] = tuple(d["ops"])
        return cls(**d)


class FaultyBackend:
    """Transparent fault-injecting wrapper around any backend.

    ``auto_retry`` > 0 makes injected transients self-heal (the op
    applies after the retries the caller would have issued) — with it,
    a transient-only plan stays bit-exact with the fault-free run.
    ``on_spurious_kill(path, freed)`` lets a harness route an injected
    kill into the intent channel (``note_external_kill``); it MUST NOT
    call back into an async facade when this wrapper runs inside an
    ``AsyncDaemonBackend`` (it would flush from the daemon thread).
    """

    def __init__(self, inner, plan: Optional[FaultPlan] = None, *,
                 auto_retry: int = 0,
                 on_spurious_kill: Optional[Callable] = None):
        self._inner = inner
        self.plan = plan if plan is not None else FaultPlan()
        self.auto_retry = auto_retry
        self.on_spurious_kill = on_spurious_kill
        self._rng = np.random.default_rng(self.plan.seed)
        self._chaos_rng = np.random.default_rng(self.plan.seed ^ _CHAOS_SEED)
        self._op_no = 0
        self._unwedge = threading.Event()
        self.injected: list[tuple] = []   # (op_no, op, fault, detail)

    # ------------------------------------------------------------ injection

    def unwedge(self) -> None:
        """Release any current (and future) wedge."""
        self._unwedge.set()

    def _pre_fault(self, name: str) -> bool:
        """Draws this op's fault decisions; returns True when a
        transient error should fire.  Fixed four draws per op keeps the
        schedule independent of fault outcomes."""
        p = self.plan
        r_tr, r_dl, r_ki, r_we = self._rng.random(4)
        op_no = self._op_no
        self._op_no += 1
        if r_we < p.p_wedge:
            self.injected.append((op_no, name, "wedge", p.wedge_s))
            self._unwedge.wait(p.wedge_s)
        if r_dl < p.p_delay:
            self.injected.append((op_no, name, "delay", p.delay_s))
            time.sleep(p.delay_s)
        if r_ki < p.p_spurious_kill:
            self._spurious_kill(op_no)
        return r_tr < p.p_transient

    def _spurious_kill(self, op_no: int) -> None:
        victims = sorted(p for p in self._inner.paths()
                         if p != "/" and len(p.split("/")) > 2)
        if not victims:
            return
        pick = victims[int(self._rng.integers(len(victims)))]
        freed = self._inner.kill(pick)
        self.injected.append((op_no, "kill", "spurious_kill", pick))
        if self.on_spurious_kill is not None:
            self.on_spurious_kill(pick, freed)

    def _kill_mid_freeze(self, path: str) -> None:
        """The kernel OOM killer fired while the freezer was quiescing:
        the subtree dies FIRST (usage released, domains retired), then
        the caller's freeze applies to the dead subtree — the race the
        escalation/engine recovery paths must absorb."""
        freed = self._inner.kill(path)
        self.injected.append((self._op_no - 1, "freeze",
                              "kill_mid_freeze", path))
        if self.on_spurious_kill is not None:
            self.on_spurious_kill(path, freed)

    def offload_fault(self, session_id: str) -> None:
        """``FrozenStore.offload_hook`` seam: wire as
        ``caches.store.offload_hook = faulty.offload_fault`` and the
        device->host offload fails transiently mid-copy — the hook
        raises before the entry commits, so the store never holds a
        partial entry and the caller's retry is safe."""
        if self.plan.p_offload_transient <= 0.0:
            return
        if self._chaos_rng.random() < self.plan.p_offload_transient:
            self.injected.append((self._op_no, "offload", "transient",
                                  session_id))
            raise TransientBackendError(
                f"injected offload failure for {session_id!r} "
                f"(seed {self.plan.seed})")

    def _wrap(self, name: str, fn):
        def wrapper(*a, **k):
            transient = self._pre_fault(name)
            if transient:
                self.injected.append((self._op_no - 1, name, "transient", ""))
                if self.auto_retry <= 0:
                    raise TransientBackendError(
                        f"injected transient failure in {name} "
                        f"(op #{self._op_no - 1}, seed {self.plan.seed})")
            if (name == "freeze" and self.plan.p_kill_mid_freeze > 0.0
                    and self._chaos_rng.random()
                    < self.plan.p_kill_mid_freeze):
                self._kill_mid_freeze(a[0] if a else k["path"])
            return fn(*a, **k)
        return wrapper

    # ---------------------------------------------------------- passthrough

    def __getattr__(self, name):
        attr = getattr(self._inner, name)
        if name in self.plan.ops and callable(attr):
            return self._wrap(name, attr)
        return attr

    def close(self, **kw) -> None:
        self.unwedge()
        fn = getattr(self._inner, "close", None)
        if fn is not None:
            fn(**kw)

"""Pluggable in-step policy programs — the memcg_bpf_ops analogue.

The paper's responsiveness and adaptability fixes hinge on enforcement
logic that is *attachable* and *runtime-updatable* at the kernel charge
point (memcg_bpf_ops / sched_ext struct_ops).  The repo's analogue is a
``PolicyProgram``: a small object of pure, JAX-traceable hooks

    on_charge(view, req)   -> Verdict          (the try_charge verdict)
    on_over_high(view, req, over_frac, protected) -> delay_ms
    on_gate(view, step)    -> may-advance bool (the slot gate)

closed over a flat device-resident parameter table ``(n_domains, P)``
f32 — one row per domain, columns named by ``param_names``.  The table
is *state*, not a trace constant: it rides inside the control-state
pytree (key ``"prog"``), so the host daemon can retune a live policy
(``cg.update_params(path, overage_gain=...)``) between two jitted
engine steps with zero recompilation — exactly how a BPF map update
retunes a loaded program without reloading it.  Attaching a *different*
program (``cg.attach(path, prog)``) swaps the decision code and does
recompile, like loading a new BPF object.

Every backend executes the SAME decision code:

  * the device table runs ``charge_decision`` inside ``lax.scan`` in the
    jitted engine step (``controller.charge_batch``);
  * the sharded table runs the identical kernel per shard under
    ``shard_map``;
  * the host tree calls the identical ``charge_decision`` (jit-compiled
    once per program) from ``HostTreeBackend.try_charge`` — so the
    trace-replay simulator and the serving engine can no longer drift.

The memcg *contract* (hierarchical hard ``max``, cgroup.freeze, atomic
commit) is enforced by the default ``on_charge`` and is what programs
normally build on; a program may also tighten it (``TokenBucketProgram``
denies what the contract alone would grant) — mirroring how BPF hooks
refine, not replace, kernel invariants.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as D
from repro.core.domains import (BASE_DELAY_MS, HIGH_PRIORITY_DISCOUNT,
                                MAX_DELAY_MS, OVERAGE_GAIN, UNLIMITED)


def path_in_scope(scope: str, path: str) -> bool:
    """Is ``path`` inside the subtree rooted at ``scope``?  The single
    prefix rule every backend uses for attach scoping and subtree
    parameter writes."""
    return (scope == "/" or path == scope
            or path.startswith(scope.rstrip("/") + "/"))


class Request(NamedTuple):
    """One charge attempt, as seen by a program hook."""
    dom: jax.Array        # charged domain handle (i32 scalar)
    amt: jax.Array        # pages requested (i32 scalar)
    step: jax.Array       # throttle clock (i32 steps in-step; ms host-side)


class ChainView(NamedTuple):
    """The charged domain's ancestor chain (self-first), padded/masked so
    invalid entries are neutral (usage 0, limits UNLIMITED, not frozen).
    ``params`` is the charged domain's program row; ``prog_id`` selects
    its decision code from the attached program registry (slot 0 — the
    primary program — when only one program is attached)."""
    valid: jax.Array            # (depth,) bool
    usage: jax.Array            # (depth,) i32 — pre-charge
    high: jax.Array             # (depth,) i32
    max: jax.Array              # (depth,) i32
    low: jax.Array              # (depth,) i32
    frozen: jax.Array           # (depth,) bool
    throttle_until: jax.Array   # (depth,) i32/f32, same clock as req.step
    priority: jax.Array         # i32 scalar — the charged domain's
    params: jax.Array           # (P,) f32 — the charged domain's row
    prog_id: jax.Array = 0      # i32 scalar — registry slot of the domain


class Verdict(NamedTuple):
    """What ``on_charge`` decides.  ``stall`` marks retryable denials
    (freeze / throttle / hard max / program admission).  ``params`` is
    the possibly-updated program row for the charged domain — programs
    with per-domain mutable state (token buckets) write it back here."""
    grant: jax.Array            # bool scalar
    stall: jax.Array            # bool scalar
    delay_ms: jax.Array         # f32 scalar — program-imposed extra delay
    params: jax.Array           # (P,) f32


class SchedRequest(NamedTuple):
    """One slot asking for a step grant, as seen by ``on_schedule``."""
    dom: jax.Array        # scheduled domain handle (i32 scalar)
    cost: jax.Array       # step cost in budget units (i32 scalar)
    step: jax.Array       # engine step (i32 scalar)


class SchedView(NamedTuple):
    """The scheduled domain's ancestor chain (self-first, masked like
    ``ChainView``) plus its CPU scheduling account.  ``weight`` and
    ``flat_weight`` are the *charged domain's* scalars (the flattened
    weight already folds the ancestors in, as scx_flatcg does)."""
    valid: jax.Array            # (depth,) bool
    frozen: jax.Array           # (depth,) bool
    throttle_until: jax.Array   # (depth,) i32/f32, same clock as req.step
    weight: jax.Array           # i32 scalar — the domain's own cpu.weight
    flat_weight: jax.Array      # f32 scalar — flattened hierarchical weight
    vruntime: jax.Array         # f32 scalar — fairness account
    priority: jax.Array         # i32 scalar
    params: jax.Array           # (P,) f32 — the domain's program row
    prog_id: jax.Array = 0      # i32 scalar — registry slot of the domain


class PolicyProgram:
    """Base program: the bare memcg contract, no throttling.

    Subclasses override hooks and declare ``param_names``.  Hooks must
    stay pure and JAX-traceable (``jnp``/``lax`` ops only, no python
    control flow on traced values) — the same callable runs inside the
    jitted engine step, under ``shard_map``, and host-side.
    """

    param_names: tuple = ()
    step_ms: float = 10.0        # delay quantum (trace constant)
    sched_window: int = 100      # cpu.max accounting window, steps
    sched_lag: float = 8.0       # max vruntime lag a waking domain keeps

    # ------------------------------------------------------- param table

    @property
    def n_params(self) -> int:
        return max(1, len(self.param_names))    # keep (n, P) well-formed

    def default_row(self) -> np.ndarray:
        """Row for domains inside the attach scope."""
        return np.zeros((self.n_params,), np.float32)

    def neutral_row(self) -> np.ndarray:
        """Row for domains *outside* the attach scope: the program's
        parameterized behaviour must be a no-op there (the contract
        still applies everywhere)."""
        return np.zeros((self.n_params,), np.float32)

    def init_params(self, n_domains: int) -> jnp.ndarray:
        return jnp.broadcast_to(
            jnp.asarray(self.default_row(), jnp.float32),
            (n_domains, self.n_params))

    def col(self, name: str) -> int:
        try:
            return self.param_names.index(name)
        except ValueError:
            raise KeyError(
                f"{type(self).__name__} has no param {name!r}; "
                f"knobs: {self.param_names}") from None

    # ------------------------------------------------------------- hooks

    def on_charge(self, view: ChainView, req: Request) -> Verdict:
        """The memcg try_charge contract: deny on a frozen ancestor, an
        active throttle window, or a hierarchical hard-``max`` breach;
        all denials are retryable stalls (the engine's graceful-
        degradation path never OOM-kills in-step)."""
        frozen = jnp.any(view.valid & view.frozen)
        throttled = jnp.any(view.valid & (view.throttle_until > req.step))
        over_max = jnp.any(view.valid & (view.usage + req.amt > view.max))
        deny = frozen | throttled | over_max
        return Verdict(~deny, deny, jnp.float32(0.0), view.params)

    def on_over_high(self, view: ChainView, req: Request, over_frac,
                     protected) -> jax.Array:
        """Delay (ms, f32) to impose on the charged domain after a
        granted charge breached ``high`` — get_high_delay_ms.  ``view``
        carries POST-charge usage.  Default: no throttling."""
        return jnp.float32(0.0)

    def on_gate(self, view: ChainView, step) -> jax.Array:
        """May a slot in this domain advance this step?  Default: no
        frozen or throttled ancestor (cgroup.freeze + active delay)."""
        frozen = jnp.any(view.valid & view.frozen)
        throttled = jnp.any(view.valid & (view.throttle_until > step))
        return ~frozen & ~throttled

    def on_schedule(self, view: SchedView, req: SchedRequest) -> jax.Array:
        """Scheduling weight (f32) for one runnable slot.  A weight
        ``<= 0`` means "outside the weighted scheduler": the slot
        advances whenever the gate allows, without consuming the step
        budget — which is exactly the old binary ``slot_gate``
        behaviour.  The base program IS the trivial program."""
        return jnp.float32(0.0)

    # ------------------------------------------------- host-daemon helper

    def delay_ms(self, params, over_frac, priority=None, protected=False):
        """Scalar delay math on one param row — shared by ``on_over_high``
        and host daemons computing the same curve from telemetry."""
        return jnp.float32(0.0)


def _decision_one(prog: PolicyProgram, view: ChainView, req: Request):
    """The complete per-request decision for ONE program: contract +
    program verdict, then post-charge soft-limit math routed through
    ``on_over_high``.  ``charge_decision`` dispatches here — directly
    for a single attached program, via ``lax.switch`` per ``prog_id``
    for a multi-program registry."""
    v = prog.on_charge(view, req)
    add = jnp.where(v.grant, req.amt, 0)
    new_usage = jnp.where(view.valid, view.usage + add, 0)
    over = jnp.where(view.valid & (view.high < UNLIMITED),
                     new_usage - view.high, 0)
    protected = jnp.where(view.valid, new_usage <= view.low, True)
    over_frac = jnp.max(jnp.where(over > 0,
                                  over / jnp.maximum(view.high, 1), 0.0))
    post = view._replace(usage=new_usage)
    dly = prog.on_over_high(post, req, over_frac,
                            jnp.all(protected | (over <= 0)))
    dly = jnp.maximum(jnp.asarray(dly, jnp.float32), v.delay_ms)
    throttle = v.grant & ((over_frac > 0) | (v.delay_ms > 0))
    return v, dly, throttle


def _single_prog(progs: tuple):
    """Python-time registry dispatch: the registry length is a trace
    constant, so a one-entry registry compiles to exactly the old
    single-program decision (bit-identical traces)."""
    return progs[0] if len(progs) == 1 else None


def _decision_branch(prog: PolicyProgram):
    return lambda view, req: _decision_one(prog, view, req)


def charge_decision(prog, view: ChainView, req: Request):
    """The complete per-request decision, shared verbatim by every
    backend.  ``prog`` is one program or a registry tuple; with a
    registry, ``view.prog_id`` picks the branch via ``lax.switch`` —
    different tenants run truly different enforcement code in the same
    trace (out-of-range ids clamp to the primary slot 0).

    Returns ``(verdict, delay_ms, throttle)`` where ``throttle`` says
    whether a window must be imposed on the charged domain
    (``throttle_until = max(old, now + quantize(delay_ms))``).
    """
    progs = as_programs(prog)
    single = _single_prog(progs)
    if single is not None:
        return _decision_one(single, view, req)
    idx = jnp.clip(jnp.asarray(view.prog_id, jnp.int32),
                   0, len(progs) - 1)
    return jax.lax.switch(idx, tuple(_decision_branch(p) for p in progs),
                          view, req)


def _gate_branch(prog: PolicyProgram):
    return lambda view, step: prog.on_gate(view, step)


def gate_decision(prog, view: ChainView, step):
    """``on_gate`` with registry dispatch — single program calls the
    hook directly (bit-identical to the pre-registry trace); a
    multi-program registry switches on ``view.prog_id``."""
    progs = as_programs(prog)
    single = _single_prog(progs)
    if single is not None:
        return single.on_gate(view, step)
    idx = jnp.clip(jnp.asarray(view.prog_id, jnp.int32),
                   0, len(progs) - 1)
    return jax.lax.switch(idx, tuple(_gate_branch(p) for p in progs),
                          view, jnp.asarray(step))


def _sched_branch(prog: PolicyProgram):
    return lambda view, req: prog.on_schedule(view, req)


def schedule_weight(prog, view: SchedView, req: SchedRequest):
    """``on_schedule`` with registry dispatch (same shape as
    ``gate_decision``): the slot's effective scheduling weight under
    its domain's own program."""
    progs = as_programs(prog)
    single = _single_prog(progs)
    if single is not None:
        return single.on_schedule(view, req)
    idx = jnp.clip(jnp.asarray(view.prog_id, jnp.int32),
                   0, len(progs) - 1)
    return jax.lax.switch(idx, tuple(_sched_branch(p) for p in progs),
                          view, req)


def as_program(prog_or_cfg) -> PolicyProgram:
    """Normalize the enforcement argument: a program passes through, a
    ``ControllerConfig`` (or None) becomes the stock graduated-throttle
    program with matching scalars.  Registry tuples normalize to their
    primary (slot 0) program."""
    if isinstance(prog_or_cfg, (tuple, list)):
        return as_programs(prog_or_cfg)[0]
    if prog_or_cfg is None:
        return GraduatedThrottleProgram()
    if isinstance(prog_or_cfg, PolicyProgram):
        return prog_or_cfg
    return GraduatedThrottleProgram.from_config(prog_or_cfg)


def as_programs(prog_or_cfg) -> tuple:
    """Normalize the enforcement argument to a program registry: an
    ordered tuple of ``PolicyProgram``s, entry 0 the primary (root
    default).  Single programs/configs/None become a one-entry tuple;
    tuples/lists pass through element-normalized."""
    if isinstance(prog_or_cfg, (tuple, list)):
        progs = tuple(as_program(p) for p in prog_or_cfg)
        return progs if progs else (GraduatedThrottleProgram(),)
    return (as_program(prog_or_cfg),)


def check_registry(progs: tuple) -> tuple:
    """Validate a multi-program registry's trace constants: every
    program must agree on ``step_ms``/``sched_window``/``sched_lag``
    (they quantize the shared throttle clock and the shared scheduler
    window — per-slot values would desynchronize the one trace all
    slots share).  Returns the registry; raises ``ValueError``."""
    head = progs[0]
    for p in progs[1:]:
        for attr in ("step_ms", "sched_window", "sched_lag"):
            if getattr(p, attr) != getattr(head, attr):
                raise ValueError(
                    f"program registry disagrees on {attr}: "
                    f"{type(head).__name__}={getattr(head, attr)} vs "
                    f"{type(p).__name__}={getattr(p, attr)} — registry "
                    "trace constants come from the primary program")
    return progs


def registry_unknown_params(progs, kv) -> set:
    """Param names no registered program declares — the typo guard for
    ``update_params`` under a multi-program registry (a name known to
    ANY slot is writable; domains whose program lacks it are skipped)."""
    names = set(kv)
    for p in as_programs(progs):
        names -= set(p.param_names)
    return names


def registry_width(progs) -> int:
    """Shared param-table width for a registry: the widest program.
    Narrower programs never read past their own ``n_params``, and the
    zero padding is neutral for every stock program."""
    return max(p.n_params for p in as_programs(progs))


def pad_row(row: np.ndarray, width: int) -> np.ndarray:
    """Zero-pad one program row to the registry width (f32)."""
    row = np.asarray(row, np.float32)
    if row.shape[0] >= width:
        return row[:width]
    return np.concatenate([row, np.zeros((width - row.shape[0],),
                                         np.float32)])


# ----------------------------------------------------------- stock programs


class GraduatedThrottleProgram(PolicyProgram):
    """The paper's graduated allocator delay (§5): over-``high`` domains
    get ``min(max_delay, base_delay * (1 + gain * overage))`` ms, HIGH
    priority pays a discount, below-``low`` protection zeroes it.  All
    four knobs are per-domain table columns — retunable live."""

    param_names = ("base_delay_ms", "max_delay_ms", "overage_gain",
                   "high_priority_discount")

    def __init__(self, *, step_ms: float = 10.0,
                 base_delay_ms: float = BASE_DELAY_MS,
                 max_delay_ms: float = MAX_DELAY_MS,
                 overage_gain: float = OVERAGE_GAIN,
                 high_priority_discount: float = HIGH_PRIORITY_DISCOUNT):
        self.step_ms = step_ms
        self._defaults = (base_delay_ms, max_delay_ms, overage_gain,
                          high_priority_discount)

    @classmethod
    def from_config(cls, cfg) -> "GraduatedThrottleProgram":
        return cls(step_ms=cfg.step_ms, base_delay_ms=cfg.base_delay_ms,
                   max_delay_ms=cfg.max_delay_ms,
                   overage_gain=cfg.overage_gain,
                   high_priority_discount=cfg.high_priority_discount)

    def default_row(self) -> np.ndarray:
        return np.asarray(self._defaults, np.float32)

    def delay_ms(self, params, over_frac, priority=None, protected=False):
        d = jnp.minimum(params[1], params[0] * (1.0 + params[2] * over_frac))
        if priority is not None:
            d = jnp.where(priority == D.HIGH, d * params[3], d)
        return jnp.where(protected, 0.0, d)

    def on_over_high(self, view, req, over_frac, protected):
        return self.delay_ms(view.params, over_frac, view.priority, protected)


class TokenBucketProgram(GraduatedThrottleProgram):
    """Per-priority token-bucket admission on top of the graduated
    throttle: a domain with a configured bucket may only charge pages
    covered by accumulated tokens, refilled every step at a rate picked
    by the domain's priority.  This is *rate* control — pages per step —
    which the overage-delay curve cannot express (it only reacts to
    standing usage), the kind of scenario the pluggable surface exists
    for.  ``bucket_capacity == 0`` (the neutral row) disables the bucket
    for that domain; the memcg contract still applies everywhere.

    Mutable per-domain state (the bucket level, the last refill step)
    lives in the same param table the knobs do, written back through
    ``Verdict.params`` — a BPF map used as both config and scratch.
    """

    param_names = GraduatedThrottleProgram.param_names + (
        "bucket_level", "bucket_last_step", "bucket_capacity",
        "refill_low", "refill_normal", "refill_high")

    def __init__(self, *, bucket_capacity: float = 0.0,
                 refill: Sequence[float] = (1.0, 2.0, 4.0), **kw):
        super().__init__(**kw)
        self.bucket_capacity = float(bucket_capacity)
        self.refill = tuple(float(r) for r in refill)

    def default_row(self) -> np.ndarray:
        base = super().default_row()
        bucket = np.asarray(
            [self.bucket_capacity, 0.0, self.bucket_capacity] +
            list(self.refill), np.float32)
        return np.concatenate([base, bucket])

    # neutral_row: the base all-zeros row — outside the attach scope
    # BOTH the bucket (capacity 0) and the graduated delays are off

    def on_charge(self, view, req):
        base = super().on_charge(view, req)
        p = view.params
        cap = p[6]
        enabled = cap > 0
        dt = jnp.maximum(jnp.asarray(req.step, jnp.float32) - p[5], 0.0)
        refill = jnp.where(view.priority == D.HIGH, p[9],
                           jnp.where(view.priority == D.NORMAL, p[8], p[7]))
        level = jnp.minimum(cap, p[4] + dt * refill)
        have = level >= req.amt
        grant = base.grant & (~enabled | have)
        level = jnp.where(grant & enabled, level - req.amt, level)
        newp = p.at[4].set(level).at[5].set(jnp.asarray(req.step, jnp.float32))
        return Verdict(grant,
                       base.stall | (base.grant & enabled & ~have),
                       base.delay_ms,
                       jnp.where(enabled, newp, p))

"""Device-resident domain state + in-step enforcement (the eBPF analogue).

The paper's responsiveness fix is to run control logic *at the kernel
enforcement point* (memcg_bpf_ops / sched_ext) instead of in a
user-space daemon.  The TPU-pod analogue: enforcement decisions are
computed *inside the jitted engine step* from device-resident domain
state (``jax.lax`` ops only), so a burst is throttled in the same step
it occurs — no host round trip.  The host-side daemon (serving engine /
``policy.py``) only manages lifecycle (create/freeze/thaw/remove) via
the shared state arrays, exactly like the paper's "lightweight
user-space daemon managing cgroup lifecycle via shared BPF maps".

The decision logic itself is NOT in this file: ``charge_batch`` and
``slot_gate`` are thin kernels that build a per-request ``ChainView``
and dispatch into the attached ``PolicyProgram`` (``core/progs.py``) —
the memcg_bpf_ops analogue.  The program's parameter table rides in the
state pytree under ``"prog"``, so retuning a live policy is a state
update (no retrace); attaching a different program swaps the traced
code (a recompile, like loading a new BPF object).

State layout (fixed capacity ``n``; index 0 is the root):
  usage/high/max/low : i32 pages          parent : i32 (-1 for root)
  priority           : i32 (0/1/2)        frozen : bool
  throttle_until     : i32 engine step    peak   : i32
  prog               : f32 (n, P) program parameter table

``charge_batch`` serializes grants within a step via ``lax.scan`` —
the same serialization the memcg page-counter hierarchy applies — so
results are deterministic and order-faithful.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as D
from repro.core.pressure import charge_stall_event, saturating_count
from repro.core.progs import (ChainView, PolicyProgram, Request, as_program,
                              as_programs, charge_decision, check_registry,
                              gate_decision, pad_row, path_in_scope,
                              registry_unknown_params, registry_width)

UNLIMITED = D.UNLIMITED
DEPTH = 4          # root / tenant / session / tool-call


@dataclass(frozen=True)
class ControllerConfig:
    """Scalar knobs for the stock graduated-throttle program.  The
    defaults single-source from ``domains`` — the same constants the
    host tree's reference ``throttle_delay_ms`` uses."""
    step_ms: float = 10.0             # engine-step duration the delays quantize to
    base_delay_ms: float = D.BASE_DELAY_MS
    max_delay_ms: float = D.MAX_DELAY_MS
    high_priority_discount: float = D.HIGH_PRIORITY_DISCOUNT
    overage_gain: float = D.OVERAGE_GAIN


def new_state(capacity_pages: int, n_domains: int = 64,
              prog: Optional[PolicyProgram] = None) -> dict:
    """Fresh device state with only the root (index 0) configured.
    ``prog`` may be a registry tuple: the param table is sized to the
    widest program and every domain starts on the primary (slot 0)."""
    progs = as_programs(prog)
    width = registry_width(progs)
    n = n_domains
    st = {
        "usage": jnp.zeros((n,), jnp.int32),
        "high": jnp.full((n,), UNLIMITED, jnp.int32),
        "max": jnp.full((n,), UNLIMITED, jnp.int32),
        "low": jnp.zeros((n,), jnp.int32),
        "parent": jnp.full((n,), -1, jnp.int32),
        "priority": jnp.full((n,), D.NORMAL, jnp.int32),
        "frozen": jnp.zeros((n,), bool),
        "active": jnp.zeros((n,), bool),
        "throttle_until": jnp.zeros((n,), jnp.int32),
        "peak": jnp.zeros((n,), jnp.int32),
        "prog": jnp.broadcast_to(
            jnp.asarray(pad_row(progs[0].default_row(), width)),
            (n, width)),
        "prog_id": jnp.zeros((n,), jnp.int32),
        # CPU scheduling rows (cpu.weight / cpu.max, core/sched.py)
        "weight": jnp.full((n,), D.DEFAULT_WEIGHT, jnp.int32),
        "cpu_max": jnp.full((n,), UNLIMITED, jnp.int32),
        "flat_weight": jnp.zeros((n,), jnp.float32),
        "vruntime": jnp.zeros((n,), jnp.float32),
        "cpu_used": jnp.zeros((n,), jnp.int32),
        "cpu_stamp": jnp.full((n,), -1, jnp.int32),
        # PSI-style stall-event counters (core/pressure.py): local to
        # each domain, aggregated up the hierarchy host-side at read
        "mem_stall": jnp.zeros((n,), jnp.int32),
        "cpu_stall": jnp.zeros((n,), jnp.int32),
    }
    st["max"] = st["max"].at[0].set(capacity_pages)
    st["high"] = st["high"].at[0].set(capacity_pages)
    st["active"] = st["active"].at[0].set(True)
    st["flat_weight"] = st["flat_weight"].at[0].set(1.0)
    return st


def _ancestor_chain(parent, idx):
    """(DEPTH,) ancestor indices of ``idx`` (self first), -1-padded."""
    chain = [idx]
    for _ in range(DEPTH - 1):
        prev = chain[-1]
        nxt = jnp.where(prev >= 0, parent[jnp.maximum(prev, 0)], -1)
        chain.append(nxt)
    return jnp.stack(chain)


def _chain_view(state, usage, throttle_until, params, d) -> ChainView:
    """Masked ancestor-chain view for one request (invalid entries are
    neutral: usage 0, limits UNLIMITED, not frozen, no throttle)."""
    chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
    valid = (chain >= 0) & (d >= 0)
    cidx = jnp.maximum(chain, 0)
    di = jnp.maximum(d, 0)
    return ChainView(
        valid=valid,
        usage=jnp.where(valid, usage[cidx], 0),
        high=jnp.where(valid, state["high"][cidx], UNLIMITED),
        max=jnp.where(valid, state["max"][cidx], UNLIMITED),
        low=jnp.where(valid, state["low"][cidx], 0),
        frozen=jnp.where(valid, state["frozen"][cidx], False),
        throttle_until=jnp.where(valid, throttle_until[cidx], 0),
        priority=state["priority"][di],
        params=params[di],
        prog_id=state["prog_id"][di],
    )


def charge_batch(state: dict, dom: jax.Array, amt: jax.Array, step,
                 prog=None):
    """Hierarchically charge ``amt[i]`` pages to domain ``dom[i]``,
    dispatching every decision into the attached ``PolicyProgram``
    (``prog`` also accepts a ``ControllerConfig`` for the stock
    graduated program, or None for defaults).

    Returns (new_state, granted (m,) bool, stalled (m,) bool).
    ``stalled`` marks requests denied *because of throttle/freeze* (they
    retry next step); hard-``max`` denials also stall (the engine's
    graceful-degradation path never OOM-kills from inside the step).
    Zero-amount requests are gated only by freeze/throttle (a decode
    step that does not cross a page boundary allocates nothing but must
    still respect cgroup.freeze).

    On TPU (or under ``REPRO_FORCE_PALLAS_INTERPRET=1``) the whole
    batch runs in the fused Pallas enforcement kernel
    (``kernels/enforcement.py``) — one pass over the control-state
    table, ancestor walk resident in VMEM; the lax path below is the
    CPU/interpret fallback and the kernel's conformance reference.
    """
    progs = as_programs(prog)
    fused = _fused_charge_or_none()
    if fused is not None:
        return fused(state, dom.astype(jnp.int32), amt.astype(jnp.int32),
                     step, progs)
    return _lax_charge_batch(state, dom, amt, step, progs)


def _lax_charge_batch(state: dict, dom: jax.Array, amt: jax.Array, step,
                      progs):
    """The lax.scan reference body of ``charge_batch`` — callable
    directly (bypassing the fused dispatch) so the roofline and the
    overhead benchmark can compile both paths side by side."""
    def one(carry, req):
        usage, peak, throttle_until, params, mem_stall = carry
        d, a = req
        view = _chain_view(state, usage, throttle_until, params, d)
        verdict, delay_ms, throttle = charge_decision(
            progs, view, Request(d, a, step))
        grant = (d >= 0) & verdict.grant
        stalled = (d >= 0) & verdict.stall

        chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
        cvalid = (chain >= 0) & (d >= 0)
        cidx = jnp.maximum(chain, 0)
        add = jnp.where(cvalid & grant, a, 0)
        usage = usage.at[cidx].add(add)
        peak = jnp.maximum(peak, usage)

        di = jnp.maximum(d, 0)
        dly = jnp.ceil(delay_ms / progs[0].step_ms).astype(jnp.int32)
        tu = jnp.where(throttle & (d >= 0),
                       jnp.maximum(throttle_until[di], step + dly),
                       throttle_until[di])
        throttle_until = throttle_until.at[di].set(
            jnp.where(d >= 0, tu, throttle_until[di]))
        params = params.at[di].set(
            jnp.where(d >= 0, verdict.params, params[di]))
        # PSI accounting: a stalled or throttled decision is one
        # memory-stall event on the charged domain (core/pressure.py),
        # saturating at INT32_MAX instead of wrapping negative
        mem_stall = mem_stall.at[di].set(saturating_count(
            mem_stall[di],
            jnp.where(d >= 0,
                      charge_stall_event(stalled, (d >= 0) & throttle), 0)))
        return (usage, peak, throttle_until, params, mem_stall), \
            (grant, stalled)

    (usage, peak, throttle_until, params, mem_stall), (granted, stalled) = \
        jax.lax.scan(
            one, (state["usage"], state["peak"], state["throttle_until"],
                  state["prog"], state["mem_stall"]),
            (dom.astype(jnp.int32), amt.astype(jnp.int32)))
    new_state = dict(state, usage=usage, peak=peak,
                     throttle_until=throttle_until, prog=params,
                     mem_stall=mem_stall)
    return new_state, granted, stalled


def host_charge(state: dict, idx: int, amt: int) -> dict:
    """Unconditional hierarchical charge for host-side lifecycle moves
    (residual transfer on tool-domain close, thaw re-charge).  Never
    denied — the pages are already resident; this is bookkeeping."""
    usage = np.asarray(state["usage"]).copy()
    parent = np.asarray(state["parent"])
    i = idx
    for _ in range(DEPTH):
        if i < 0:
            break
        usage[i] = max(0, usage[i] + amt)
        i = int(parent[i])
    return dict(state, usage=jnp.asarray(usage),
                peak=jnp.maximum(state["peak"], jnp.asarray(usage)))


def uncharge_batch(state: dict, dom: jax.Array, amt: jax.Array):
    """Release pages (always succeeds); vectorized scatter over chains."""
    chain = jax.vmap(lambda d: _ancestor_chain(state["parent"],
                                               jnp.maximum(d, 0)))(dom)
    valid = (chain >= 0) & (dom >= 0)[:, None]
    sub = jnp.where(valid, amt[:, None], 0)
    usage = state["usage"].at[jnp.maximum(chain, 0).reshape(-1)].add(
        -sub.reshape(-1))
    return dict(state, usage=jnp.maximum(usage, 0))


def slot_gate(state: dict, slot_dom: jax.Array, step, prog=None) -> jax.Array:
    """May each slot advance this step?  Dispatches ``on_gate`` of the
    slot's domain program (default: no frozen/throttled ancestor).  On
    TPU / forced interpret the fused Pallas gate kernel takes the same
    decision in one pass (``kernels/enforcement.py``)."""
    progs = as_programs(prog)
    fused = _fused_gate_or_none()
    if fused is not None:
        return fused(state, slot_dom.astype(jnp.int32), step, progs)
    return _lax_slot_gate(state, slot_dom, step, progs)


def _lax_slot_gate(state: dict, slot_dom: jax.Array, step, progs):
    """The vmapped reference body of ``slot_gate`` (see
    ``_lax_charge_batch``)."""
    def one(d):
        view = _chain_view(state, state["usage"], state["throttle_until"],
                           state["prog"], d)
        return (d >= 0) & gate_decision(progs, view, step)
    return jax.vmap(one)(slot_dom.astype(jnp.int32))


def _fused_charge_or_none():
    """Resolve the fused Pallas charge kernel, or None for the lax
    fallback — python-time dispatch (a trace constant), mirroring
    ``kernels/ops._resolve``: Pallas on real TPUs or under the
    ``REPRO_FORCE_PALLAS_INTERPRET=1`` conformance override."""
    from repro import compat
    if not (compat.on_tpu() or compat.force_interpret()):
        return None
    from repro.kernels.enforcement import fused_charge_batch
    return fused_charge_batch


def _fused_gate_or_none():
    from repro import compat
    if not (compat.on_tpu() or compat.force_interpret()):
        return None
    from repro.kernels.enforcement import fused_slot_gate
    return fused_slot_gate


# -------------------------------------------------------------- host mirror


class DeviceDomainTable:
    """Host-side index allocator + lifecycle editor for the device state.

    This is the paper's 'lightweight user-space daemon': it creates and
    removes domains, configures limits, freezes/thaws, attaches and
    retunes the policy program — but the per-allocation enforcement runs
    on device inside the jitted step.
    """

    def __init__(self, capacity_pages: int, n_domains: int = 64,
                 cfg: ControllerConfig = ControllerConfig(),
                 prog: Optional[PolicyProgram] = None):
        self.cfg = cfg
        self.n = n_domains
        self.progs = as_programs(prog if prog is not None else cfg)
        self.scopes = ["/"] * len(self.progs)
        self.state = new_state(capacity_pages, n_domains, self.progs)
        self.index: dict[str, int] = {"/": 0}
        self._free = list(range(1, n_domains))   # heap: lowest index first

    # ------------------------------------------------------------ programs

    @property
    def prog(self) -> PolicyProgram:
        """The primary (slot 0) program — the registry's trace constants
        (``step_ms`` etc.) and the single-program compatibility surface."""
        return self.progs[0]

    @property
    def attach_scope(self) -> str:
        return self.scopes[0]

    def in_scope(self, path: str) -> bool:
        return path_in_scope(self.attach_scope, path)

    def attach(self, scope: str, prog: PolicyProgram) -> None:
        """Attach ``prog`` to the subtree at ``scope`` (a recompile for
        jitted consumers — like loading a new BPF object).  A root
        attach resets the registry to this single program, every domain
        on its default row (the pre-registry semantics, bit-identical).
        A subtree attach COMPOSES: the program takes a registry slot
        (replacing a previous attach at the same scope), domains inside
        ``scope`` move to it on its default row, and domains outside
        keep their current program and live rows — different tenants
        run truly different enforcement code."""
        prog = as_program(prog)
        if scope == "/":
            self.progs = (prog,)
            self.scopes = ["/"]
            rows = np.broadcast_to(prog.default_row(),
                                   (self.n, prog.n_params)).copy()
            self.state = dict(self.state, prog=jnp.asarray(rows),
                              prog_id=jnp.zeros((self.n,), jnp.int32))
            return
        if scope in self.scopes:
            k = self.scopes.index(scope)
            self.progs = self.progs[:k] + (prog,) + self.progs[k + 1:]
        else:
            k = len(self.progs)
            self.progs = self.progs + (prog,)
            self.scopes.append(scope)
        check_registry(self.progs)
        width = registry_width(self.progs)
        old = np.asarray(self.state["prog"])
        rows = np.zeros((self.n, width), np.float32)
        keep = min(width, old.shape[1])
        rows[:, :keep] = old[:, :keep]
        ids = np.asarray(self.state["prog_id"]).copy()
        for path, idx in self.index.items():
            if path_in_scope(scope, path):
                ids[idx] = k
                rows[idx] = pad_row(prog.default_row(), width)
        self.state = dict(self.state, prog=jnp.asarray(rows),
                          prog_id=jnp.asarray(ids))

    def update_params(self, paths: list, kv: dict) -> None:
        """Retune the live program for the given domains — a pure state
        write, never a retrace.  Each domain resolves column names
        through its OWN program (its ``prog_id`` slot); names unknown
        to every registered program raise ``KeyError``."""
        unknown = registry_unknown_params(self.progs, kv)
        if unknown:
            raise KeyError(
                f"no registered program has param(s) {sorted(unknown)}; "
                f"knobs: {sorted(set().union(*(p.param_names for p in self.progs)))}")
        ids = np.asarray(self.state["prog_id"])
        prog = self.state["prog"]
        for p in paths:
            idx = self.index[p]
            pr = self.progs[int(ids[idx])]
            for k, v in kv.items():
                if k in pr.param_names:
                    prog = prog.at[idx, pr.col(k)].set(float(v))
        self.state = dict(self.state, prog=prog)

    def _fresh_row(self, path: str, pidx: int) -> np.ndarray:
        """New domains inherit their parent's live row (cgroup settings
        propagate down) — and, with ``_fresh_prog_id``, the parent's
        program slot: a child created after a subtree attach runs the
        subtree's program, not the root default."""
        return np.asarray(self.state["prog"][pidx])

    def _fresh_prog_id(self, pidx: int) -> int:
        return int(self.state["prog_id"][pidx])

    # ------------------------------------------------------------ lifecycle

    def create(self, path: str, *, high: int = UNLIMITED, max: int = UNLIMITED,
               low: int = 0, priority: int = D.NORMAL,
               weight: int = D.DEFAULT_WEIGHT,
               cpu_max: int = UNLIMITED) -> int:
        assert path not in self.index, path
        parent_path = path.rsplit("/", 1)[0] or "/"
        pidx = self.index[parent_path]
        idx = heapq.heappop(self._free)
        self.index[path] = idx
        st = self.state
        self.state = dict(
            st,
            high=st["high"].at[idx].set(high),
            max=st["max"].at[idx].set(max),
            low=st["low"].at[idx].set(low),
            parent=st["parent"].at[idx].set(pidx),
            priority=st["priority"].at[idx].set(priority),
            usage=st["usage"].at[idx].set(0),
            peak=st["peak"].at[idx].set(0),
            frozen=st["frozen"].at[idx].set(False),
            active=st["active"].at[idx].set(True),
            throttle_until=st["throttle_until"].at[idx].set(0),
            prog=st["prog"].at[idx].set(
                jnp.asarray(self._fresh_row(path, pidx))),
            prog_id=st["prog_id"].at[idx].set(self._fresh_prog_id(pidx)),
            weight=st["weight"].at[idx].set(weight),
            cpu_max=st["cpu_max"].at[idx].set(cpu_max),
            flat_weight=st["flat_weight"].at[idx].set(0.0),
            vruntime=st["vruntime"].at[idx].set(0.0),
            cpu_used=st["cpu_used"].at[idx].set(0),
            cpu_stamp=st["cpu_stamp"].at[idx].set(-1),
            mem_stall=st["mem_stall"].at[idx].set(0),
            cpu_stall=st["cpu_stall"].at[idx].set(0),
        )
        return idx

    def remove(self, path: str) -> None:
        idx = self.index.pop(path)
        residual = int(self.state["usage"][idx])
        if residual:
            # release residual charges up the chain (host-side lifecycle op)
            self.state = uncharge_batch(self.state,
                                        jnp.array([idx], jnp.int32),
                                        jnp.array([residual], jnp.int32))
        st = self.state
        self.state = dict(st, active=st["active"].at[idx].set(False),
                          frozen=st["frozen"].at[idx].set(False),
                          parent=st["parent"].at[idx].set(-1),
                          weight=st["weight"].at[idx].set(D.DEFAULT_WEIGHT),
                          cpu_max=st["cpu_max"].at[idx].set(UNLIMITED),
                          flat_weight=st["flat_weight"].at[idx].set(0.0),
                          vruntime=st["vruntime"].at[idx].set(0.0),
                          cpu_used=st["cpu_used"].at[idx].set(0),
                          cpu_stamp=st["cpu_stamp"].at[idx].set(-1),
                          mem_stall=st["mem_stall"].at[idx].set(0),
                          cpu_stall=st["cpu_stall"].at[idx].set(0),
                          prog_id=st["prog_id"].at[idx].set(0))
        heapq.heappush(self._free, idx)

    def set_frozen(self, path: str, flag: bool) -> None:
        idx = self.index[path]
        st = self.state
        self.state = dict(st, frozen=st["frozen"].at[idx].set(flag))

    def usage(self, path: str) -> int:
        return int(self.state["usage"][self.index[path]])

    def peak(self, path: str) -> int:
        return int(self.state["peak"][self.index[path]])

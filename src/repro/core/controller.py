"""Device-resident domain state + in-step enforcement (the eBPF analogue).

The paper's responsiveness fix is to run control logic *at the kernel
enforcement point* (memcg_bpf_ops / sched_ext) instead of in a
user-space daemon.  The TPU-pod analogue: enforcement decisions are
computed *inside the jitted engine step* from device-resident domain
state (``jax.lax`` ops only), so a burst is throttled in the same step
it occurs — no host round trip.  The host-side daemon (serving engine /
``policy.py``) only manages lifecycle (create/freeze/thaw/remove) via
the shared state arrays, exactly like the paper's "lightweight
user-space daemon managing cgroup lifecycle via shared BPF maps".

The decision logic itself is NOT in this file: ``charge_batch`` and
``slot_gate`` are thin kernels that build a per-request ``ChainView``
and dispatch into the attached ``PolicyProgram`` (``core/progs.py``) —
the memcg_bpf_ops analogue.  The program's parameter table rides in the
state pytree under ``"prog"``, so retuning a live policy is a state
update (no retrace); attaching a different program swaps the traced
code (a recompile, like loading a new BPF object).

State layout (fixed capacity ``n``; index 0 is the root):
  usage/high/max/low : i32 pages          parent : i32 (-1 for root)
  priority           : i32 (0/1/2)        frozen : bool
  throttle_until     : i32 engine step    peak   : i32
  prog               : f32 (n, P) program parameter table

``charge_batch`` serializes grants within a step via ``lax.scan`` —
the same serialization the memcg page-counter hierarchy applies — so
results are deterministic and order-faithful.
"""
from __future__ import annotations

import dataclasses
import heapq
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as D
from repro.core.pressure import charge_stall_event
from repro.core.progs import (ChainView, PolicyProgram, Request, as_program,
                              charge_decision, path_in_scope)

UNLIMITED = D.UNLIMITED
DEPTH = 4          # root / tenant / session / tool-call


@dataclass(frozen=True)
class ControllerConfig:
    """Scalar knobs for the stock graduated-throttle program.  The
    defaults single-source from ``domains`` — the same constants the
    host tree's reference ``throttle_delay_ms`` uses."""
    step_ms: float = 10.0             # engine-step duration the delays quantize to
    base_delay_ms: float = D.BASE_DELAY_MS
    max_delay_ms: float = D.MAX_DELAY_MS
    high_priority_discount: float = D.HIGH_PRIORITY_DISCOUNT
    overage_gain: float = D.OVERAGE_GAIN


def new_state(capacity_pages: int, n_domains: int = 64,
              prog: Optional[PolicyProgram] = None) -> dict:
    """Fresh device state with only the root (index 0) configured."""
    prog = as_program(prog)
    n = n_domains
    st = {
        "usage": jnp.zeros((n,), jnp.int32),
        "high": jnp.full((n,), UNLIMITED, jnp.int32),
        "max": jnp.full((n,), UNLIMITED, jnp.int32),
        "low": jnp.zeros((n,), jnp.int32),
        "parent": jnp.full((n,), -1, jnp.int32),
        "priority": jnp.full((n,), D.NORMAL, jnp.int32),
        "frozen": jnp.zeros((n,), bool),
        "active": jnp.zeros((n,), bool),
        "throttle_until": jnp.zeros((n,), jnp.int32),
        "peak": jnp.zeros((n,), jnp.int32),
        "prog": prog.init_params(n),
        # CPU scheduling rows (cpu.weight / cpu.max, core/sched.py)
        "weight": jnp.full((n,), D.DEFAULT_WEIGHT, jnp.int32),
        "cpu_max": jnp.full((n,), UNLIMITED, jnp.int32),
        "flat_weight": jnp.zeros((n,), jnp.float32),
        "vruntime": jnp.zeros((n,), jnp.float32),
        "cpu_used": jnp.zeros((n,), jnp.int32),
        "cpu_stamp": jnp.full((n,), -1, jnp.int32),
        # PSI-style stall-event counters (core/pressure.py): local to
        # each domain, aggregated up the hierarchy host-side at read
        "mem_stall": jnp.zeros((n,), jnp.int32),
        "cpu_stall": jnp.zeros((n,), jnp.int32),
    }
    st["max"] = st["max"].at[0].set(capacity_pages)
    st["high"] = st["high"].at[0].set(capacity_pages)
    st["active"] = st["active"].at[0].set(True)
    st["flat_weight"] = st["flat_weight"].at[0].set(1.0)
    return st


def _ancestor_chain(parent, idx):
    """(DEPTH,) ancestor indices of ``idx`` (self first), -1-padded."""
    chain = [idx]
    for _ in range(DEPTH - 1):
        prev = chain[-1]
        nxt = jnp.where(prev >= 0, parent[jnp.maximum(prev, 0)], -1)
        chain.append(nxt)
    return jnp.stack(chain)


def _chain_view(state, usage, throttle_until, params, d) -> ChainView:
    """Masked ancestor-chain view for one request (invalid entries are
    neutral: usage 0, limits UNLIMITED, not frozen, no throttle)."""
    chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
    valid = (chain >= 0) & (d >= 0)
    cidx = jnp.maximum(chain, 0)
    di = jnp.maximum(d, 0)
    return ChainView(
        valid=valid,
        usage=jnp.where(valid, usage[cidx], 0),
        high=jnp.where(valid, state["high"][cidx], UNLIMITED),
        max=jnp.where(valid, state["max"][cidx], UNLIMITED),
        low=jnp.where(valid, state["low"][cidx], 0),
        frozen=jnp.where(valid, state["frozen"][cidx], False),
        throttle_until=jnp.where(valid, throttle_until[cidx], 0),
        priority=state["priority"][di],
        params=params[di],
    )


def charge_batch(state: dict, dom: jax.Array, amt: jax.Array, step,
                 prog=None):
    """Hierarchically charge ``amt[i]`` pages to domain ``dom[i]``,
    dispatching every decision into the attached ``PolicyProgram``
    (``prog`` also accepts a ``ControllerConfig`` for the stock
    graduated program, or None for defaults).

    Returns (new_state, granted (m,) bool, stalled (m,) bool).
    ``stalled`` marks requests denied *because of throttle/freeze* (they
    retry next step); hard-``max`` denials also stall (the engine's
    graceful-degradation path never OOM-kills from inside the step).
    Zero-amount requests are gated only by freeze/throttle (a decode
    step that does not cross a page boundary allocates nothing but must
    still respect cgroup.freeze).
    """
    prog = as_program(prog)

    def one(carry, req):
        usage, peak, throttle_until, params, mem_stall = carry
        d, a = req
        view = _chain_view(state, usage, throttle_until, params, d)
        verdict, delay_ms, throttle = charge_decision(
            prog, view, Request(d, a, step))
        grant = (d >= 0) & verdict.grant
        stalled = (d >= 0) & verdict.stall

        chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
        cvalid = (chain >= 0) & (d >= 0)
        cidx = jnp.maximum(chain, 0)
        add = jnp.where(cvalid & grant, a, 0)
        usage = usage.at[cidx].add(add)
        peak = jnp.maximum(peak, usage)

        di = jnp.maximum(d, 0)
        dly = jnp.ceil(delay_ms / prog.step_ms).astype(jnp.int32)
        tu = jnp.where(throttle & (d >= 0),
                       jnp.maximum(throttle_until[di], step + dly),
                       throttle_until[di])
        throttle_until = throttle_until.at[di].set(
            jnp.where(d >= 0, tu, throttle_until[di]))
        params = params.at[di].set(
            jnp.where(d >= 0, verdict.params, params[di]))
        # PSI accounting: a stalled or throttled decision is one
        # memory-stall event on the charged domain (core/pressure.py)
        mem_stall = mem_stall.at[di].add(
            jnp.where(d >= 0,
                      charge_stall_event(stalled, (d >= 0) & throttle), 0))
        return (usage, peak, throttle_until, params, mem_stall), \
            (grant, stalled)

    (usage, peak, throttle_until, params, mem_stall), (granted, stalled) = \
        jax.lax.scan(
            one, (state["usage"], state["peak"], state["throttle_until"],
                  state["prog"], state["mem_stall"]),
            (dom.astype(jnp.int32), amt.astype(jnp.int32)))
    new_state = dict(state, usage=usage, peak=peak,
                     throttle_until=throttle_until, prog=params,
                     mem_stall=mem_stall)
    return new_state, granted, stalled


def host_charge(state: dict, idx: int, amt: int) -> dict:
    """Unconditional hierarchical charge for host-side lifecycle moves
    (residual transfer on tool-domain close, thaw re-charge).  Never
    denied — the pages are already resident; this is bookkeeping."""
    usage = np.asarray(state["usage"]).copy()
    parent = np.asarray(state["parent"])
    i = idx
    for _ in range(DEPTH):
        if i < 0:
            break
        usage[i] = max(0, usage[i] + amt)
        i = int(parent[i])
    return dict(state, usage=jnp.asarray(usage),
                peak=jnp.maximum(state["peak"], jnp.asarray(usage)))


def uncharge_batch(state: dict, dom: jax.Array, amt: jax.Array):
    """Release pages (always succeeds); vectorized scatter over chains."""
    chain = jax.vmap(lambda d: _ancestor_chain(state["parent"],
                                               jnp.maximum(d, 0)))(dom)
    valid = (chain >= 0) & (dom >= 0)[:, None]
    sub = jnp.where(valid, amt[:, None], 0)
    usage = state["usage"].at[jnp.maximum(chain, 0).reshape(-1)].add(
        -sub.reshape(-1))
    return dict(state, usage=jnp.maximum(usage, 0))


def slot_gate(state: dict, slot_dom: jax.Array, step, prog=None) -> jax.Array:
    """May each slot advance this step?  Dispatches ``on_gate`` of the
    attached program (default: no frozen/throttled ancestor)."""
    prog = as_program(prog)

    def one(d):
        view = _chain_view(state, state["usage"], state["throttle_until"],
                           state["prog"], d)
        return (d >= 0) & prog.on_gate(view, step)
    return jax.vmap(one)(slot_dom.astype(jnp.int32))


# -------------------------------------------------------------- host mirror


class DeviceDomainTable:
    """Host-side index allocator + lifecycle editor for the device state.

    This is the paper's 'lightweight user-space daemon': it creates and
    removes domains, configures limits, freezes/thaws, attaches and
    retunes the policy program — but the per-allocation enforcement runs
    on device inside the jitted step.
    """

    def __init__(self, capacity_pages: int, n_domains: int = 64,
                 cfg: ControllerConfig = ControllerConfig(),
                 prog: Optional[PolicyProgram] = None):
        self.cfg = cfg
        self.n = n_domains
        self.prog = prog if prog is not None else as_program(cfg)
        self.attach_scope = "/"
        self.state = new_state(capacity_pages, n_domains, self.prog)
        self.index: dict[str, int] = {"/": 0}
        self._free = list(range(1, n_domains))   # heap: lowest index first

    # ------------------------------------------------------------ programs

    def in_scope(self, path: str) -> bool:
        return path_in_scope(self.attach_scope, path)

    def attach(self, scope: str, prog: PolicyProgram) -> None:
        """Swap the enforcement program (a recompile for jitted consumers
        — like loading a new BPF object).  Domains inside ``scope`` get
        the program's default row; domains outside get the neutral row
        (the contract still applies everywhere)."""
        self.prog = prog
        self.attach_scope = scope
        rows = np.broadcast_to(prog.neutral_row(),
                               (self.n, prog.n_params)).copy()
        for path, idx in self.index.items():
            if self.in_scope(path):
                rows[idx] = prog.default_row()
        self.state = dict(self.state, prog=jnp.asarray(rows))

    def update_params(self, paths: list, kv: dict) -> None:
        """Retune the live program for the given domains — a pure state
        write, never a retrace."""
        cols = {self.prog.col(k): float(v) for k, v in kv.items()}
        idxs = jnp.asarray([self.index[p] for p in paths], jnp.int32)
        prog = self.state["prog"]
        for c, v in cols.items():
            prog = prog.at[idxs, c].set(v)
        self.state = dict(self.state, prog=prog)

    def _fresh_row(self, path: str, pidx: int) -> np.ndarray:
        """New domains inherit their parent's live row (cgroup settings
        propagate down) when both sit in the attach scope."""
        if not self.in_scope(path):
            return self.prog.neutral_row()
        parent_path = path.rsplit("/", 1)[0] or "/"
        if self.in_scope(parent_path):
            return np.asarray(self.state["prog"][pidx])
        return self.prog.default_row()

    # ------------------------------------------------------------ lifecycle

    def create(self, path: str, *, high: int = UNLIMITED, max: int = UNLIMITED,
               low: int = 0, priority: int = D.NORMAL,
               weight: int = D.DEFAULT_WEIGHT,
               cpu_max: int = UNLIMITED) -> int:
        assert path not in self.index, path
        parent_path = path.rsplit("/", 1)[0] or "/"
        pidx = self.index[parent_path]
        idx = heapq.heappop(self._free)
        self.index[path] = idx
        st = self.state
        self.state = dict(
            st,
            high=st["high"].at[idx].set(high),
            max=st["max"].at[idx].set(max),
            low=st["low"].at[idx].set(low),
            parent=st["parent"].at[idx].set(pidx),
            priority=st["priority"].at[idx].set(priority),
            usage=st["usage"].at[idx].set(0),
            peak=st["peak"].at[idx].set(0),
            frozen=st["frozen"].at[idx].set(False),
            active=st["active"].at[idx].set(True),
            throttle_until=st["throttle_until"].at[idx].set(0),
            prog=st["prog"].at[idx].set(
                jnp.asarray(self._fresh_row(path, pidx))),
            weight=st["weight"].at[idx].set(weight),
            cpu_max=st["cpu_max"].at[idx].set(cpu_max),
            flat_weight=st["flat_weight"].at[idx].set(0.0),
            vruntime=st["vruntime"].at[idx].set(0.0),
            cpu_used=st["cpu_used"].at[idx].set(0),
            cpu_stamp=st["cpu_stamp"].at[idx].set(-1),
            mem_stall=st["mem_stall"].at[idx].set(0),
            cpu_stall=st["cpu_stall"].at[idx].set(0),
        )
        return idx

    def remove(self, path: str) -> None:
        idx = self.index.pop(path)
        residual = int(self.state["usage"][idx])
        if residual:
            # release residual charges up the chain (host-side lifecycle op)
            self.state = uncharge_batch(self.state,
                                        jnp.array([idx], jnp.int32),
                                        jnp.array([residual], jnp.int32))
        st = self.state
        self.state = dict(st, active=st["active"].at[idx].set(False),
                          frozen=st["frozen"].at[idx].set(False),
                          parent=st["parent"].at[idx].set(-1),
                          weight=st["weight"].at[idx].set(D.DEFAULT_WEIGHT),
                          cpu_max=st["cpu_max"].at[idx].set(UNLIMITED),
                          flat_weight=st["flat_weight"].at[idx].set(0.0),
                          vruntime=st["vruntime"].at[idx].set(0.0),
                          cpu_used=st["cpu_used"].at[idx].set(0),
                          cpu_stamp=st["cpu_stamp"].at[idx].set(-1),
                          mem_stall=st["mem_stall"].at[idx].set(0),
                          cpu_stall=st["cpu_stall"].at[idx].set(0))
        heapq.heappush(self._free, idx)

    def set_frozen(self, path: str, flag: bool) -> None:
        idx = self.index[path]
        st = self.state
        self.state = dict(st, frozen=st["frozen"].at[idx].set(flag))

    def usage(self, path: str) -> int:
        return int(self.state["usage"][self.index[path]])

    def peak(self, path: str) -> int:
        return int(self.state["peak"][self.index[path]])

"""Device-resident domain state + in-step enforcement (the eBPF analogue).

The paper's responsiveness fix is to run control logic *at the kernel
enforcement point* (memcg_bpf_ops / sched_ext) instead of in a
user-space daemon.  The TPU-pod analogue: enforcement decisions are
computed *inside the jitted engine step* from device-resident domain
state (``jax.lax`` ops only), so a burst is throttled in the same step
it occurs — no host round trip.  The host-side daemon (serving engine /
``policy.py``) only manages lifecycle (create/freeze/thaw/remove) via
the shared state arrays, exactly like the paper's "lightweight
user-space daemon managing cgroup lifecycle via shared BPF maps".

State layout (fixed capacity ``n``; index 0 is the root):
  usage/high/max/low : i32 pages          parent : i32 (-1 for root)
  priority           : i32 (0/1/2)        frozen : bool
  throttle_until     : i32 engine step    peak   : i32

``charge_batch`` serializes grants within a step via ``lax.scan`` —
the same serialization the memcg page-counter hierarchy applies — so
results are deterministic and order-faithful.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as D

UNLIMITED = D.UNLIMITED
DEPTH = 4          # root / tenant / session / tool-call


@dataclass(frozen=True)
class ControllerConfig:
    step_ms: float = 10.0             # engine-step duration the delays quantize to
    base_delay_ms: float = 10.0
    max_delay_ms: float = 2000.0
    high_priority_discount: float = 0.1
    overage_gain: float = 10.0


def new_state(capacity_pages: int, n_domains: int = 64) -> dict:
    """Fresh device state with only the root (index 0) configured."""
    n = n_domains
    st = {
        "usage": jnp.zeros((n,), jnp.int32),
        "high": jnp.full((n,), UNLIMITED, jnp.int32),
        "max": jnp.full((n,), UNLIMITED, jnp.int32),
        "low": jnp.zeros((n,), jnp.int32),
        "parent": jnp.full((n,), -1, jnp.int32),
        "priority": jnp.full((n,), D.NORMAL, jnp.int32),
        "frozen": jnp.zeros((n,), bool),
        "active": jnp.zeros((n,), bool),
        "throttle_until": jnp.zeros((n,), jnp.int32),
        "peak": jnp.zeros((n,), jnp.int32),
    }
    st["max"] = st["max"].at[0].set(capacity_pages)
    st["high"] = st["high"].at[0].set(capacity_pages)
    st["active"] = st["active"].at[0].set(True)
    return st


def _ancestor_chain(parent, idx):
    """(DEPTH,) ancestor indices of ``idx`` (self first), -1-padded."""
    chain = [idx]
    for _ in range(DEPTH - 1):
        prev = chain[-1]
        nxt = jnp.where(prev >= 0, parent[jnp.maximum(prev, 0)], -1)
        chain.append(nxt)
    return jnp.stack(chain)


def _delay_steps(cfg: ControllerConfig, over_frac, priority, protected):
    """get_high_delay_ms analogue, quantized to engine steps."""
    delay_ms = jnp.minimum(cfg.max_delay_ms,
                           cfg.base_delay_ms * (1.0 + cfg.overage_gain * over_frac))
    delay_ms = jnp.where(priority == D.HIGH,
                         delay_ms * cfg.high_priority_discount, delay_ms)
    delay_ms = jnp.where(protected, 0.0, delay_ms)
    return jnp.ceil(delay_ms / cfg.step_ms).astype(jnp.int32)


def charge_batch(state: dict, dom: jax.Array, amt: jax.Array, step,
                 cfg: ControllerConfig = ControllerConfig()):
    """Hierarchically charge ``amt[i]`` pages to domain ``dom[i]``.

    Returns (new_state, granted (m,) bool, stalled (m,) bool).
    ``stalled`` marks requests denied *because of throttle/freeze* (they
    retry next step); hard-``max`` denials also stall (the engine's
    graceful-degradation path never OOM-kills from inside the step).
    Zero-amount requests are gated only by freeze/throttle (a decode
    step that does not cross a page boundary allocates nothing but must
    still respect cgroup.freeze).
    """
    def one(carry, req):
        usage, peak, throttle_until = carry
        d, a = req
        valid = d >= 0
        chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
        cvalid = (chain >= 0) & valid
        cidx = jnp.maximum(chain, 0)

        frozen = jnp.any(jnp.where(cvalid, state["frozen"][cidx], False))
        throttled = jnp.any(jnp.where(cvalid, throttle_until[cidx] > step, False))
        over_max = jnp.any(jnp.where(cvalid, usage[cidx] + a > state["max"][cidx],
                                     False))
        grant = valid & ~frozen & ~throttled & ~over_max
        add = jnp.where(cvalid & grant, a, 0)
        usage = usage.at[cidx].add(add)
        peak = jnp.maximum(peak, usage)

        # soft-limit breach -> graduated throttle on the charged domain
        new_usage = jnp.where(cvalid, usage[cidx], 0)
        high = state["high"][cidx]
        over = jnp.where(cvalid & (high < UNLIMITED),
                         new_usage - high, 0)
        protected = jnp.where(cvalid, new_usage <= state["low"][cidx], True)
        over_frac = jnp.max(jnp.where(over > 0,
                                      over / jnp.maximum(high, 1), 0.0))
        any_over = grant & (over_frac > 0)
        dly = _delay_steps(cfg, over_frac, state["priority"][jnp.maximum(d, 0)],
                           jnp.all(protected | (over <= 0)))
        tu = jnp.where(any_over,
                       jnp.maximum(throttle_until[jnp.maximum(d, 0)],
                                   step + dly),
                       throttle_until[jnp.maximum(d, 0)])
        throttle_until = throttle_until.at[jnp.maximum(d, 0)].set(
            jnp.where(valid, tu, throttle_until[jnp.maximum(d, 0)]))
        stalled = valid & (frozen | throttled | over_max)
        return (usage, peak, throttle_until), (grant, stalled)

    (usage, peak, throttle_until), (granted, stalled) = jax.lax.scan(
        one, (state["usage"], state["peak"], state["throttle_until"]),
        (dom.astype(jnp.int32), amt.astype(jnp.int32)))
    new_state = dict(state, usage=usage, peak=peak,
                     throttle_until=throttle_until)
    return new_state, granted, stalled


def host_charge(state: dict, idx: int, amt: int) -> dict:
    """Unconditional hierarchical charge for host-side lifecycle moves
    (residual transfer on tool-domain close, thaw re-charge).  Never
    denied — the pages are already resident; this is bookkeeping."""
    usage = np.asarray(state["usage"]).copy()
    parent = np.asarray(state["parent"])
    i = idx
    for _ in range(DEPTH):
        if i < 0:
            break
        usage[i] = max(0, usage[i] + amt)
        i = int(parent[i])
    return dict(state, usage=jnp.asarray(usage),
                peak=jnp.maximum(state["peak"], jnp.asarray(usage)))


def uncharge_batch(state: dict, dom: jax.Array, amt: jax.Array):
    """Release pages (always succeeds); vectorized scatter over chains."""
    chain = jax.vmap(lambda d: _ancestor_chain(state["parent"],
                                               jnp.maximum(d, 0)))(dom)
    valid = (chain >= 0) & (dom >= 0)[:, None]
    sub = jnp.where(valid, amt[:, None], 0)
    usage = state["usage"].at[jnp.maximum(chain, 0).reshape(-1)].add(
        -sub.reshape(-1))
    return dict(state, usage=jnp.maximum(usage, 0))


def slot_gate(state: dict, slot_dom: jax.Array, step) -> jax.Array:
    """May each slot advance this step?  (no frozen/throttled ancestor)"""
    def one(d):
        chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
        cvalid = (chain >= 0) & (d >= 0)
        cidx = jnp.maximum(chain, 0)
        frozen = jnp.any(jnp.where(cvalid, state["frozen"][cidx], False))
        throttled = jnp.any(jnp.where(cvalid,
                                      state["throttle_until"][cidx] > step,
                                      False))
        return (d >= 0) & ~frozen & ~throttled
    return jax.vmap(one)(slot_dom.astype(jnp.int32))


# -------------------------------------------------------------- host mirror


class DeviceDomainTable:
    """Host-side index allocator + lifecycle editor for the device state.

    This is the paper's 'lightweight user-space daemon': it creates and
    removes domains, configures limits, freezes/thaws — but the per-
    allocation enforcement runs on device inside the jitted step.
    """

    def __init__(self, capacity_pages: int, n_domains: int = 64,
                 cfg: ControllerConfig = ControllerConfig()):
        self.cfg = cfg
        self.n = n_domains
        self.state = new_state(capacity_pages, n_domains)
        self.index: dict[str, int] = {"/": 0}
        self._free = list(range(1, n_domains))

    def create(self, path: str, *, high: int = UNLIMITED, max: int = UNLIMITED,
               low: int = 0, priority: int = D.NORMAL) -> int:
        assert path not in self.index, path
        parent_path = path.rsplit("/", 1)[0] or "/"
        pidx = self.index[parent_path]
        idx = self._free.pop(0)
        self.index[path] = idx
        st = self.state
        self.state = dict(
            st,
            high=st["high"].at[idx].set(high),
            max=st["max"].at[idx].set(max),
            low=st["low"].at[idx].set(low),
            parent=st["parent"].at[idx].set(pidx),
            priority=st["priority"].at[idx].set(priority),
            usage=st["usage"].at[idx].set(0),
            peak=st["peak"].at[idx].set(0),
            frozen=st["frozen"].at[idx].set(False),
            active=st["active"].at[idx].set(True),
            throttle_until=st["throttle_until"].at[idx].set(0),
        )
        return idx

    def remove(self, path: str) -> None:
        idx = self.index.pop(path)
        residual = int(self.state["usage"][idx])
        if residual:
            # release residual charges up the chain (host-side lifecycle op)
            self.state = uncharge_batch(self.state,
                                        jnp.array([idx], jnp.int32),
                                        jnp.array([residual], jnp.int32))
        st = self.state
        self.state = dict(st, active=st["active"].at[idx].set(False),
                          frozen=st["frozen"].at[idx].set(False),
                          parent=st["parent"].at[idx].set(-1))
        self._free.append(idx)

    def set_frozen(self, path: str, flag: bool) -> None:
        idx = self.index[path]
        st = self.state
        self.state = dict(st, frozen=st["frozen"].at[idx].set(flag))

    def usage(self, path: str) -> int:
        return int(self.state["usage"][self.index[path]])

    def peak(self, path: str) -> int:
        return int(self.state["peak"][self.index[path]])

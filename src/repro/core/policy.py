"""Resource-control policies for the trace-replay harness.

One class per row of the paper's Table 2, plus AgentCgroup itself:

  * ``NoIsolationPolicy``   — the Fig-8 baseline: one shared pool, kernel
    OOM-kills the largest consumer when allocations stall too long.
  * ``StaticLimitPolicy``   — memory.max per container: peak-sized limits
    waste >90 % of reservation; average-sized limits OOM on bursts
    (granularity mismatch).
  * ``ReactivePSIPolicy``   — systemd-oomd/Meta-oomd analogue: a daemon
    polls PSI and kills, but poll + reaction latency lands *after* the
    1-2 s bursts (responsiveness mismatch).
  * ``PredictiveP95Policy`` — Autopilot/VPA analogue: limits from
    historical P95s, defeated by 1.8x-20x non-determinism (adaptability
    mismatch).
  * ``AgentCgroupPolicy``   — the paper's system: hierarchical tool-call
    domains + intent hints (upward), graduated in-kernel enforcement
    throttle -> freeze -> feedback-retry (downward), kill only as last
    resort.

Policies drive the unified ``AgentCgroup`` control plane owned by the
simulator (``sim.cg`` — ``core/cgroup.py``), never a raw tree; the
simulator provides the allocation-latency physics (reclaim costs) and
calls back on tool-span boundaries and ticks.

Since the ``PolicyProgram`` redesign the per-allocation *decision*
(grant / deny / graduated delay) is no longer computed here: it runs in
the program attached to ``sim.cg`` — the same code the device backends
trace — and arrives on the ``ChargeTicket``.  What stays host-side is
exactly the paper's user-space daemon work: domain lifecycle, limit
sizing, kill/freeze selection, and the intent channel.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import domains as D
from repro.core.cgroup import DomainSpec
from repro.core.intent import (AdaptiveAgentModel, CATEGORY_HINT, Feedback,
                               hint_to_high)
from repro.core.progs import PolicyProgram


@dataclass
class AllocOutcome:
    granted: bool
    delay_ms: float = 0.0
    kill: bool = False
    freeze: bool = False
    feedback: Optional[Feedback] = None
    protected: bool = False     # below-``low`` fast path (skips direct reclaim)


class BasePolicy:
    name = "base"
    hierarchical = False

    def setup(self, sim, tasks) -> None:
        for t in tasks:
            sim.cg.mkdir(self.domain_for(t), DomainSpec(priority=t.priority))

    def domain_for(self, task) -> str:
        return f"/{task.key}"

    def on_tool_start(self, sim, task, call) -> None:
        pass

    def on_tool_end(self, sim, task, call) -> None:
        pass

    def charge_path(self, sim, task) -> str:
        return self.domain_for(task)

    def on_alloc(self, sim, task, mb: int) -> AllocOutcome:
        raise NotImplementedError

    def on_release(self, sim, task, mb: int) -> None:
        sim.cg.uncharge(self.charge_path(sim, task), mb)

    def tick(self, sim) -> None:
        pass

    def on_task_end(self, sim, task) -> None:
        path = self.domain_for(task)
        usage = sim.cg.usage(path)
        if usage:
            sim.cg.uncharge(path, usage)

    # admission control: how many tasks fit concurrently (for the
    # mismatch benchmark's concurrency-density comparison)
    def max_concurrency(self, capacity_mb: int, per_task_mb: float) -> int:
        return max(1, int(capacity_mb // max(per_task_mb, 1)))


# --------------------------------------------------------------- baselines


class NoIsolationPolicy(BasePolicy):
    """Shared pool, no domains below root; kernel global OOM heuristic."""
    name = "no_isolation"

    def __init__(self, oom_after_ms: float = 120.0):
        self.oom_after_ms = oom_after_ms

    def on_alloc(self, sim, task, mb: int) -> AllocOutcome:
        ticket = sim.cg.try_charge(self.charge_path(sim, task), mb)
        if ticket.granted:
            return AllocOutcome(True)
        # pool exhausted: stall; the kernel OOMs the largest consumer
        # once the stall exceeds its patience
        if sim.stall_ms(task) > self.oom_after_ms:
            victim = max(sim.running_tasks(),
                         key=lambda t: sim.cg.usage(self.domain_for(t)))
            sim.kill_task(victim, reason="global_oom")
            return AllocOutcome(False)
        return AllocOutcome(False)


class StaticLimitPolicy(BasePolicy):
    """memory.max per container (K8s Guaranteed-style)."""
    name = "static_limit"

    def __init__(self, limit_mb: int):
        self.limit_mb = limit_mb

    def setup(self, sim, tasks) -> None:
        for t in tasks:
            sim.cg.mkdir(self.domain_for(t),
                         DomainSpec(max=self.limit_mb, priority=t.priority))

    def on_alloc(self, sim, task, mb: int) -> AllocOutcome:
        ticket = sim.cg.try_charge(self.charge_path(sim, task), mb)
        if ticket.granted:
            return AllocOutcome(True)
        if ticket.blocked_by == self.domain_for(task):
            # the container's own memory.max: immediate OOM kill
            sim.kill_task(task, reason="memory.max")
            return AllocOutcome(False, kill=True)
        return AllocOutcome(False)

    def max_concurrency(self, capacity_mb: int, per_task_mb: float) -> int:
        return max(1, int(capacity_mb // self.limit_mb))


class ReactivePSIPolicy(BasePolicy):
    """PSI-watching user-space OOM daemon (oomd / systemd-oomd)."""
    name = "reactive_psi"

    def __init__(self, poll_ms: float = 100.0, react_ms: float = 40.0,
                 pressure_threshold: float = 0.4):
        self.poll_ms = poll_ms
        self.react_ms = react_ms
        self.threshold = pressure_threshold
        self._last_poll = 0.0
        self._pending_kill_at: Optional[float] = None

    def on_alloc(self, sim, task, mb: int) -> AllocOutcome:
        ticket = sim.cg.try_charge(self.charge_path(sim, task), mb)
        return AllocOutcome(ticket.granted)

    def tick(self, sim) -> None:
        now = sim.now_ms
        if self._pending_kill_at is not None and now >= self._pending_kill_at:
            self._pending_kill_at = None
            lows = [t for t in sim.running_tasks() if t.priority == D.LOW]
            if lows:
                victim = max(lows,
                             key=lambda t: sim.cg.usage(self.domain_for(t)))
                sim.kill_task(victim, reason="oomd_psi")
        if now - self._last_poll < self.poll_ms:
            return
        self._last_poll = now
        if sim.accounting.pressure("root", now) > self.threshold:
            # daemon wakes, decides, writes cgroup.kill — react_ms later
            if self._pending_kill_at is None:
                self._pending_kill_at = now + self.react_ms


class PredictiveP95Policy(StaticLimitPolicy):
    """Autopilot-style: per-task limit = P95 of historical peaks."""
    name = "predictive_p95"

    def __init__(self, history_peaks_mb: dict, safety: float = 1.1,
                 default_mb: int = 600):
        self.history = history_peaks_mb
        self.safety = safety
        self.default_mb = default_mb
        self.limit_mb = default_mb       # updated per task at setup

    def setup(self, sim, tasks) -> None:
        self.limits = {}
        for t in tasks:
            hist = self.history.get(t.trace.task_id)
            lim = (int(np.percentile(hist, 95) * self.safety)
                   if hist else self.default_mb)
            self.limits[t.key] = lim
            sim.cg.mkdir(self.domain_for(t),
                         DomainSpec(max=lim, priority=t.priority))

    def on_alloc(self, sim, task, mb: int) -> AllocOutcome:
        ticket = sim.cg.try_charge(self.charge_path(sim, task), mb)
        if ticket.granted:
            return AllocOutcome(True)
        if ticket.blocked_by == self.domain_for(task):
            sim.kill_task(task, reason="predicted_limit")
            return AllocOutcome(False, kill=True)
        return AllocOutcome(False)


# ------------------------------------------------------------- AgentCgroup


class AgentCgroupPolicy(BasePolicy):
    """The paper's system (§5): hierarchical tool-call domains, intent
    hints, graduated in-kernel enforcement throttle -> freeze ->
    feedback, kill last.  Tool-call domains open and close through the
    control plane's ``IntentChannel`` leases."""
    name = "agentcgroup"
    hierarchical = True

    def __init__(self, *, session_high: Optional[dict] = None,
                 use_intent: bool = True,
                 freeze_threshold: float = 0.97, thaw_threshold: float = 0.80,
                 hard_patience_ms: float = 150.0,
                 agent_model: Optional[AdaptiveAgentModel] = None,
                 program: Optional[PolicyProgram] = None,
                 escalation=None,
                 lease_max_factor: Optional[float] = None):
        # graduated-throttle constants live in the attached program
        # (domains.BASE_DELAY_MS etc. by default) — not duplicated here
        self.session_high = session_high or {}
        self.use_intent = use_intent
        self.freeze_threshold = freeze_threshold
        self.thaw_threshold = thaw_threshold
        self.hard_patience_ms = hard_patience_ms
        self.agent_model = agent_model or AdaptiveAgentModel()
        self.program = program
        # semantic OOM escalation (core/escalation.py): when
        # ``lease_max_factor`` is set, tool leases carry a hard
        # ``memory.max`` = factor * high; a breach kills the lease and —
        # with an ``EscalationPolicy`` — retries it at a negotiated
        # higher limit instead of killing the task (both default off,
        # preserving the established replay outputs bit-for-bit)
        self.escalation = escalation
        self.lease_max_factor = lease_max_factor
        self._lease: dict = {}          # task.key -> open tool Lease
        self._tool_seq = 0

    def setup(self, sim, tasks) -> None:
        if self.program is not None:
            sim.cg.attach("/", self.program)
        for t in tasks:
            # session_high keyed by task_id (paper: LOW sessions get
            # memory.high = 400 MB, HIGH gets memory.high = max)
            high = self.session_high.get(t.trace.task_id, D.UNLIMITED)
            low = 0
            if t.priority == D.HIGH:
                # below_low protection for the latency-sensitive session
                low = int(t.trace.peak_mb * 1.05)
            sim.cg.mkdir(self.domain_for(t),
                         DomainSpec(high=high, low=low, priority=t.priority))

    # --- fine-grained domains at tool-call boundaries (bash-wrapper analogue)

    def on_tool_start(self, sim, task, call) -> None:
        self._tool_seq += 1
        hint = None
        if self.use_intent:
            declared = CATEGORY_HINT.get(call.category)
            hint = self.agent_model.hint_for(call.category, declared)
        high = hint_to_high(hint)
        lease_max = D.UNLIMITED
        if self.lease_max_factor is not None:
            lease_max = max(1, int(high * self.lease_max_factor))
        self._lease[task.key] = sim.cg.intent.declare(
            f"tool_{self._tool_seq}", hint, parent=self.domain_for(task),
            priority=task.priority, high=high, max=lease_max)

    def on_tool_end(self, sim, task, call) -> None:
        lease = self._lease.pop(task.key, None)
        if lease is not None:
            if lease.attempt > 1 and not lease.killed:
                # an escalated retry ran to completion — recovered
                esc = getattr(sim, "_escalator", None)
                if esc is not None:
                    esc.ledger.record_recovery(f"{task.key}:{lease.tool_id}")
            # lease close logs memory.peak and moves retained memory up
            # to the session (retry accumulation)
            lease.close()

    def open_lease(self, task):
        return self._lease.get(task.key)

    def replace_lease(self, task, lease) -> None:
        if lease is None:
            self._lease.pop(task.key, None)
        else:
            self._lease[task.key] = lease

    def charge_path(self, sim, task) -> str:
        lease = self._lease.get(task.key)
        return lease.path if lease is not None else self.domain_for(task)

    def on_release(self, sim, task, mb: int) -> None:
        path = self.charge_path(sim, task)
        take = min(mb, sim.cg.usage(path))
        if take:
            sim.cg.uncharge(path, take)
        rest = mb - take
        if rest > 0 and path != self.domain_for(task):
            sim.cg.uncharge(self.domain_for(task), rest)

    # --- graduated in-kernel enforcement

    def on_alloc(self, sim, task, mb: int) -> AllocOutcome:
        path = self.charge_path(sim, task)
        ticket = sim.cg.try_charge(path, mb)
        if ticket.granted:
            # graduated delay comes straight off the ticket — computed
            # by the attached program, the same decision code the
            # device backends run in-step
            delay = ticket.delay_ms
            # below_low protection: the HIGH session's allocations skip
            # direct reclaim — sibling throttling did the work already
            sess = self.domain_for(task)
            protected = (task.priority == D.HIGH
                         and sim.cg.usage(sess)
                         <= sim.cg.read(sess, "memory.low"))
            return AllocOutcome(True, delay_ms=delay, protected=protected)
        # memcg-max breach on the tool lease itself: kill the CALL (not
        # the task) and — when escalation is on — retry it at a
        # negotiated higher limit (the paper's exit-137 -> retry loop)
        lease = self._lease.get(task.key)
        if (lease is not None and ticket.blocked_by == lease.path
                and lease.max < D.UNLIMITED
                and sim.cg.usage(lease.path) + mb > lease.max):
            if self.escalation is not None:
                sim.escalate_tool_call(task)
            else:
                # no-retry baseline: a hard tool limit is fatal
                sim.kill_task(task, reason="memcg_max_tool",
                              allow_escalation=False)
            return AllocOutcome(False, kill=True)
        # hard denial: stall; after patience, feedback-retry (strategy
        # reconstruction) instead of killing
        if sim.stall_ms(task) > self.hard_patience_ms:
            fb = sim.cg.intent.feedback(
                path, "oom", peak=sim.cg.peak(path),
                limit=sim.cg.read(path, "memory.max"))
            return AllocOutcome(False, feedback=fb)
        return AllocOutcome(False)

    # --- daemon: freeze under extreme pressure, thaw when it clears

    def tick(self, sim) -> None:
        usage, cap = sim.cg.usage("/"), sim.cg.capacity
        frozen = sim.frozen_tasks()
        if usage > self.freeze_threshold * cap:
            cands = [t for t in sim.running_tasks() if t.priority == D.LOW]
            if cands:
                victim = max(cands,
                             key=lambda t: sim.cg.usage(self.domain_for(t)))
                sim.freeze_task(victim)
        elif frozen:
            # thaw only when the re-charge will not immediately push the
            # pool back over the freeze threshold (hysteresis)
            cand = min(frozen, key=lambda t: t.frozen_mb)
            if usage + cand.frozen_mb < self.thaw_threshold * cap:
                sim.thaw_task(cand)

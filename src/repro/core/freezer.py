"""Freeze/thaw with state offload — the cgroup.freeze analogue.

Freezing a session must *release the contended resource* (HBM pages /
pool pages) while preserving the session's accumulated context, so
freeze = offload state to host memory + park; thaw = restore + resume.
This is the paper's graceful-degradation middle step between throttling
and termination: unlike an OOM kill, the LLM context survives.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np


@dataclass
class FrozenEntry:
    session_id: str
    blobs: Any                   # host pytree (numpy)
    pages: int                   # pages the session held when frozen
    meta: dict
    frozen_at: float             # caller's step clock, never wall time:
                                 # records must be replay-deterministic


class FrozenStore:
    """Host-memory swap space for frozen sessions' device state."""

    def __init__(self) -> None:
        self._entries: dict[str, FrozenEntry] = {}
        self.n_freezes = 0
        self.n_thaws = 0
        self.bytes_held = 0
        # chaos seam: called with the session id after the host copy
        # but BEFORE the entry commits; a raise aborts the freeze with
        # the store unchanged (never a partial entry) — see
        # ``FaultyBackend.offload_fault``
        self.offload_hook: Optional[Any] = None

    def freeze(self, session_id: str, device_tree: Any, *, pages: int,
               meta: Optional[dict] = None, now: float = 0.0) -> None:
        """Offload a pytree of device arrays to host memory.  ``now``
        is the caller's logical clock (engine step number).

        Transactional: the entry (and the freeze/bytes accounting)
        commits only after the whole device->host copy — and the
        ``offload_hook`` chaos seam — succeeded, so a transient
        mid-offload failure leaves the store exactly as it was."""
        assert session_id not in self._entries, session_id
        host = jax.tree.map(lambda x: np.asarray(x), device_tree)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(host))
        if self.offload_hook is not None:
            self.offload_hook(session_id)      # may raise: nothing committed
        self._entries[session_id] = FrozenEntry(
            session_id, host, pages, meta or {}, float(now))
        self.n_freezes += 1
        self.bytes_held += nbytes

    def thaw(self, session_id: str) -> FrozenEntry:
        """Return the offloaded state (caller re-uploads / re-charges)."""
        e = self._entries.pop(session_id)
        self.n_thaws += 1
        self.bytes_held -= sum(x.nbytes for x in jax.tree.leaves(e.blobs))
        return e

    def is_frozen(self, session_id: str) -> bool:
        return session_id in self._entries

    def frozen_ids(self) -> list[str]:
        return list(self._entries)

    def pages_held(self, session_id: str) -> int:
        return self._entries[session_id].pages

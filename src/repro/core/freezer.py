"""Freeze/thaw with state offload — the cgroup.freeze analogue.

Freezing a session must *release the contended resource* (HBM pages /
pool pages) while preserving the session's accumulated context, so
freeze = offload state to host memory + park; thaw = restore + resume.
This is the paper's graceful-degradation middle step between throttling
and termination: unlike an OOM kill, the LLM context survives.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np


@dataclass
class FrozenEntry:
    session_id: str
    blobs: Any                   # host pytree (numpy)
    pages: int                   # pages the session held when frozen
    meta: dict
    frozen_at: float             # caller's step clock, never wall time:
                                 # records must be replay-deterministic


class FrozenStore:
    """Host-memory swap space for frozen sessions' device state."""

    def __init__(self) -> None:
        self._entries: dict[str, FrozenEntry] = {}
        self.n_freezes = 0
        self.n_thaws = 0
        self.bytes_held = 0

    def freeze(self, session_id: str, device_tree: Any, *, pages: int,
               meta: Optional[dict] = None, now: float = 0.0) -> None:
        """Offload a pytree of device arrays to host memory.  ``now``
        is the caller's logical clock (engine step number)."""
        assert session_id not in self._entries, session_id
        host = jax.tree.map(lambda x: np.asarray(x), device_tree)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(host))
        self._entries[session_id] = FrozenEntry(
            session_id, host, pages, meta or {}, float(now))
        self.n_freezes += 1
        self.bytes_held += nbytes

    def thaw(self, session_id: str) -> FrozenEntry:
        """Return the offloaded state (caller re-uploads / re-charges)."""
        e = self._entries.pop(session_id)
        self.n_thaws += 1
        self.bytes_held -= sum(x.nbytes for x in jax.tree.leaves(e.blobs))
        return e

    def is_frozen(self, session_id: str) -> bool:
        return session_id in self._entries

    def frozen_ids(self) -> list[str]:
        return list(self._entries)

    def pages_held(self, session_id: str) -> int:
        return self._entries[session_id].pages

"""Unified cgroupfs-style control plane for AgentCgroup (paper §5).

One API, two enforcement substrates.  The paper's artifact is a single
hierarchical interface — cgroup files plus an intent channel — yet this
repo grew two divergent surfaces: the pure-python ``DomainTree``
(trace replay) and the device-resident state + free functions
(serving engine).  ``AgentCgroup`` unifies them behind the cgroupfs
idiom:

    cg = AgentCgroup(HostTreeBackend(capacity))        # or DeviceTableBackend
    cg.mkdir("/t/sess", DomainSpec(high=400, priority=HIGH))
    cg.write("/t/sess", "memory.high", 300)
    cg.try_charge("/t/sess", 64)
    cg.read("/t/sess", "memory.events")
    cg.freeze("/t/sess"); cg.thaw("/t/sess"); cg.kill("/t/sess")
    lease = cg.intent.declare("tool_7", Hint.HIGH, parent="/t/sess")
    ...; lease.feedback("throttled"); lease.close()    # residual moves up

Backends conform to the ``Backend`` protocol:

  * ``HostTreeBackend``  — wraps ``domains.DomainTree``; the reference
    semantics, with memcg-style event counters surfaced through
    ``read(path, "memory.events")``.
  * ``DeviceTableBackend`` — wraps the jax device-resident state
    (``core/controller.py``).  Lifecycle ops run host-side (the paper's
    lightweight daemon); per-allocation enforcement stays inside the
    jitted engine step via ``device_view()``, whose pure ``lax``-only
    methods the step function closes over.
  * ``ShardedTableBackend`` (``core/sharded.py``) — the device table
    across an N-device mesh, per-tenant device-group placement.
  * ``AsyncDaemonBackend`` (``core/daemon.py``) — wraps any of the
    above and moves every lifecycle op onto a daemon thread behind a
    FIFO command queue, applied in batched epochs at step boundaries;
    ``flush()``/``barrier()`` make it bit-exact with its inner backend.

Because both backends speak the same op vocabulary, host/device
cross-validation is one loop: replay an op sequence against two
``AgentCgroup`` instances and compare ``usage``/``peak``/grants.

Enforcement decisions on EVERY backend dispatch into one attached
``PolicyProgram`` (``core/progs.py``, the memcg_bpf_ops analogue):

    cg.attach("/", TokenBucketProgram(bucket_capacity=32))  # swap code
    cg.update_params("/tenant", overage_gain=25.0)          # retune live

``attach`` swaps the decision code (a recompile for jitted consumers,
like loading a new BPF object); ``update_params`` writes the program's
per-domain parameter table (plain state — never a retrace).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Protocol, Union, runtime_checkable

import numpy as np

from repro.core import domains as D
from repro.core import pressure as P
from repro.core.events import Ev, EventLog, OomEvent
from repro.core.intent import Feedback, Hint, hint_to_high, make_feedback
from repro.core.progs import (ChainView, PolicyProgram, Request, as_program,
                              as_programs, charge_decision, check_registry,
                              pad_row, path_in_scope,
                              registry_unknown_params, registry_width)

UNLIMITED = D.UNLIMITED

# readable / writable control files (the cgroupfs surface);
# memory.pressure / cpu.pressure are PSI strings computed by the facade
# from the backends' raw subtree stall counters (memory.stall /
# cpu.stall — see core/pressure.py)
_READ_FILES = ("memory.current", "memory.peak", "memory.high", "memory.max",
               "memory.low", "memory.priority", "memory.events",
               "cgroup.freeze", "cpu.weight", "cpu.max",
               "memory.pressure", "cpu.pressure",
               "memory.stall", "cpu.stall")
_WRITE_FILES = ("memory.high", "memory.max", "memory.low", "memory.priority",
                "cgroup.freeze", "cpu.weight", "cpu.max")


@dataclass(frozen=True)
class DomainSpec:
    """Creation-time limits — the values seeded into the control files."""
    high: int = UNLIMITED
    max: int = UNLIMITED
    low: int = 0
    priority: int = D.NORMAL
    weight: int = D.DEFAULT_WEIGHT     # cpu.weight (1..10000)
    cpu_max: int = UNLIMITED           # cpu.max: step quota per window


@dataclass(frozen=True)
class ChargeTicket:
    """Unified result of a hierarchical charge attempt.

    ``stalled`` marks retryable denials (freeze / throttle / hard max —
    the engine's graceful-degradation path never OOM-kills in-step).
    ``blocked_by``/``over_high`` carry the host backend's detail; the
    device backend reports grants only (its detail lives in-step).
    ``delay_ms`` is the program-imposed throttle window now pending on
    the charged domain (get_high_delay_ms), 0 when none.
    """
    granted: bool
    stalled: bool = False
    blocked_by: Optional[str] = None
    over_high: tuple = ()
    delay_ms: float = 0.0


def parent_path(path: str) -> Optional[str]:
    if path == "/":
        return None
    return path.rsplit("/", 1)[0] or "/"


def ancestor_paths(path: str) -> list[str]:
    """Self-first ancestor chain, derived purely from the path string —
    identical for every backend."""
    out = [path]
    while (p := parent_path(out[-1])) is not None:
        out.append(p)
    return out


@runtime_checkable
class Backend(Protocol):
    """What a conforming enforcement substrate must provide."""

    log: EventLog
    prog: PolicyProgram

    def attach(self, scope: str, prog: PolicyProgram) -> None: ...
    def update_params(self, path: str, kv: dict) -> None: ...
    def mkdir(self, path: str, spec: DomainSpec) -> int: ...
    def rmdir(self, path: str, transfer_residual: bool) -> int: ...
    def exists(self, path: str) -> bool: ...
    def paths(self) -> list[str]: ...
    def handle(self, path: str) -> int: ...
    def path_of(self, handle: int) -> str: ...
    def try_charge(self, path: str, pages: int,
                   step: Optional[int]) -> ChargeTicket: ...
    def uncharge(self, path: str, pages: int) -> None: ...
    def charge_unchecked(self, path: str, pages: int) -> None: ...
    def schedule(self, paths: list, costs: list, step: int,
                 budget: int) -> list: ...
    def freeze(self, path: str) -> None: ...
    def thaw(self, path: str) -> None: ...
    def kill(self, path: str) -> int: ...
    def read(self, path: str, file: str): ...
    def write(self, path: str, file: str, value) -> None: ...
    def snapshot(self) -> dict: ...
    def set_time(self, t: float) -> None: ...


# --------------------------------------------------------------------- host


class HostTreeBackend:
    """Reference backend: the pure-python ``DomainTree`` data model, with
    every charge *decision* dispatched into the attached
    ``PolicyProgram`` — the literal same ``charge_decision`` the device
    kernels trace, jit-compiled once per program and chain depth.  This
    is what makes trace replay and the serving engine impossible to
    drift: one decision path, three substrates.

    Clock convention: ``try_charge(..., step=k)`` runs on the integer
    step clock (throttle windows quantize to ``prog.step_ms`` steps,
    matching the device backends bit-for-bit); ``step=None`` runs on the
    facade's millisecond clock (``set_time``) with unquantized windows —
    what the trace-replay simulator uses.  Don't mix the two on one
    instance.
    """

    def __init__(self, capacity: int, log: Optional[EventLog] = None,
                 prog: Optional[PolicyProgram] = None):
        self.tree = D.DomainTree(capacity, log)
        self.log = self.tree.log
        self._ids: dict[str, int] = {"/": 0}
        self._paths: dict[int, str] = {0: "/"}
        self._next_id = 1
        self.progs = as_programs(prog)
        self.scopes = ["/"]
        self._rows: dict[str, np.ndarray] = {"/": self.prog.default_row()}
        self._pids: dict[str, int] = {"/": 0}    # path -> registry slot
        self._decide = None              # jitted charge_decision, per registry
        self.tree.root.flat_weight = 1.0

    # -------------------------------------------------------------- programs

    @property
    def prog(self) -> PolicyProgram:
        """The primary (slot 0) program — registry trace constants and
        the single-program compatibility surface."""
        return self.progs[0]

    @property
    def attach_scope(self) -> str:
        return self.scopes[0]

    def _in_scope(self, path: str) -> bool:
        return path_in_scope(self.attach_scope, path)

    def attach(self, scope: str, prog: PolicyProgram) -> None:
        """Root attach resets the registry to this one program (every
        domain on its default row — the pre-registry semantics).  A
        subtree attach composes: the program takes a registry slot,
        in-scope domains move to it; everything outside keeps its
        current program and live rows."""
        prog = as_program(prog)
        self._decide = None
        if scope == "/":
            self.progs = (prog,)
            self.scopes = ["/"]
            self._rows = {p: prog.default_row() for p in self.tree._index}
            self._pids = {p: 0 for p in self.tree._index}
            return
        if scope in self.scopes:
            k = self.scopes.index(scope)
            self.progs = self.progs[:k] + (prog,) + self.progs[k + 1:]
        else:
            k = len(self.progs)
            self.progs = self.progs + (prog,)
            self.scopes.append(scope)
        check_registry(self.progs)
        width = registry_width(self.progs)
        for p in self.tree._index:
            if path_in_scope(scope, p):
                self._pids[p] = k
                self._rows[p] = pad_row(prog.default_row(), width)
            else:
                self._rows[p] = pad_row(self._rows[p], width)

    def update_params(self, path: str, kv: dict) -> None:
        unknown = registry_unknown_params(self.progs, kv)
        if unknown:
            raise KeyError(
                f"no registered program has param(s) {sorted(unknown)}")
        for p in self.tree._index:
            if path_in_scope(path, p):
                pr = self.progs[self._pids[p]]
                for k, v in kv.items():
                    if k in pr.param_names:
                        self._rows[p][pr.col(k)] = float(v)

    def _decide_fn(self):
        if self._decide is None:
            import jax
            progs = self.progs
            self._decide = jax.jit(
                lambda view, req: charge_decision(progs, view, req))
        return self._decide

    def _recompute_flat(self) -> None:
        """Re-flatten hierarchical weights (lifecycle rate: mkdir /
        rmdir / cpu.weight writes), scx_flatcg style."""
        from repro.core.sched import flat_weights_by_path
        flat = flat_weights_by_path(
            {p: d.weight for p, d in self.tree._index.items()})
        for p, d in self.tree._index.items():
            d.flat_weight = float(flat[p])

    # lifecycle
    def mkdir(self, path: str, spec: DomainSpec) -> int:
        self.tree.create(path, high=spec.high, max=spec.max, low=spec.low,
                         priority=spec.priority, weight=spec.weight,
                         cpu_max=spec.cpu_max)
        h = self._next_id
        self._next_id += 1
        self._ids[path] = h
        self._paths[h] = path
        parent = parent_path(path)
        # children inherit the parent's live row AND program slot
        # (settings propagate down; a child created after a subtree
        # attach runs the subtree's program, not the root default)
        self._rows[path] = self._rows[parent].copy()
        self._pids[path] = self._pids[parent]
        self._recompute_flat()
        return h

    def rmdir(self, path: str, transfer_residual: bool) -> int:
        residual = self.tree.get(path).usage
        parent = parent_path(path)
        self.tree.remove(path)           # uncharges residual from the chain
        if transfer_residual and residual and parent is not None:
            self.charge_unchecked(parent, residual)
        self._paths.pop(self._ids.pop(path), None)
        self._rows.pop(path, None)
        self._pids.pop(path, None)
        self._recompute_flat()
        return residual

    def exists(self, path: str) -> bool:
        return self.tree.exists(path)

    def paths(self) -> list[str]:
        return list(self.tree._index)

    def handle(self, path: str) -> int:
        return self._ids[path]

    def path_of(self, handle: int) -> str:
        return self._paths[handle]

    # charging
    def try_charge(self, path: str, pages: int,
                   step: Optional[int]) -> ChargeTicket:
        import jax.numpy as jnp
        d = self.tree.get(path)
        step_mode = step is not None
        clock = step if step_mode else self.tree.now_ms
        chain = list(d.ancestors())
        n = len(chain)
        view = ChainView(
            valid=jnp.ones((n,), bool),
            usage=jnp.asarray([a.usage for a in chain], jnp.int32),
            high=jnp.asarray([a.high for a in chain], jnp.int32),
            max=jnp.asarray([a.max for a in chain], jnp.int32),
            low=jnp.asarray([a.low for a in chain], jnp.int32),
            frozen=jnp.asarray([a.frozen or a.killed for a in chain], bool),
            throttle_until=jnp.asarray([a.throttle_until for a in chain],
                                       jnp.float32),
            priority=jnp.int32(d.priority),
            params=jnp.asarray(self._rows[path], jnp.float32),
            prog_id=jnp.int32(self._pids[path]),
        )
        req = Request(jnp.int32(self._ids[path] % (1 << 30)),
                      jnp.int32(pages),
                      jnp.int32(clock) if step_mode else jnp.float32(clock))
        verdict, delay_ms, throttle = self._decide_fn()(view, req)
        self._rows[path] = np.array(verdict.params)     # writable copy
        # PSI accounting — the same event formula charge_batch scatters
        # on device: a stalled or throttled decision stalls the domain
        # (saturating at INT32_MAX like the traced accumulators)
        if bool(verdict.stall) or bool(throttle):
            d.mem_stall = min(d.mem_stall + 1, P.INT32_MAX)

        # ``delay_ms`` on the ticket = the throttle window now pending on
        # the charged domain, in ms — the device backends' convention
        # (quantized on the step clock, exact on the ms clock)
        def window() -> float:
            w = max(0.0, d.throttle_until - clock)
            return w * self.prog.step_ms if step_mode else w

        if not bool(verdict.grant):
            if d.frozen or d.killed:
                return ChargeTicket(False, True, blocked_by=path,
                                    delay_ms=window())
            blk = self.tree.blocking_ancestor(d, pages)
            if blk is not None:           # hard-max denial: memcg counters
                self.tree.note_max_breach(blk, pages)
                return ChargeTicket(False, True, blocked_by=blk.name,
                                    delay_ms=window())
            # active throttle window or program admission (token bucket)
            return ChargeTicket(False, True, blocked_by=path,
                                delay_ms=window())

        over = self.tree.commit_charge(d, pages)
        dly_ms = float(delay_ms)
        if bool(throttle) and dly_ms > 0:
            if step_mode:                 # quantized, like the device table
                deadline = clock + int(np.ceil(
                    np.float32(dly_ms) / np.float32(self.prog.step_ms)))
            else:
                deadline = clock + dly_ms
            d.throttle_until = max(d.throttle_until, deadline)
            d.n_throttle += 1
            self.log.emit(self.tree.now_ms, Ev.THROTTLE, path,
                          delay_ms=dly_ms)
        return ChargeTicket(True, False, over_high=over,
                            delay_ms=window())

    def uncharge(self, path: str, pages: int) -> None:
        self.tree.uncharge(path, pages)

    def charge_unchecked(self, path: str, pages: int) -> None:
        """Bookkeeping charge for lifecycle moves (residual transfer,
        thaw re-charge): the pages are already resident, never denied."""
        for a in self.tree.get(path).ancestors():
            a.usage = max(0, a.usage + pages)
            a.peak = max(a.peak, a.usage)

    # scheduling (the sched_ext half)
    def schedule(self, paths: list, costs: list, step: int,
                 budget: int) -> list:
        """One weighted scheduling round over the given slots — the
        literal same jitted ``schedule_decision`` the device kernels
        trace, run on a state view assembled from the tree."""
        import jax.numpy as jnp

        from repro.core.sched import jit_schedule
        order = list(self.tree._index)
        row = {p: i for i, p in enumerate(order)}
        doms = [self.tree.get(p) for p in order]
        state = {
            "usage": jnp.asarray([d.usage for d in doms], jnp.int32),
            "high": jnp.asarray([d.high for d in doms], jnp.int32),
            "max": jnp.asarray([d.max for d in doms], jnp.int32),
            "low": jnp.asarray([d.low for d in doms], jnp.int32),
            "parent": jnp.asarray(
                [row.get(parent_path(p), -1) if p != "/" else -1
                 for p in order], jnp.int32),
            "priority": jnp.asarray([d.priority for d in doms], jnp.int32),
            "frozen": jnp.asarray([d.frozen or d.killed for d in doms],
                                  bool),
            "active": jnp.ones((len(order),), bool),
            "throttle_until": jnp.asarray(
                [d.throttle_until for d in doms], jnp.float32),
            "prog": jnp.asarray(np.stack([self._rows[p] for p in order]),
                                jnp.float32),
            "weight": jnp.asarray([d.weight for d in doms], jnp.int32),
            "cpu_max": jnp.asarray([d.cpu_max for d in doms], jnp.int32),
            "flat_weight": jnp.asarray([d.flat_weight for d in doms],
                                       jnp.float32),
            "vruntime": jnp.asarray([d.vruntime for d in doms],
                                    jnp.float32),
            "cpu_used": jnp.asarray([d.cpu_used for d in doms], jnp.int32),
            "cpu_stamp": jnp.asarray([d.cpu_stamp for d in doms],
                                     jnp.int32),
            "cpu_stall": jnp.asarray([d.cpu_stall for d in doms],
                                     jnp.int32),
            "prog_id": jnp.asarray([self._pids[p] for p in order],
                                   jnp.int32),
        }
        dom = jnp.asarray([row[p] for p in paths], jnp.int32)
        cost = jnp.asarray(list(costs), jnp.int32)
        st, advance = jit_schedule(self.progs, state, dom, cost,
                                   int(step), int(budget))
        vr = np.asarray(st["vruntime"])
        used = np.asarray(st["cpu_used"])
        stamp = np.asarray(st["cpu_stamp"])
        stall = np.asarray(st["cpu_stall"])
        for i, d in enumerate(doms):
            d.vruntime = float(vr[i])
            d.cpu_used = int(used[i])
            d.cpu_stamp = int(stamp[i])
            d.cpu_stall = int(stall[i])
        return [bool(a) for a in np.asarray(advance)]

    # subtree control
    def freeze(self, path: str) -> None:
        self.tree.freeze(path)

    def thaw(self, path: str) -> None:
        self.tree.thaw(path)

    def kill(self, path: str) -> int:
        return self.tree.kill(path)

    # control files
    def read(self, path: str, file: str):
        d = self.tree.get(path)
        if file == "memory.current":
            return d.usage
        if file == "memory.peak":
            return d.peak
        if file == "memory.high":
            return d.high
        if file == "memory.max":
            return d.max
        if file == "memory.low":
            return d.low
        if file == "memory.priority":
            return d.priority
        if file == "cgroup.freeze":
            return int(d.frozen)
        if file == "cpu.weight":
            return d.weight
        if file == "cpu.max":
            return d.cpu_max
        if file == "memory.events":
            return {"high": d.n_high_breach, "max": d.n_max_breach,
                    "throttle": d.n_throttle, "oom_kill": d.n_oom_kill}
        if file in P.STALL_FILES:
            attr = "mem_stall" if file == "memory.stall" else "cpu_stall"
            return P.subtree_counts_by_path(
                {n.name: getattr(n, attr)
                 for n in self.tree.subtree(path)})[path]
        raise KeyError(file)

    def write(self, path: str, file: str, value) -> None:
        d = self.tree.get(path)
        if file == "memory.high":
            d.high = int(value)
        elif file == "memory.max":
            d.max = int(value)
        elif file == "memory.low":
            d.low = int(value)
        elif file == "memory.priority":
            d.priority = int(value)
        elif file == "cgroup.freeze":
            (self.freeze if int(value) else self.thaw)(path)
        elif file == "cpu.weight":
            from repro.core.sched import check_weight
            d.weight = check_weight(value)
            self._recompute_flat()
        elif file == "cpu.max":
            d.cpu_max = int(value)
        else:
            raise KeyError(file)

    def throttle_delay_ms(self, path: str, **kw) -> float:
        return self.tree.throttle_delay_ms(path, **kw)

    def snapshot(self) -> dict:
        idx = self.tree._index
        order = list(idx)
        usage = np.array([idx[p].usage for p in order], np.int64)
        high = np.array([idx[p].high for p in order], np.int64)
        maxl = np.array([idx[p].max for p in order], np.int64)
        prow = {p: i for i, p in enumerate(order)}
        parent = np.array([prow.get(parent_path(p), -1) if p != "/" else -1
                           for p in order], np.int64)
        active = np.ones(len(order), bool)
        params = np.stack([self._rows[p] for p in order])
        return {"paths": order, "index": prow, "usage": usage, "high": high,
                "max": maxl, "parent": parent, "active": active,
                "params": params,
                "peak": np.array([idx[p].peak for p in order], np.int64),
                "low": np.array([idx[p].low for p in order], np.int64),
                "priority": np.array([idx[p].priority for p in order],
                                     np.int64),
                "frozen": np.array([idx[p].frozen for p in order], bool),
                "killed": np.array([idx[p].killed for p in order], bool),
                "throttle_until": np.array([idx[p].throttle_until
                                            for p in order]),
                "weight": np.array([idx[p].weight for p in order], np.int64),
                "cpu_max": np.array([idx[p].cpu_max for p in order],
                                    np.int64),
                "vruntime": np.array([idx[p].vruntime for p in order],
                                     np.float32),
                "cpu_used": np.array([idx[p].cpu_used for p in order],
                                     np.int64),
                "cpu_stamp": np.array([idx[p].cpu_stamp for p in order],
                                      np.int64),
                "mem_stall": np.array([idx[p].mem_stall for p in order],
                                      np.int64),
                "cpu_stall": np.array([idx[p].cpu_stall for p in order],
                                      np.int64),
                "prog_id": np.array([self._pids[p] for p in order],
                                    np.int64),
                "root_usage": self.tree.root.usage}

    def restore(self, snap: dict) -> None:
        """Rebuild the full control state from a ``snapshot()`` dict —
        the crash-recovery path: a poisoned async daemon is closed and
        a freshly constructed backend resumes from the last good
        snapshot.  Call after ``attach`` (parameter rows are restored
        verbatim from the snapshot, overwriting attach's defaults)."""
        idx = snap["index"]
        zeros = np.zeros(len(snap["paths"]), bool)
        killed = snap.get("killed", zeros)
        frozen = snap.get("frozen", zeros)
        for p in snap["paths"]:           # parents precede children
            if p != "/" and not self.tree.exists(p):
                self.mkdir(p, DomainSpec())
            d = self.tree.root if p == "/" else self.tree.get(p)
            i = idx[p]
            d.high = int(snap["high"][i])
            d.max = int(snap["max"][i])
            d.usage = int(snap["usage"][i])
            d.throttle_until = float(snap["throttle_until"][i])
            d.frozen = bool(frozen[i])
            d.killed = bool(killed[i])
            if "peak" in snap:
                d.peak = int(snap["peak"][i])
                d.low = int(snap["low"][i])
                d.priority = int(snap["priority"][i])
            if "weight" in snap:
                d.weight = int(snap["weight"][i])
                d.cpu_max = int(snap["cpu_max"][i])
                d.vruntime = float(snap["vruntime"][i])
                d.cpu_used = int(snap["cpu_used"][i])
                d.cpu_stamp = int(snap["cpu_stamp"][i])
            if "mem_stall" in snap:       # older snapshots: counters stay 0
                d.mem_stall = int(snap["mem_stall"][i])
                d.cpu_stall = int(snap["cpu_stall"][i])
            self._rows[p] = np.asarray(snap["params"][i]).copy()
            pid = snap.get("prog_id")
            self._pids[p] = int(pid[i]) if pid is not None else 0
        self._recompute_flat()

    def set_time(self, t: float) -> None:
        self.tree.now_ms = t


# ------------------------------------------------------------------- device


class DeviceView:
    """The jit-safe slice of the device backend: the live state pytree
    plus pure (``lax``-only) enforcement functions the engine's jitted
    step closes over — keeping in-step enforcement fully on device while
    everything stateful goes through the facade."""

    def __init__(self, backend: "DeviceTableBackend"):
        self._backend = backend
        self.cfg = backend.table.cfg

    @property
    def state(self) -> dict:
        return self._backend.table.state

    @property
    def prog(self) -> PolicyProgram:
        """The primary attached program (read at trace time, so a re-jit
        after ``attach`` picks up the new decision code)."""
        return self._backend.table.prog

    @property
    def progs(self) -> tuple:
        """The full program registry (read at trace time)."""
        return self._backend.table.progs

    def charge(self, state, dom, amt, step):
        """In-step hierarchical charge: (state, granted, stalled) —
        dispatched into each domain's registered program."""
        from repro.core import controller as C
        return C.charge_batch(state, dom, amt, step, self.progs)

    def account(self, state, dom, amt):
        """Post-hoc unconditional charge (the user-space baseline:
        usage recorded after the stale gate already decided)."""
        from repro.core import controller as C
        return C.uncharge_batch(state, dom, -amt)

    def uncharge(self, state, dom, amt):
        from repro.core import controller as C
        return C.uncharge_batch(state, dom, amt)

    def gate(self, state, dom, step):
        """Per-slot advance gate (the program's ``on_gate``)."""
        from repro.core import controller as C
        return C.slot_gate(state, dom, step, self.progs)

    def schedule(self, state, dom, cost, step, budget):
        """Weighted per-slot scheduling round: (state, advance) —
        the gate plus cpu.weight fair share and cpu.max throttling."""
        from repro.core import sched as S
        return S.schedule_decision(self.progs, state, dom, cost, step,
                                   budget)

    def commit(self, state: dict) -> None:
        """Adopt the (possibly donated) post-step state."""
        self._backend.table.state = state


class DeviceTableBackend:
    """Device-resident backend: lifecycle host-side, enforcement in-step.

    Wraps ``controller.DeviceDomainTable``.  ``try_charge`` here is the
    *host-driven* path (lifecycle, replay, cross-validation); the
    serving engine charges inside its jitted step through
    ``device_view()`` instead.
    """

    def __init__(self, capacity: int, n_domains: int = 64, cfg=None,
                 log: Optional[EventLog] = None,
                 prog: Optional[PolicyProgram] = None):
        from repro.core.controller import ControllerConfig, DeviceDomainTable
        self.table = DeviceDomainTable(capacity, n_domains,
                                       cfg or ControllerConfig(), prog)
        self.log = log if log is not None else EventLog()
        self._now = 0.0

    @property
    def n_domains(self) -> int:
        return self.table.n

    @property
    def prog(self) -> PolicyProgram:
        return self.table.prog

    @property
    def progs(self) -> tuple:
        return self.table.progs

    def attach(self, scope: str, prog: PolicyProgram) -> None:
        self.table.attach(scope, prog)

    def update_params(self, path: str, kv: dict) -> None:
        self.table.update_params(self._subtree(path), kv)

    def device_view(self) -> DeviceView:
        return DeviceView(self)

    def _recompute_flat(self) -> None:
        """Re-flatten hierarchical weights into the device row
        (lifecycle rate — one host sync, like the other lifecycle ops),
        scx_flatcg style."""
        import jax.numpy as jnp

        from repro.core.sched import flat_weights_by_path
        st = self.table.state
        w = np.asarray(st["weight"])
        flat = flat_weights_by_path(
            {p: int(w[i]) for p, i in self.table.index.items()})
        arr = np.zeros((self.table.n,), np.float32)
        for p, i in self.table.index.items():
            arr[i] = flat[p]
        self.table.state = dict(st, flat_weight=jnp.asarray(arr))

    # lifecycle
    def mkdir(self, path: str, spec: DomainSpec) -> int:
        assert len(ancestor_paths(path)) <= 4, f"{path}: deeper than DEPTH"
        idx = self.table.create(path, high=spec.high, max=spec.max,
                                low=spec.low, priority=spec.priority,
                                weight=spec.weight, cpu_max=spec.cpu_max)
        self._recompute_flat()
        self.log.emit(self._now, Ev.CREATE, path, high=spec.high,
                      max=spec.max)
        return idx

    def rmdir(self, path: str, transfer_residual: bool) -> int:
        residual = self.table.usage(path)
        parent = parent_path(path)
        self.table.remove(path)          # uncharges residual from the chain
        if transfer_residual and residual and parent is not None:
            self.charge_unchecked(parent, residual)
        self._recompute_flat()
        self.log.emit(self._now, Ev.REMOVE, path)
        return residual

    def exists(self, path: str) -> bool:
        return path in self.table.index

    def paths(self) -> list[str]:
        return list(self.table.index)

    def handle(self, path: str) -> int:
        return self.table.index[path]

    def path_of(self, handle: int) -> str:
        for p, i in self.table.index.items():
            if i == handle:
                return p
        raise KeyError(handle)

    # charging (host-driven path)
    def try_charge(self, path: str, pages: int,
                   step: Optional[int]) -> ChargeTicket:
        import jax.numpy as jnp
        from repro.core import controller as C
        if step is None:
            # honor the facade clock so earlier throttles expire
            step = int(self._now)
        idx = self.table.index[path]
        st, granted, stalled = C.charge_batch(
            self.table.state, jnp.array([idx], jnp.int32),
            jnp.array([pages], jnp.int32), step, self.table.progs)
        self.table.state = st
        window = max(0, int(st["throttle_until"][idx]) - step)
        return ChargeTicket(granted=bool(granted[0]),
                            stalled=bool(stalled[0]),
                            delay_ms=window * self.table.prog.step_ms)

    def uncharge(self, path: str, pages: int) -> None:
        import jax.numpy as jnp
        from repro.core import controller as C
        idx = self.table.index[path]
        self.table.state = C.uncharge_batch(
            self.table.state, jnp.array([idx], jnp.int32),
            jnp.array([pages], jnp.int32))

    def charge_unchecked(self, path: str, pages: int) -> None:
        from repro.core import controller as C
        self.table.state = C.host_charge(self.table.state,
                                         self.table.index[path], pages)

    # scheduling (host-driven path; the engine schedules in-step via
    # device_view().schedule)
    def schedule(self, paths: list, costs: list, step: int,
                 budget: int) -> list:
        import jax.numpy as jnp

        from repro.core.sched import jit_schedule
        dom = jnp.asarray([self.table.index[p] for p in paths], jnp.int32)
        cost = jnp.asarray(list(costs), jnp.int32)
        st, advance = jit_schedule(self.table.progs, self.table.state,
                                   dom, cost, int(step), int(budget))
        self.table.state = st
        return [bool(a) for a in np.asarray(advance)]

    # subtree control
    def _subtree(self, path: str) -> list[str]:
        return [p for p in self.table.index if path_in_scope(path, p)]

    def freeze(self, path: str) -> None:
        for p in self._subtree(path):
            self.table.set_frozen(p, True)
        self.log.emit(self._now, Ev.FREEZE, path)

    def thaw(self, path: str) -> None:
        for p in self._subtree(path):
            self.table.set_frozen(p, False)
        self.log.emit(self._now, Ev.THAW, path)

    def kill(self, path: str) -> int:
        """Atomic subtree kill: release the subtree root's hierarchical
        usage from its chain, then retire every node in place.  Mirrors
        the host semantics: killed domains stay registered (``exists``
        is True) and deny further charges — here via the frozen flag,
        the device state's only in-step deny bit."""
        freed = self.table.usage(path)
        if freed:
            self.uncharge(path, freed)
        for p in self._subtree(path):
            idx = self.table.index[p]
            st = self.table.state
            self.table.state = dict(
                st,
                usage=st["usage"].at[idx].set(0),
                active=st["active"].at[idx].set(False),
                frozen=st["frozen"].at[idx].set(True))
        self.log.emit(self._now, Ev.OOM_KILL, path, freed=freed)
        return freed

    # control files
    _FILE_KEY = {"memory.current": "usage", "memory.peak": "peak",
                 "memory.high": "high", "memory.max": "max",
                 "memory.low": "low", "memory.priority": "priority",
                 "cgroup.freeze": "frozen", "cpu.weight": "weight",
                 "cpu.max": "cpu_max"}

    def read(self, path: str, file: str):
        if file == "memory.events":
            # device counters live in-step; only throttle state is
            # observable host-side
            st = self.table.state
            idx = self.table.index[path]
            return {"high": 0, "max": 0,
                    "throttle": int(int(st["throttle_until"][idx]) > 0),
                    "oom_kill": 0}
        if file in P.STALL_FILES:
            key = "mem_stall" if file == "memory.stall" else "cpu_stall"
            col = np.asarray(self.table.state[key])
            return P.subtree_counts_by_path(
                {p: int(col[i]) for p, i in self.table.index.items()
                 if path_in_scope(path, p)})[path]
        idx = self.table.index[path]
        return int(self.table.state[self._FILE_KEY[file]][idx])

    def write(self, path: str, file: str, value) -> None:
        if file == "cgroup.freeze":
            (self.freeze if int(value) else self.thaw)(path)
            return
        if file == "cpu.weight":
            from repro.core.sched import check_weight
            value = check_weight(value)
        idx = self.table.index[path]
        key = self._FILE_KEY[file]
        st = self.table.state
        self.table.state = dict(
            st, **{key: st[key].at[idx].set(int(value))})
        if file == "cpu.weight":
            self._recompute_flat()

    def snapshot(self) -> dict:
        st = self.table.state
        return {"paths": list(self.table.index),
                "index": dict(self.table.index),
                "usage": np.asarray(st["usage"]),
                "high": np.asarray(st["high"]),
                "max": np.asarray(st["max"]),
                "parent": np.asarray(st["parent"]),
                "active": np.asarray(st["active"]),
                "peak": np.asarray(st["peak"]),
                "low": np.asarray(st["low"]),
                "priority": np.asarray(st["priority"]),
                "frozen": np.asarray(st["frozen"]),
                "throttle_until": np.asarray(st["throttle_until"]),
                "params": np.asarray(st["prog"]),
                "weight": np.asarray(st["weight"]),
                "cpu_max": np.asarray(st["cpu_max"]),
                "flat_weight": np.asarray(st["flat_weight"]),
                "vruntime": np.asarray(st["vruntime"]),
                "cpu_used": np.asarray(st["cpu_used"]),
                "cpu_stamp": np.asarray(st["cpu_stamp"]),
                "mem_stall": np.asarray(st["mem_stall"]),
                "cpu_stall": np.asarray(st["cpu_stall"]),
                "prog_id": np.asarray(st["prog_id"]),
                "root_usage": int(st["usage"][0])}

    def restore(self, snap: dict) -> None:
        """Rebuild index + device state from a ``snapshot()`` dict —
        the crash-recovery path (see ``HostTreeBackend.restore``).
        Call on a freshly constructed backend of the same ``n_domains``,
        after ``attach``."""
        import heapq

        import jax.numpy as jnp
        t = self.table
        assert len(snap["usage"]) == t.n, "snapshot/table shape mismatch"
        t.index = dict(snap["index"])
        used = set(t.index.values())
        t._free = [i for i in range(1, t.n) if i not in used]
        heapq.heapify(t._free)
        st = dict(t.state)
        for key, src, dtype in (
                ("usage", "usage", jnp.int32), ("peak", "peak", jnp.int32),
                ("high", "high", jnp.int32), ("max", "max", jnp.int32),
                ("low", "low", jnp.int32), ("parent", "parent", jnp.int32),
                ("priority", "priority", jnp.int32),
                ("frozen", "frozen", jnp.bool_),
                ("active", "active", jnp.bool_),
                ("throttle_until", "throttle_until", jnp.int32),
                ("prog", "params", jnp.float32),
                ("weight", "weight", jnp.int32),
                ("cpu_max", "cpu_max", jnp.int32),
                ("flat_weight", "flat_weight", jnp.float32),
                ("vruntime", "vruntime", jnp.float32),
                ("cpu_used", "cpu_used", jnp.int32),
                ("cpu_stamp", "cpu_stamp", jnp.int32),
                ("mem_stall", "mem_stall", jnp.int32),
                ("cpu_stall", "cpu_stall", jnp.int32),
                ("prog_id", "prog_id", jnp.int32)):
            if src in snap:
                st[key] = jnp.asarray(np.asarray(snap[src]), dtype)
        t.state = st
        if "flat_weight" not in snap:      # older snapshot: re-flatten
            self._recompute_flat()

    def set_time(self, t: float) -> None:
        self._now = t


# ----------------------------------------------------------- intent channel


@dataclass
class Lease:
    """A declared tool-call scope: an ephemeral child domain whose
    ``memory.high`` came from the upward intent hint.  Closing the lease
    removes the domain and moves retained pages up to the parent
    (retry/context accumulation — the paper's residual-transfer rule).

    ``attempt`` counts re-declarations of the same tool call by the
    escalation loop; a kill on the lease's domain marks it ``killed``
    and attaches the typed ``OomEvent`` (semantic OOM feedback)."""
    channel: "IntentChannel"
    tool_id: str
    path: str
    parent: str
    hint: Optional[Hint]
    high: int
    priority: int = D.NORMAL
    max: int = UNLIMITED
    attempt: int = 1
    closed: bool = False
    killed: bool = False
    oom: Optional[OomEvent] = None

    def feedback(self, reason: str, peak: Optional[int] = None,
                 limit: Optional[int] = None) -> Feedback:
        return self.channel.feedback(self.path, reason, peak=peak,
                                     limit=limit)

    def close(self, *, transfer_residual: bool = True) -> int:
        """rmdir the tool domain; returns the residual moved upward.

        The residual transfer is bookkeeping (``charge_unchecked``) —
        the pages are already resident, so unlike a fresh ``try_charge``
        it is never denied and counts no breach events.  The DONE event
        (with ``memory.peak``) lands in the backend's log; on the
        device backend that read costs one host sync, at lifecycle
        rate, not step rate.  A killed lease emits no DONE — the kill
        already emitted OOM_KILL + OOM; close() only reclaims the
        (empty) domain so the tool id can be re-declared."""
        if self.closed:
            return 0
        self.closed = True
        self.channel._open.pop(self.path, None)
        cg = self.channel.cg
        if not cg.exists(self.path):
            return 0
        if not self.killed:
            cg.log.emit(cg.now, Ev.DONE, self.path,
                        peak=cg.read(self.path, "memory.peak"))
        return cg.rmdir(self.path, transfer_residual=transfer_residual)


class IntentChannel:
    """Bidirectional intent coordination bound to one ``AgentCgroup``.

    Upward: ``declare(tool_id, hint)`` opens a per-tool-call child
    domain whose ``memory.high`` derives from the hint (mis-declared
    calls throttle early instead of starving siblings).  Downward:
    ``feedback`` emits the structured record an adaptive agent uses to
    reconstruct its strategy, and any ``kill()`` that lands on an open
    lease produces a typed ``OomEvent`` delivered to the owning session
    (``oom_events``) — the exit-137 -> stderr loop of the paper's §6
    wrapper, made structural.
    """

    def __init__(self, cg: "AgentCgroup"):
        self.cg = cg
        self.n_declared = 0
        self.n_feedbacks = 0
        self._open: dict[str, Lease] = {}        # path -> live lease
        self._oom: dict[str, list] = {}          # session -> [OomEvent]

    def declare(self, tool_id: str, hint: Optional[Hint] = None, *,
                parent: str = "/", priority: int = D.NORMAL,
                high: Optional[int] = None, max: int = UNLIMITED,
                attempt: int = 1) -> Lease:
        if high is None:
            high = hint_to_high(hint)
        path = f"{parent.rstrip('/')}/{tool_id}"
        self.cg.mkdir(path, DomainSpec(high=high, max=max, priority=priority))
        self.n_declared += 1
        lease = Lease(self, tool_id, path, parent, hint, high,
                      priority=priority, max=max, attempt=attempt)
        self._open[path] = lease
        return lease

    def open_leases(self, under: str = "/") -> list[Lease]:
        return [ls for p, ls in self._open.items()
                if path_in_scope(under, p)]

    def feedback(self, path: str, reason: str, *, peak: Optional[int] = None,
                 limit: Optional[int] = None) -> Feedback:
        if peak is None and self.cg.exists(path):
            peak = self.cg.read(path, "memory.peak")
        if limit is None and self.cg.exists(path):
            limit = self.cg.read(path, "memory.high")
            if limit >= UNLIMITED:
                limit = self.cg.read(path, "memory.max")
        fb = make_feedback(path, reason,
                           peak if peak is not None else 0,
                           limit if limit is not None else 0)
        self.n_feedbacks += 1
        self.cg.log.emit(self.cg.now, Ev.FEEDBACK, path, reason=reason)
        return fb

    # ------------------------------------------------- semantic OOM events

    def _pre_kill(self, path: str) -> list[tuple]:
        """Capture (lease, peak, limit, residual) for every open lease
        under ``path`` BEFORE the backend kill zeroes usage."""
        pre = []
        for lease in self.open_leases(path):
            if lease.killed or not self.cg.exists(lease.path):
                continue
            peak = self.cg.read(lease.path, "memory.peak")
            limit = self.cg.read(lease.path, "memory.max")
            if limit >= UNLIMITED:
                limit = self.cg.read(lease.path, "memory.high")
            pre.append((lease, peak, limit, self.cg.usage(lease.path)))
        return pre

    def _post_kill(self, pre: list[tuple]) -> None:
        """Mark the leases killed and deliver typed OomEvents to their
        owning sessions (the lease parent)."""
        for lease, peak, limit, residual in pre:
            ev = OomEvent(path=lease.path, session=lease.parent,
                          peak_pages=int(peak), limit_pages=int(limit),
                          attempt=lease.attempt,
                          residual_pages=int(residual), t_ms=self.cg.now)
            lease.killed = True
            lease.oom = ev
            self._oom.setdefault(lease.parent, []).append(ev)
            self.cg.log.emit(self.cg.now, Ev.OOM, lease.path,
                             session=lease.parent, peak=ev.peak_pages,
                             limit=ev.limit_pages, attempt=ev.attempt,
                             residual=ev.residual_pages)

    def note_external_kill(self, path: str, freed: int = 0) -> None:
        """Record a kill that bypassed the facade (fault injection, a
        backend-side OOM): synthesize the same OomEvents an in-band
        ``AgentCgroup.kill`` would have delivered.  Peak/limit are read
        after the fact (both survive the kill on every backend); usage
        is already zeroed, so the caller supplies ``freed`` as the
        residual when a single lease was hit."""
        pre = self._pre_kill(path)
        if len(pre) == 1 and freed:
            lease, peak, limit, _ = pre[0]
            pre = [(lease, peak, limit, freed)]
        self._post_kill(pre)

    def oom_events(self, session: str, *, clear: bool = False) -> list:
        """Typed OomEvents delivered to ``session`` (oldest first)."""
        evs = self._oom.get(session, [])
        if clear:
            self._oom[session] = []
        return list(evs)


# -------------------------------------------------------------------- facade


class AgentCgroup:
    """The unified control plane: cgroupfs-style files + intent channel
    over a pluggable enforcement backend."""

    def __init__(self, backend: Backend):
        self.backend = backend
        self.intent = IntentChannel(self)
        self._now = 0.0
        # PSI averaging over the backends' raw stall counters; decay
        # runs on the facade clock (set_time) — one meter per facade,
        # so identical op sequences render identical pressure strings
        # on every backend kind
        self._pressure = P.PressureMeter()

    # ------------------------------------------------------------ lifecycle

    def mkdir(self, path: str, spec: Optional[DomainSpec] = None, **kw) -> int:
        """Create a domain; returns the backend handle (slot index)."""
        assert path.startswith("/") and path != "/", path
        spec = spec if spec is not None else DomainSpec(**kw)
        parent = parent_path(path)
        if not self.backend.exists(parent):
            raise FileNotFoundError(f"parent {parent!r} of {path!r}")
        return self.backend.mkdir(path, spec)

    def rmdir(self, path: str, *, transfer_residual: bool = True) -> int:
        """Remove a leaf domain.  By default residual charges transfer
        to the parent (pages outliving the tool call stay accounted to
        the session); with ``transfer_residual=False`` they release."""
        self._pressure.forget(path)
        return self.backend.rmdir(path, transfer_residual)

    def exists(self, path: str) -> bool:
        return self.backend.exists(path)

    def paths(self) -> list[str]:
        return self.backend.paths()

    def handle(self, path: str) -> int:
        return self.backend.handle(path)

    def path_of(self, handle: int) -> str:
        return self.backend.path_of(handle)

    # ------------------------------------------------------------- programs

    @property
    def program(self) -> PolicyProgram:
        """The primary attached enforcement program (memcg_bpf_ops
        analogue) — registry slot 0."""
        return self.backend.prog

    @property
    def programs(self) -> tuple:
        """The full program registry: slot 0 is the primary; subtree
        attaches append further slots, selected per domain by the
        ``prog_id`` control-state column."""
        return tuple(getattr(self.backend, "progs", (self.backend.prog,)))

    def attach(self, path: str, prog: PolicyProgram) -> None:
        """Attach a ``PolicyProgram`` to the subtree at ``path`` — the
        BPF-attach analogue.  A root attach (``path="/"``) resets the
        registry to this one program.  A subtree attach COMPOSES: the
        program takes a registry slot and only in-scope domains dispatch
        into it (via their ``prog_id``), so different tenants run truly
        different enforcement code; domains outside the subtree keep
        their current program and live parameters (the memcg contract
        still applies to them).  Jitted consumers must re-trace
        (``Engine.attach_program`` does).
        """
        assert path == "/" or self.backend.exists(path), path
        self.backend.attach(path, prog)

    def update_params(self, path: str, **kv) -> None:
        """Retune the live program for the subtree at ``path`` — a BPF
        map write: pure state, takes effect next charge, never a
        recompile.  Each domain resolves keys through its own program;
        keys unknown to every registered program raise ``KeyError``.
        """
        self.backend.update_params(path, kv)

    # --------------------------------------------------------- control files

    def read(self, path: str, file: str):
        assert file in _READ_FILES, file
        if file in P.PRESSURE_FILES:
            total = int(self.backend.read(path, P.STALL_OF[file]))
            if self._pressure.auto_step:    # ms clock: track the program
                self._pressure.step_ms = float(self.backend.prog.step_ms)
            return self._pressure.read(path, file, total, self._now)
        return self.backend.read(path, file)

    def write(self, path: str, file: str, value) -> None:
        assert file in _WRITE_FILES, file
        self.backend.write(path, file, value)

    def pressure_clock(self, *, step_quantum: Optional[float] = None,
                       windows: Optional[tuple] = None) -> None:
        """Reconfigure the PSI meter: a caller whose ``set_time`` counts
        steps instead of ms (the serving engine) passes
        ``step_quantum=1.0`` and the decay windows converted to steps;
        ``windows`` alone shortens the averaging horizon (tests,
        fast-reacting controllers) while keeping the ms clock."""
        if step_quantum is not None:
            self._pressure.auto_step = False
            self._pressure.step_ms = float(step_quantum)
        if windows is not None:
            self._pressure.windows = (float(windows[0]), float(windows[1]))

    # -------------------------------------------------------------- charging

    def try_charge(self, path: Union[str, int], pages: int,
                   step: Optional[int] = None) -> ChargeTicket:
        """Hierarchical memcg charge.  ``step`` is the device backend's
        throttle clock; when omitted it falls back to the facade clock
        (``set_time``), so host-driven throttles expire with time."""
        if isinstance(path, int):
            path = self.path_of(path)
        return self.backend.try_charge(path, pages, step)

    def uncharge(self, path: Union[str, int], pages: int) -> None:
        if isinstance(path, int):
            path = self.path_of(path)
        self.backend.uncharge(path, pages)

    def charge_unchecked(self, path: Union[str, int], pages: int) -> None:
        """Lifecycle bookkeeping charge (residual transfer, thaw
        re-charge): the pages are already resident, never denied."""
        if isinstance(path, int):
            path = self.path_of(path)
        self.backend.charge_unchecked(path, pages)

    # ------------------------------------------------------------ scheduling

    def schedule(self, paths: list, costs: list, step: int,
                 budget: int) -> list:
        """One weighted scheduling round (the sched_ext half): slot
        ``i`` runs in domain ``paths[i]`` at step cost ``costs[i]``;
        ``budget`` is the total cost grantable to weighted slots this
        step.  Returns per-slot advance booleans and updates the
        domains' vruntime / cpu.max window accounts.  With the default
        program every runnable slot advances (the old binary gate);
        attach ``WeightedFairProgram`` for cpu.weight-proportional
        sharing."""
        assert len(paths) == len(costs)
        return self.backend.schedule(paths, costs, step, budget)

    # ------------------------------------------------------ subtree control

    def freeze(self, path: str) -> None:
        self.backend.freeze(path)

    def thaw(self, path: str) -> None:
        self.backend.thaw(path)

    def kill(self, path: str) -> int:
        """memory.oom.group analogue.  Any open lease inside the killed
        subtree additionally yields a typed ``OomEvent`` delivered to
        its owning session (semantic OOM feedback, paper §5/§6)."""
        pre = self.intent._pre_kill(path)
        freed = self.backend.kill(path)
        self.intent._post_kill(pre)
        return freed

    # -------------------------------------------------------------- queries

    def usage(self, path: str = "/") -> int:
        return int(self.read(path, "memory.current"))

    def peak(self, path: str = "/") -> int:
        return int(self.read(path, "memory.peak"))

    @property
    def capacity(self) -> int:
        return int(self.read("/", "memory.max"))

    def free(self) -> int:
        return self.capacity - self.usage("/")

    def throttle_delay_ms(self, path: str, **kw) -> float:
        fn = getattr(self.backend, "throttle_delay_ms", None)
        if fn is None:
            raise NotImplementedError(
                "device throttling is computed in-step; use device_view()")
        return fn(path, **kw)

    def snapshot(self) -> dict:
        """Telemetry arrays for host-side daemons (one device sync).

        Row order is backend-specific: the device backend's rows are
        addressable by ``handle()`` (the slot index); for
        backend-agnostic lookup use ``snapshot()['index'][path]``.
        """
        return self.backend.snapshot()

    def restore(self, snap: dict) -> None:
        """Rebuild backend control state from a ``snapshot()`` dict —
        crash recovery onto a freshly constructed backend of the same
        kind (see ``HostTreeBackend.restore``)."""
        self.backend.restore(snap)

    # ----------------------------------------------------------- device path

    def device_view(self) -> DeviceView:
        fn = getattr(self.backend, "device_view", None)
        if fn is None:
            raise NotImplementedError(
                f"{type(self.backend).__name__} has no device state")
        return fn()

    def commit_device(self, state: dict) -> None:
        self.device_view().commit(state)

    # ------------------------------------------------------------------ misc

    def flush(self) -> Optional[int]:
        """Epoch boundary: apply any queued lifecycle ops (async
        backends return the epoch now reflected); a no-op on
        synchronous backends."""
        fn = getattr(self.backend, "flush", None)
        return fn() if fn is not None else None

    @property
    def log(self) -> EventLog:
        return self.backend.log

    @property
    def now(self) -> float:
        return self._now

    def set_time(self, t: float) -> None:
        self._now = t
        self.backend.set_time(t)

    @staticmethod
    def ancestors(path: str) -> list[str]:
        return ancestor_paths(path)

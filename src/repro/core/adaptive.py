"""Closed-loop adaptive retuner driven by pressure (core/pressure.py).

The paper's third mismatch — static, history-sized limits vs
non-deterministic agent executions — calls for a controller that
*observes* contention and reacts, the way userspace PSI consumers
(oomd, senpai) sit on /proc/pressure.  ``AdaptiveController`` closes
that loop using only public surfaces and zero-retrace knobs:

  * it reads ``memory.pressure`` / ``cpu.pressure`` through the facade
    (``parse_psi``), never touching backend internals, so it works
    unmodified on all six backend kinds;
  * sustained memory pressure (``avg10`` above ``high_frac``) bumps
    the domain's soft limit — ``memory.high`` grows by ``bump_factor``
    but NEVER exceeds ``memory.max`` — the classic containers-style
    soft-limit controller move: relieve throttling without weakening
    the hard isolation wall;
  * sustained CPU pressure applies the configured parameter retunes
    (e.g. ``sched_boost``) via ``update_params`` — a pure device state
    write, no retrace;
  * when ``avg10`` falls back below ``low_frac`` the knob is restored,
    with hysteresis (the [low_frac, high_frac] dead band) and a
    per-domain ``cooldown_ms`` so the loop cannot oscillate
    step-to-step.

Every action is emitted as a typed ``PressureEvent`` (and an
``Ev.PRESSURE`` log record), so benchmarks and the conformance kit can
replay exactly what the retuner did and when.  All decisions run off
the caller-supplied clock (the facade / step clock) — never wall time
— keeping replay deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.core.domains import UNLIMITED
from repro.core.events import Ev, PressureEvent
from repro.core.pressure import parse_psi


@dataclass(frozen=True)
class AdaptiveConfig:
    """Retuner policy.  ``None`` at the engine level (the default)
    disables the loop entirely — behavior stays bit-identical."""
    high_frac: float = 0.15        # act when avg10 rises above this
    low_frac: float = 0.05         # restore when avg10 falls below this
    bump_factor: float = 1.5       # memory.high multiplier per bump
    max_bumps: int = 3             # bump ceiling per domain
    cooldown_ms: float = 200.0     # min clock between actions per domain
    # (param, pressured_value, calm_value) triples applied via
    # update_params on sustained CPU pressure and restored when calm —
    # calm values are declared, not read back, so no param introspection
    retune: tuple = ()
    # domains to watch; None = every child of "/" at poll time
    watch: Optional[tuple] = None


class AdaptiveController:
    """The closed loop: poll pressure, turn knobs, emit events.

    One instance per facade.  ``poll(now_ms)`` is cheap enough to run
    at step boundaries (host-driven lifecycles) or at the async
    daemon's epoch cadence; it returns the typed actions it took.
    """

    def __init__(self, cg, cfg: Optional[AdaptiveConfig] = None):
        self.cg = cg
        self.cfg = cfg or AdaptiveConfig()
        self.events: list[PressureEvent] = []
        self._bumps: dict = {}         # path -> (original_high, n_bumps)
        self._retuned: set = set()     # paths with pressured params live
        self._last: dict = {}          # (path, file) -> last action clock

    # ------------------------------------------------------------- helpers

    def _watched(self) -> list:
        if self.cfg.watch is not None:
            return [p for p in self.cfg.watch if self.cg.exists(p)]
        return [p for p in self.cg.paths()
                if p != "/" and "/" not in p.strip("/")]

    def _cooled(self, path: str, file: str, now: float) -> bool:
        last = self._last.get((path, file))
        return last is None or now - last >= self.cfg.cooldown_ms

    def _emit(self, now: float, path: str, file: str, avg10: float,
              action: str, old: float, new: float) -> PressureEvent:
        ev = PressureEvent(path=path, file=file, avg10=avg10,
                           action=action, old=old, new=new, t_ms=now)
        self.events.append(ev)
        self.cg.log.emit(now, Ev.PRESSURE, path, file=file,
                         avg10=round(avg10, 6), action=action,
                         old=old, new=new)
        self._last[(path, file)] = now
        return ev

    # ------------------------------------------------------------ the loop

    def poll(self, now_ms: float) -> list:
        out = []
        for path in self._watched():
            out.extend(self._poll_memory(path, now_ms))
            if self.cfg.retune:
                out.extend(self._poll_cpu(path, now_ms))
        return out

    def _poll_memory(self, path: str, now: float) -> list:
        cfg = self.cfg
        psi = parse_psi(self.cg.read(path, "memory.pressure"))
        avg10 = psi["avg10"]
        if avg10 >= cfg.high_frac:
            if not self._cooled(path, "memory.pressure", now):
                return []
            high = int(self.cg.read(path, "memory.high"))
            if high >= UNLIMITED:          # nothing to relieve
                return []
            orig, n = self._bumps.get(path, (high, 0))
            if n >= cfg.max_bumps:
                return []
            cap = int(self.cg.read(path, "memory.max"))
            new = min(int(high * cfg.bump_factor), cap)   # never past max
            if new <= high:
                return []
            self.cg.write(path, "memory.high", new)
            self._bumps[path] = (orig, n + 1)
            return [self._emit(now, path, "memory.pressure", avg10,
                               "bump_high", float(high), float(new))]
        if avg10 <= cfg.low_frac and path in self._bumps:
            if not self._cooled(path, "memory.pressure", now):
                return []
            orig, _ = self._bumps.pop(path)
            high = int(self.cg.read(path, "memory.high"))
            self.cg.write(path, "memory.high", orig)
            return [self._emit(now, path, "memory.pressure", avg10,
                               "restore_high", float(high), float(orig))]
        return []

    def _poll_cpu(self, path: str, now: float) -> list:
        cfg = self.cfg
        psi = parse_psi(self.cg.read(path, "cpu.pressure"))
        avg10 = psi["avg10"]
        if avg10 >= cfg.high_frac and path not in self._retuned:
            if not self._cooled(path, "cpu.pressure", now):
                return []
            self.cg.update_params(
                path, {k: v for k, v, _ in cfg.retune})
            self._retuned.add(path)
            k, v, old = cfg.retune[0]
            return [self._emit(now, path, "cpu.pressure", avg10,
                               "retune", float(old), float(v))]
        if avg10 <= cfg.low_frac and path in self._retuned:
            if not self._cooled(path, "cpu.pressure", now):
                return []
            self.cg.update_params(
                path, {k: calm for k, _, calm in cfg.retune})
            self._retuned.discard(path)
            k, v, calm = cfg.retune[0]
            return [self._emit(now, path, "cpu.pressure", avg10,
                               "restore_params", float(v), float(calm))]
        return []

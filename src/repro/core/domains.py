"""Hierarchical resource domains — the cgroup v2 analogue.

The tree mirrors cgroup v2 semantics with *pages* (KV-cache pages /
MB in trace replay) as the charge unit:

  * charges propagate to every ancestor (memcg hierarchical accounting);
  * ``max`` is a hard wall: a charge that would cross ANY ancestor's
    ``max`` fails atomically (nothing is committed) — the memcg
    try_charge contract;
  * ``high`` is a soft throttle point: charges succeed but the breach is
    reported so the controller can apply allocator delays
    (memory.high + memcg_bpf_ops.get_high_delay_ms);
  * ``low`` is protection: while a domain is below ``low``, the
    controller refrains from throttling/reclaiming it when *siblings*
    cause pressure (memory.low / the paper's ``below_low`` guard);
  * ``freeze``/``thaw`` stop a subtree (cgroup.freeze);
  * ``kill`` atomically removes a subtree's charges (cgroup.kill +
    memory.oom.group — no partial failures).

This pure-python tree is the reference implementation used by the trace
replay benchmarks; ``core/controller.py`` holds the device-resident
(jax) mirror used inside the serving engine's jitted step.  A hypothesis
test cross-validates the two on random operation sequences.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Optional

from repro.core.events import Ev, EventLog

UNLIMITED = (1 << 31) - 1          # int32-safe "no limit" sentinel

# priorities
LOW, NORMAL, HIGH = 0, 1, 2

# cpu.weight default (cgroup v2: weights in [1, 10000], default 100)
DEFAULT_WEIGHT = 100

# Graduated-throttle defaults (get_high_delay_ms curve) — the single
# source for ``ControllerConfig``, ``GraduatedThrottleProgram``, and the
# host tree's reference ``throttle_delay_ms``.
BASE_DELAY_MS = 10.0
MAX_DELAY_MS = 2000.0
OVERAGE_GAIN = 10.0
HIGH_PRIORITY_DISCOUNT = 0.1


@dataclass
class Domain:
    name: str                      # full path, e.g. "/t0/sess1/tool_7"
    parent: Optional["Domain"]
    high: int = UNLIMITED          # soft limit (pages)
    max: int = UNLIMITED           # hard limit (pages)
    low: int = 0                   # protected floor (pages)
    priority: int = NORMAL
    usage: int = 0
    peak: int = 0
    frozen: bool = False
    killed: bool = False
    # CPU scheduling (cpu.weight / cpu.max — the sched_ext half)
    weight: int = DEFAULT_WEIGHT   # cpu.weight (1..10000)
    cpu_max: int = UNLIMITED       # cpu.max: step-cost quota per window
    flat_weight: float = 0.0       # flattened hierarchical weight (root 1.0)
    vruntime: float = 0.0          # weighted-fair account
    cpu_used: int = 0              # window usage (lazy reset via stamp)
    cpu_stamp: int = -1            # window index cpu_used belongs to
    # program-imposed throttle deadline (clock units of the caller —
    # see HostTreeBackend.try_charge); DomainTree itself never gates on
    # it, the attached PolicyProgram does
    throttle_until: float = 0.0
    children: dict = field(default_factory=dict)
    # event counters (memory.events analogue)
    n_high_breach: int = 0
    n_max_breach: int = 0
    n_throttle: int = 0
    n_oom_kill: int = 0
    # PSI stall-event counters (memory.pressure / cpu.pressure, see
    # core/pressure.py) — local to the domain; subtree aggregation
    # happens host-side at read rate
    mem_stall: int = 0
    cpu_stall: int = 0

    def ancestors(self) -> Iterable["Domain"]:
        d: Optional[Domain] = self
        while d is not None:
            yield d
            d = d.parent

    @property
    def depth(self) -> int:
        return 0 if self.parent is None else self.parent.depth + 1

    @property
    def over_high(self) -> int:
        return max(0, self.usage - self.high)

    @property
    def protected(self) -> bool:
        return self.usage <= self.low


@dataclass
class ChargeResult:
    ok: bool
    blocked_by: Optional[str] = None        # domain whose max blocked it
    over_high: tuple = ()                   # domains whose high is breached


class DomainTree:
    def __init__(self, capacity: int, log: Optional[EventLog] = None):
        """capacity: root hard limit (total pool pages)."""
        self.root = Domain("/", None, max=capacity, high=capacity)
        self._index: dict[str, Domain] = {"/": self.root}
        self.log = log if log is not None else EventLog()
        self.now_ms = 0.0

    # ------------------------------------------------------------ lifecycle

    def create(self, path: str, *, high: int = UNLIMITED, max: int = UNLIMITED,
               low: int = 0, priority: int = NORMAL,
               weight: int = DEFAULT_WEIGHT,
               cpu_max: int = UNLIMITED) -> Domain:
        assert path.startswith("/") and path not in self._index, path
        parent_path = path.rsplit("/", 1)[0] or "/"
        parent = self._index[parent_path]
        d = Domain(path, parent, high=high, max=max, low=low,
                   priority=priority, weight=weight, cpu_max=cpu_max)
        parent.children[path] = d
        self._index[path] = d
        self.log.emit(self.now_ms, Ev.CREATE, path, high=high, max=max)
        return d

    def remove(self, path: str) -> None:
        """Remove an (empty) domain, returning residual charges upward."""
        d = self._index[path]
        assert not d.children, f"{path} has children"
        if d.usage:
            self._uncharge_from(d, d.usage)
        del d.parent.children[path]
        del self._index[path]
        self.log.emit(self.now_ms, Ev.REMOVE, path)

    def get(self, path: str) -> Domain:
        return self._index[path]

    def exists(self, path: str) -> bool:
        return path in self._index

    def subtree(self, path: str) -> list[Domain]:
        d = self._index[path]
        out = [d]
        stack = list(d.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    # ------------------------------------------------------------- charging

    def blocking_ancestor(self, d: Domain, pages: int) -> Optional[Domain]:
        """First (self-first) ancestor whose ``max`` the charge would
        cross, or None."""
        for a in d.ancestors():
            if a.usage + pages > a.max:
                return a
        return None

    def note_max_breach(self, a: Domain, pages: int) -> None:
        """memcg event bookkeeping for a hard-``max`` denial."""
        a.n_max_breach += 1
        self.log.emit(self.now_ms, Ev.MAX_BREACH, a.name,
                      want=pages, usage=a.usage, max=a.max)

    def commit_charge(self, d: Domain, pages: int) -> tuple:
        """Commit a granted charge up the chain: usage/peak plus the
        ``high``-breach counters and event.  Returns the over-``high``
        domain names.  Shared by ``try_charge`` and the program-driven
        ``HostTreeBackend`` — one copy of the memcg bookkeeping."""
        over = []
        for a in d.ancestors():
            a.usage += pages
            a.peak = max(a.peak, a.usage)
            if a.usage > a.high:
                a.n_high_breach += 1
                over.append(a.name)
        if over:
            self.log.emit(self.now_ms, Ev.HIGH_BREACH, over[0],
                          domains=tuple(over), want=pages)
        return tuple(over)

    def try_charge(self, path: str, pages: int) -> ChargeResult:
        """Atomic hierarchical charge (memcg try_charge contract)."""
        d = self._index[path]
        if d.frozen or d.killed:
            return ChargeResult(False, blocked_by=path)
        blk = self.blocking_ancestor(d, pages)
        if blk is not None:
            self.note_max_breach(blk, pages)
            return ChargeResult(False, blocked_by=blk.name)
        return ChargeResult(True, over_high=self.commit_charge(d, pages))

    def uncharge(self, path: str, pages: int) -> None:
        self._uncharge_from(self._index[path], pages)

    def _uncharge_from(self, d: Domain, pages: int) -> None:
        pages = min(pages, d.usage)
        for a in d.ancestors():
            a.usage = max(0, a.usage - pages)

    # ------------------------------------------------------ freeze / kill

    def freeze(self, path: str) -> None:
        for d in self.subtree(path):
            d.frozen = True
        self.log.emit(self.now_ms, Ev.FREEZE, path)

    def thaw(self, path: str) -> None:
        for d in self.subtree(path):
            d.frozen = False
        self.log.emit(self.now_ms, Ev.THAW, path)

    def kill(self, path: str) -> int:
        """Atomic subtree kill (memory.oom.group): releases all charges.
        Returns pages freed."""
        d = self._index[path]
        freed = d.usage
        self._uncharge_from(d, d.usage)
        for n in self.subtree(path):
            n.killed = True
            n.usage = 0
            n.n_oom_kill += 1
        self.log.emit(self.now_ms, Ev.OOM_KILL, path, freed=freed)
        return freed

    # ----------------------------------------------------------- queries

    def free(self) -> int:
        return self.root.max - self.root.usage

    def usage(self, path: str = "/") -> int:
        return self._index[path].usage

    def throttle_delay_ms(self, path: str, *,
                          base_delay_ms: float = BASE_DELAY_MS,
                          max_delay_ms: float = MAX_DELAY_MS) -> float:
        """get_high_delay_ms analogue: graduated delay for over-``high``
        domains, scaled by relative overage, respecting ``low``
        protection and priority."""
        d = self._index[path]
        worst = 0.0
        for a in d.ancestors():
            if a.high >= UNLIMITED or a.usage <= a.high:
                continue
            if a.protected:
                continue
            over = (a.usage - a.high) / max(a.high, 1)
            delay = min(max_delay_ms,
                        base_delay_ms * (1.0 + OVERAGE_GAIN * over))
            worst = max(worst, delay)
        if worst and d.priority == HIGH:
            worst *= HIGH_PRIORITY_DISCOUNT   # latency-sensitive domains barely stall
        if worst:
            d.n_throttle += 1
            self.log.emit(self.now_ms, Ev.THROTTLE, path, delay_ms=worst)
        return worst

"""Resource-control event log (the analogue of cgroup event counters +
AgentSight-style observability).

Every enforcement action — soft/hard breaches, throttles, freezes,
OOM kills, intent feedback — is appended here with a timestamp, so
benchmarks can reconstruct exactly what the controller did and when.
"""
from __future__ import annotations

import collections
import enum
from dataclasses import dataclass, field
from typing import Any, Iterable, Optional


class Ev(enum.Enum):
    CREATE = "create"
    REMOVE = "remove"
    CHARGE = "charge"
    CHARGE_FAIL = "charge_fail"
    HIGH_BREACH = "high_breach"     # soft limit crossed (memory.events high)
    MAX_BREACH = "max_breach"       # hard limit would be crossed
    THROTTLE = "throttle"           # allocation delayed (get_high_delay)
    FREEZE = "freeze"               # cgroup.freeze analogue
    THAW = "thaw"
    OOM_KILL = "oom_kill"           # memory.oom.group analogue
    EVICT = "evict"
    FEEDBACK = "feedback"           # downward intent channel fired
    ADMIT = "admit"
    DONE = "done"
    OOM = "oom"                     # semantic OOM delivered to a session
    REBUILD = "rebuild"             # backend rebuilt from snapshot
    PRESSURE = "pressure"           # adaptive retuner acted on PSI


@dataclass
class Event:
    t_ms: float
    kind: Ev
    domain: str
    detail: dict = field(default_factory=dict)


@dataclass(frozen=True)
class OomEvent:
    """Typed semantic OOM: what the agent's wrapper would parse out of
    an exit-137 + memcg ``memory.events`` read (the paper's §6
    ``bash_wrapper.sh`` loop), delivered in-band to the owning session
    so it can negotiate a retry instead of silently losing the call.
    """
    path: str                   # killed tool domain
    session: str                # owning session domain (lease parent)
    peak_pages: int             # memory.peak at kill time
    limit_pages: int            # the limit that triggered the kill
    attempt: int                # 1-based attempt number of the lease
    residual_pages: int         # pages freed by the kill (work discarded)
    t_ms: float = 0.0

    def render(self) -> str:
        return (f"[agentcgroup] OOM: {self.path} attempt {self.attempt} "
                f"killed at peak {self.peak_pages} pages "
                f"(limit {self.limit_pages}); {self.residual_pages} pages "
                f"of work discarded")


@dataclass(frozen=True)
class PressureEvent:
    """Typed adaptive-retune action: the closed-loop controller
    (``core/adaptive.py``) observed sustained pressure on a domain and
    turned a zero-retrace knob — a soft-limit bump, a parameter
    retune, or the reverse once pressure subsided."""
    path: str                   # domain acted on
    file: str                   # pressure file that triggered ("memory.pressure" / "cpu.pressure")
    avg10: float                # [0, 1] stall fraction at decision time
    action: str                 # "bump_high" | "restore_high" | "retune" | "restore_params"
    old: float                  # knob value before
    new: float                  # knob value after
    t_ms: float = 0.0

    def render(self) -> str:
        return (f"[agentcgroup] PRESSURE: {self.path} {self.file} "
                f"avg10={self.avg10 * 100.0:.2f}% -> {self.action} "
                f"{self.old:g} -> {self.new:g}")


class EventLog:
    def __init__(self) -> None:
        self.events: list[Event] = []

    def emit(self, t_ms: float, kind: Ev, domain: str, **detail) -> None:
        self.events.append(Event(t_ms, kind, domain, detail))

    def count(self, kind: Ev, domain_prefix: str = "") -> int:
        return sum(1 for e in self.events
                   if e.kind is kind and e.domain.startswith(domain_prefix))

    def of(self, kind: Ev, domain_prefix: str = "") -> list[Event]:
        return [e for e in self.events
                if e.kind is kind and e.domain.startswith(domain_prefix)]

    def counts(self) -> dict[str, int]:
        c: collections.Counter = collections.Counter(e.kind.value
                                                     for e in self.events)
        return dict(c)

    def clear(self) -> None:
        self.events.clear()

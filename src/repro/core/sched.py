"""Hierarchical weighted step scheduler — the sched_ext/scx_flatcg half.

The paper's in-kernel enforcement has two halves: memcg_bpf_ops (the
charge path, ``core/progs.py``) and sched_ext — the reference daemon
launches ``scx_flatcg`` to schedule CPU through cgroup weights.  This
module is the in-repo analogue: it turns the binary ``slot_gate`` into
a weighted step scheduler that allocates decode slots and prefill
budget proportionally to *flattened hierarchical weights*.

Like flatcg, the hierarchy is flattened ahead of time: a domain's
``flat_weight`` is the product of (own weight / sibling weight sum)
along its path, recomputed host-side at lifecycle rate (mkdir / rmdir /
``cpu.weight`` writes) into a ``(n_domains,)`` f32 row of the control
state — so a weight write is a pure state write and never retraces the
step function.  Per-step scheduling then needs no tree walk:

  1. every slot asks its program for a scheduling weight
     (``on_schedule``; ``<= 0`` means "outside the weighted scheduler"
     — the slot advances whenever the gate allows, without consuming
     budget, which is exactly the old binary gate);
  2. runnable weighted slots are ranked by their domain's ``vruntime``
     (a fairness account: granted slots pay ``cost / weight``, so
     low-weight domains age faster), ties broken by slot index;
  3. grants are taken greedily until the step ``budget`` is spent;
  4. ``cpu.max`` acts as a hard per-window throttle: a domain whose
     window usage (self or any ancestor) has reached its quota is not
     runnable until the window rolls over (lazy stamp reset).

A waking domain's lag is clamped to ``sched_lag`` behind the current
minimum, so a bursty domain that idled does not return with unbounded
credit and starve steady ones — the vruntime floor EEVDF/CFS apply.

Every backend runs the SAME ``schedule_decision``: host-side through
the shared jitted entry point, the device table inside the jitted
engine step, the sharded table per shard under ``shard_map``, and the
async daemon passes it through to its inner backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import domains as D
from repro.core.controller import (DEPTH, UNLIMITED, _ancestor_chain,
                                   _chain_view)
from repro.core.pressure import saturating_count, sched_stall_events
from repro.core.progs import (GraduatedThrottleProgram, SchedRequest,
                              SchedView, as_programs, gate_decision,
                              schedule_weight)

DEFAULT_WEIGHT = D.DEFAULT_WEIGHT
MIN_WEIGHT, MAX_WEIGHT = 1, 10000


def check_weight(value: int) -> int:
    v = int(value)
    if not (MIN_WEIGHT <= v <= MAX_WEIGHT):
        raise ValueError(f"cpu.weight must be in "
                         f"[{MIN_WEIGHT}, {MAX_WEIGHT}], got {value}")
    return v


def flat_weights_by_path(weights: dict) -> dict:
    """Flatten the hierarchy the way scx_flatcg does: ``flat(d) =
    flat(parent) * weight(d) / sum(sibling weights)``, root 1.0.

    ``weights`` maps every live path to its ``cpu.weight``.  Pure host
    math over the logical tree (NOT the device arrays), so every
    backend — including the sharded one, whose per-shard tables only
    see a slice of the tree — stores identical values.  Sibling sums
    are integer sums; the division result is cast to f32 exactly once,
    keeping the row bit-identical across backends.
    """
    kids: dict = {}
    for p in weights:
        if p != "/":
            kids.setdefault(p.rsplit("/", 1)[0] or "/", []).append(p)
    flat = {"/": np.float32(1.0)}
    stack = ["/"]
    while stack:
        q = stack.pop()
        ch = sorted(kids.get(q, []))
        tot = sum(weights[c] for c in ch)
        for c in ch:
            flat[c] = np.float32(float(flat[q]) * weights[c] / tot)
            stack.append(c)
    return flat


def schedule_decision(prog, state: dict, dom: jax.Array, cost: jax.Array,
                      step, budget):
    """One scheduling round, shared verbatim by every backend.

    ``dom[i]``/``cost[i]`` describe slot ``i`` (-1 = empty slot);
    ``budget`` is the total step cost grantable to *weighted* slots.
    Returns ``(new_state, advance)`` where ``advance[i]`` says slot
    ``i`` may run this step.  Deterministic: vruntime ranking with
    slot-index tie-break, quota checked against pre-step window usage.
    """
    progs = as_programs(prog)
    dom = dom.astype(jnp.int32)
    cost = cost.astype(jnp.int32)
    step = jnp.asarray(step, jnp.int32)
    window = step // progs[0].sched_window
    eff_used = jnp.where(state["cpu_stamp"] == window, state["cpu_used"], 0)

    def per_slot(d, a):
        view = _chain_view(state, state["usage"], state["throttle_until"],
                           state["prog"], d)
        gate = (d >= 0) & gate_decision(progs, view, step)
        chain = _ancestor_chain(state["parent"], jnp.maximum(d, 0))
        cvalid = (chain >= 0) & (d >= 0)
        cidx = jnp.maximum(chain, 0)
        capped = cvalid & (state["cpu_max"][cidx] < UNLIMITED)
        quota_ok = ~jnp.any(capped & (eff_used[cidx]
                                      >= state["cpu_max"][cidx]))
        di = jnp.maximum(d, 0)
        sview = SchedView(
            valid=cvalid,
            frozen=jnp.where(cvalid, state["frozen"][cidx], False),
            throttle_until=jnp.where(cvalid,
                                     state["throttle_until"][cidx], 0),
            weight=state["weight"][di],
            flat_weight=state["flat_weight"][di],
            vruntime=state["vruntime"][di],
            priority=state["priority"][di],
            params=state["prog"][di],
            prog_id=state["prog_id"][di],
        )
        w = jnp.asarray(schedule_weight(progs, sview,
                                        SchedRequest(d, a, step)),
                        jnp.float32)
        return gate & quota_ok, w

    runnable, w = jax.vmap(per_slot)(dom, cost)
    weighted = runnable & (w > 0)
    bypass = runnable & (w <= 0)

    m = dom.shape[0]
    di = jnp.maximum(dom, 0)
    key = jnp.where(weighted, state["vruntime"][di], jnp.inf)
    order = jnp.lexsort((jnp.arange(m), key))
    cum = jnp.cumsum(jnp.where(weighted, cost, 0)[order])
    granted = jnp.zeros((m,), bool).at[order].set(
        weighted[order] & (cum <= jnp.asarray(budget, jnp.int32)))
    advance = granted | bypass

    # fairness account: granted weighted slots pay cost / weight
    pay = jnp.where(granted, cost.astype(jnp.float32)
                    / jnp.maximum(w, 1e-9), 0.0)
    vr = state["vruntime"].at[di].add(jnp.where(dom >= 0, pay, 0.0))
    # lag clamp: nobody trails the pack by more than sched_lag
    vmin = jnp.min(jnp.where(weighted, vr[di], jnp.inf),
                   initial=jnp.inf)   # identity: m may be 0 (no slots)
    floor = jnp.where(jnp.any(weighted),
                      vmin - jnp.float32(progs[0].sched_lag), -jnp.inf)
    vr = jnp.where(state["active"], jnp.maximum(vr, floor), vr)

    # cpu.max window accounting: advancing slots charge their chain
    chains = jax.vmap(lambda d: _ancestor_chain(
        state["parent"], jnp.maximum(d, 0)))(dom)
    cvalid = (chains >= 0) & (dom >= 0)[:, None] & advance[:, None]
    add = jnp.where(cvalid, cost[:, None], 0)
    used = eff_used.at[jnp.maximum(chains, 0).reshape(-1)].add(
        add.reshape(-1))
    # PSI accounting: each valid slot that may not advance — gated,
    # quota-capped, or beaten in the budget race — is one CPU-stall
    # event on its domain (core/pressure.py); slots may share a domain,
    # so gather the per-round increments first and saturate the whole
    # row at INT32_MAX (never wrap negative)
    stall_inc = jnp.zeros_like(state["cpu_stall"]).at[di].add(
        jnp.where(dom >= 0, sched_stall_events(dom, advance), 0))
    cpu_stall = saturating_count(state["cpu_stall"], stall_inc)
    new_state = dict(state, vruntime=vr, cpu_used=used,
                     cpu_stamp=jnp.full_like(state["cpu_stamp"], window),
                     cpu_stall=cpu_stall)
    return new_state, advance


# one shared jitted entry point for every host-path caller — host tree,
# device table, sharded reconciliation — so they trace identical code
jit_schedule = jax.jit(schedule_decision, static_argnums=(0,))


class WeightedFairProgram(GraduatedThrottleProgram):
    """The stock weighted-fair scheduler program: weighted slots get
    their domain's flattened hierarchical weight scaled by a live
    ``sched_boost`` (power of two, 0 = neutral) — the zero-retrace
    retune knob.  ``sched_on`` gates the scheduler per domain so the
    neutral row (outside the attach scope) degrades to the trivial
    bypass program, like every other stock program's neutral row."""

    param_names = GraduatedThrottleProgram.param_names + (
        "sched_boost", "sched_on")

    def default_row(self) -> np.ndarray:
        return np.concatenate([super().default_row(),
                               np.asarray([0.0, 1.0], np.float32)])

    # neutral_row: inherited all-zeros — sched_on 0 disables weighting

    def on_schedule(self, view, req):
        w = view.flat_weight * jnp.exp2(view.params[4])
        return jnp.where(view.params[5] > 0, w, jnp.float32(0.0))

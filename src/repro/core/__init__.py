"""AgentCgroup core: the paper's contribution, ported to a multi-tenant
JAX serving pod (see DESIGN.md §2 for the kernel->TPU mapping).

  cgroup      — the unified cgroupfs-style control plane (AgentCgroup
                facade + pluggable host/device backends + intent channel)
  daemon      — async lifecycle daemon backend: lifecycle ops off the
                enforcement hot path, applied in batched FIFO epochs
  progs       — attachable in-step policy programs (memcg_bpf_ops
                analogue): PolicyProgram hooks over a live param table
  domains     — hierarchical resource domains (cgroup v2 analogue)
  accounting  — PSI-style pressure + allocation-latency statistics
  controller  — device-resident state + in-step (jitted) enforcement
  policy      — AgentCgroup + the mismatch baselines of Table 2
  intent      — upward hints / downward feedback protocol
  freezer     — freeze/thaw with host-memory state offload
  events      — enforcement event log
"""
from repro.core.domains import (DomainTree, Domain, ChargeResult,
                                UNLIMITED, LOW, NORMAL, HIGH)
from repro.core.cgroup import (AgentCgroup, Backend, ChargeTicket,
                               DeviceTableBackend, DeviceView, DomainSpec,
                               HostTreeBackend, IntentChannel, Lease)
from repro.core.daemon import AsyncDaemonBackend, DaemonError
from repro.core.progs import (ChainView, GraduatedThrottleProgram,
                              PolicyProgram, Request, TokenBucketProgram,
                              Verdict, charge_decision)
from repro.core.events import Ev, Event, EventLog
from repro.core.accounting import Accounting, LatencyStats, PSITracker
from repro.core.intent import (Hint, AdaptiveAgentModel, Feedback,
                               hint_to_high, make_feedback, parse_hint)
from repro.core.freezer import FrozenStore

__all__ = [
    "DomainTree", "Domain", "ChargeResult", "UNLIMITED", "LOW", "NORMAL",
    "HIGH", "AgentCgroup", "AsyncDaemonBackend", "Backend", "ChargeTicket",
    "DaemonError", "DeviceTableBackend",
    "DeviceView", "DomainSpec", "HostTreeBackend", "IntentChannel", "Lease",
    "Ev", "Event", "EventLog", "Accounting", "LatencyStats",
    "PSITracker", "Hint", "AdaptiveAgentModel", "Feedback", "hint_to_high",
    "make_feedback", "parse_hint", "FrozenStore",
    "ChainView", "GraduatedThrottleProgram", "PolicyProgram", "Request",
    "TokenBucketProgram", "Verdict", "charge_decision",
]

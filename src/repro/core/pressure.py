"""PSI-style pressure accounting (the /proc/pressure analogue).

The paper's third mismatch is adaptability: history-based prediction
cannot size limits for non-deterministic agent executions, so the
control plane must *observe* contention and react.  Linux exposes
contention as PSI (pressure stall information): per-cgroup files
``memory.pressure`` / ``cpu.pressure`` reporting the fraction of
recent time some task was stalled on that resource, as ``avg10`` /
``avg60`` exponentially-weighted averages.

The in-repo analogue splits the work exactly like the weight
flattening in ``core/sched.py``:

  * **In-step accounting** — two i32 control-state rows, ``mem_stall``
    and ``cpu_stall``, count stall *events* per domain: a charge
    decision that stalled or throttled (``charge_stall_event``, called
    from every ``charge_decision`` caller) and a valid schedule slot
    that did not advance (``sched_stall_events``, called inside
    ``schedule_decision``).  Pure ``jnp`` — traced identically by all
    six backend kinds, so the counters are bit-identical wherever the
    same op sequence runs.
  * **Host-side aggregation** — like ``flat_weights_by_path``, the
    hierarchy roll-up is pure host math over the logical path tree
    (``subtree_counts_by_path``): a domain's pressure includes every
    descendant, computed at read rate, never inside the step.
  * **Host-side averaging** — ``PressureMeter`` turns monotone counter
    reads into PSI-style ``some avg10/avg60`` lines.  Decay runs on
    the facade clock (``AgentCgroup.set_time``) quantized by the
    program's ``step_ms`` — never wall time, so replay is
    deterministic and two backends fed the same ops render identical
    pressure strings.

This module is a decision module for tracelint purposes: the traced
helpers admit no host syncs and no suppression pragmas.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

# PSI windows, on the facade ms clock (10 s / 60 s like /proc/pressure)
AVG10_MS = 10_000.0
AVG60_MS = 60_000.0

PRESSURE_FILES = ("memory.pressure", "cpu.pressure")
# raw monotone counters backing the pressure files (subtree-aggregated
# stall-event counts; the facade's PressureMeter averages them)
STALL_FILES = ("memory.stall", "cpu.stall")

STALL_OF = {"memory.pressure": "memory.stall", "cpu.pressure": "cpu.stall"}

# Saturation ceiling for the stall accumulators.  The counters are i32
# control-state rows (x64 is off); a long-lived engine accumulating one
# event per step would wrap negative after ~2^31 events and corrupt the
# PSI averages (the meter clamps negative deltas to 0, so a wrapped
# counter reads as permanent calm).  Every accumulation site — traced
# and host-side — saturates here instead.
INT32_MAX = 2**31 - 1


def saturating_count(counter, events):
    """Accumulate stall ``events`` into an i32 ``counter`` saturating at
    ``INT32_MAX`` instead of wrapping negative.  Pure ``jnp`` and
    elementwise, so it composes with scalar scan carries and whole-row
    updates alike; the wrapped sum in the untaken branch is computed
    but always discarded, keeping the op deterministic on every
    backend."""
    counter = jnp.asarray(counter, jnp.int32)
    inc = jnp.asarray(events, jnp.int32)
    return jnp.where(inc > INT32_MAX - counter,
                     jnp.int32(INT32_MAX), counter + inc)


def charge_stall_event(stalled, throttled):
    """1 iff this charge decision counts as a memory-stall event: the
    request stalled (denied by freeze/throttle/max) or was granted
    under a graduated throttle.  Shared by every ``charge_decision``
    caller so all six backend kinds accumulate identical counters."""
    return jnp.logical_or(stalled, throttled).astype(jnp.int32)


def sched_stall_events(dom, advance):
    """Per-slot i32 CPU-stall indicators for one scheduling round: a
    valid slot (``dom >= 0``) that may not advance — gated, quota-
    capped, or beaten in the budget race — stalls its domain."""
    return jnp.logical_and(dom >= 0,
                           jnp.logical_not(advance)).astype(jnp.int32)


def subtree_counts_by_path(counts: dict) -> dict:
    """Hierarchical roll-up of per-domain stall counters: ``total(d) =
    own(d) + sum(total(children))`` over the logical path tree.

    ``counts`` maps every live path to its own (local) counter.  Pure
    integer host math — like ``flat_weights_by_path``, every backend
    (including the sharded one, whose per-shard tables only see a
    slice of the tree) aggregates identically.
    """
    kids: dict = {}
    for p in counts:
        if p != "/":
            kids.setdefault(p.rsplit("/", 1)[0] or "/", []).append(p)
    total = dict(counts)

    def walk(path):
        for c in kids.get(path, ()):
            walk(c)
            total[path] += total[c]

    if "/" in total:
        walk("/")
    else:                       # partial view (no root row): roots are
        for p in counts:        # the paths whose parent is absent
            parent = p.rsplit("/", 1)[0] or "/"
            if parent not in counts:
                walk(p)
    return total


def format_psi(avg10: float, avg60: float, total: int) -> str:
    """Render one PSI line: ``some avg10=<pct> avg60=<pct> total=<n>``
    (percent of recent steps stalled; ``total`` is the raw aggregated
    stall-event count, the analogue of PSI's total stall time)."""
    return (f"some avg10={avg10 * 100.0:.2f} "
            f"avg60={avg60 * 100.0:.2f} total={int(total)}")


def parse_psi(line: str) -> dict:
    """Parse a PSI line back into ``{"avg10": frac, "avg60": frac,
    "total": int}`` (averages as [0, 1] fractions) — what the adaptive
    controller consumes, reading only the public file surface."""
    fields = dict(kv.split("=", 1) for kv in line.split()[1:])
    return {"avg10": float(fields["avg10"]) / 100.0,
            "avg60": float(fields["avg60"]) / 100.0,
            "total": int(fields["total"])}


class PressureMeter:
    """Counter-to-average converter for the pressure control files.

    One meter per facade; per (path, file) it tracks the last sampled
    (clock, counter) pair and the two running averages.  A sample at
    clock ``now`` converts the counter delta into a stall *fraction*
    (events per elapsed step, clamped to [0, 1] — the PSI "some share
    of time" analogue) and folds it into each window with the exact
    decay ``exp(-dt / window)``.  All inputs come off the facade clock
    and the device counters, so identical op sequences yield identical
    strings on every backend.
    """

    def __init__(self, step_ms: float = 10.0,
                 windows: tuple = (AVG10_MS, AVG60_MS)):
        # ``step_ms`` is the step quantum in facade-clock units and
        # ``windows`` the two decay windows in the same units.  A
        # facade whose clock counts ms keeps the defaults (and tracks
        # the attached program's step_ms — ``auto_step``); a caller
        # whose clock counts steps (the serving engine) reconfigures
        # via ``AgentCgroup.pressure_clock``.
        self.step_ms = float(step_ms)
        self.windows = (float(windows[0]), float(windows[1]))
        self.auto_step = True
        self._rows: dict = {}    # (path, file) -> [t, count, avg10, avg60]

    def sample(self, path: str, file: str, total: int, now: float):
        row = self._rows.get((path, file))
        if row is None:
            row = [float(now), int(total), 0.0, 0.0]
            self._rows[(path, file)] = row
            return row
        dt = float(now) - row[0]
        if dt <= 0.0:
            return row
        steps = max(dt / self.step_ms, 1.0)
        frac = min(max(int(total) - row[1], 0) / steps, 1.0)
        for slot, window in ((2, self.windows[0]), (3, self.windows[1])):
            a = math.exp(-dt / window)
            row[slot] = row[slot] * a + frac * (1.0 - a)
        row[0], row[1] = float(now), int(total)
        return row

    def read(self, path: str, file: str, total: int, now: float) -> str:
        row = self.sample(path, file, total, now)
        return format_psi(row[2], row[3], total)

    def avg10(self, path: str, file: str) -> float:
        row = self._rows.get((path, file))
        return row[2] if row is not None else 0.0

    def forget(self, path: str) -> None:
        """Drop meter rows for a removed domain (and its subtree)."""
        for key in [k for k in self._rows
                    if k[0] == path or k[0].startswith(path + "/")]:
            del self._rows[key]

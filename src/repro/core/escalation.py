"""Semantic OOM escalation: negotiate, re-declare, retry (paper §6).

The paper's waste-reduction claim rests on agents *recovering* from
enforcement, not just being contained by it: its exemplar
``bash_wrapper.sh`` watches for exit-137, reads ``memory.events``, and
injects a structured message so the agent retries with a different
strategy.  This module is the structural version of that loop:

  1. ``AgentCgroup.kill`` on a tool lease delivers a typed ``OomEvent``
     (events.py) to the owning session via the intent channel.
  2. ``EscalationPolicy.negotiate`` turns the event into a bounded
     grant: exponential limit growth from the observed peak, capped by
     the tightest ancestor ``memory.max`` (you can never be granted
     more than the hierarchy could admit), with deterministic jittered
     backoff on the facade clock.
  3. ``Escalator.escalate`` closes the killed lease (no DONE — the kill
     already accounted the call) and re-declares the same tool id at
     the negotiated limit, attempt+1.
  4. ``WasteLedger`` accounts what the loop buys: pages of discarded
     work per attempt vs. the no-retry baseline that throws away the
     whole task.

Attempts are bounded; exhaustion raises ``EscalationExhausted`` — the
loud-failure half of the robustness contract (a caller must either
recover or know it didn't).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Optional

from repro.core import domains as D
from repro.core.cgroup import AgentCgroup, Lease
from repro.core.events import OomEvent

UNLIMITED = D.UNLIMITED


class EscalationExhausted(RuntimeError):
    """The retry budget is spent (or the hierarchy has no headroom):
    the tool call is permanently lost.  Carries the last OomEvent."""

    def __init__(self, ev: OomEvent, msg: str):
        super().__init__(msg)
        self.event = ev


@dataclass(frozen=True)
class Negotiation:
    """One negotiated retry: the new hard limit and when to start."""
    grant_pages: int
    backoff_ms: float
    attempt: int                # attempt number the retry will run as


@dataclass(frozen=True)
class EscalationPolicy:
    """Bounded exponential limit negotiation with jittered backoff.

    The negotiated grant is ``max(limit*growth, peak*headroom)`` —
    growth from the *limit* guarantees progress even when the kill
    fired before the peak got near the limit; headroom over the *peak*
    skips futile intermediate attempts when the observed need is
    already known.  Jitter is deterministic (hash of lease key and
    attempt), so replays are bit-reproducible."""
    max_attempts: int = 4
    growth: float = 2.0
    headroom: float = 1.25
    base_backoff_ms: float = 20.0
    backoff_factor: float = 2.0
    jitter_frac: float = 0.25

    def _jitter(self, key: str, attempt: int) -> float:
        """Deterministic in [0, 1): replays never depend on wall clock."""
        return zlib.crc32(f"{key}#{attempt}".encode()) / 2**32

    def backoff_ms(self, key: str, attempt: int) -> float:
        base = self.base_backoff_ms * self.backoff_factor ** (attempt - 1)
        return base * (1.0 + self.jitter_frac * self._jitter(key, attempt))

    def negotiate(self, ev: OomEvent,
                  parent_max: int) -> Optional[Negotiation]:
        """The grant for the next attempt, or None when exhausted
        (attempt budget spent, or the cap allows no further growth)."""
        if ev.attempt >= self.max_attempts:
            return None
        want = max(int(ev.limit_pages * self.growth),
                   int(ev.peak_pages * self.headroom),
                   ev.limit_pages + 1)
        grant = min(want, parent_max)
        if grant <= ev.limit_pages:
            return None              # already at the hierarchy's ceiling
        return Negotiation(grant_pages=grant,
                           backoff_ms=self.backoff_ms(ev.path, ev.attempt),
                           attempt=ev.attempt + 1)


@dataclass
class WasteLedger:
    """Accounts what escalation buys vs. a no-retry baseline.

    Per killed attempt we discard only that attempt's resident pages
    (``attempt_waste``); the no-retry baseline discards the whole
    task's resident set and gives up (``baseline_waste``).  A recovered
    call is one that later completed at a negotiated limit."""
    kills: int = 0
    exhausted: int = 0
    attempt_waste_pages: int = 0
    baseline_waste_pages: int = 0
    _killed: set = field(default_factory=set)
    _recovered: set = field(default_factory=set)

    def record_kill(self, key: str, attempt_pages: int,
                    baseline_pages: int) -> None:
        self.kills += 1
        self.attempt_waste_pages += int(attempt_pages)
        if key not in self._killed:      # baseline dies on the FIRST kill
            self.baseline_waste_pages += int(baseline_pages)
        self._killed.add(key)

    def record_recovery(self, key: str) -> None:
        if key in self._killed:
            self._recovered.add(key)

    def record_exhausted(self, key: str) -> None:
        self.exhausted += 1

    @property
    def killed_calls(self) -> int:
        return len(self._killed)

    @property
    def recovered_calls(self) -> int:
        return len(self._recovered)

    @property
    def recovery_rate(self) -> float:
        return self.recovered_calls / max(self.killed_calls, 1)

    @property
    def saved_pages(self) -> int:
        """Work the baseline would have discarded but escalation kept."""
        return max(self.baseline_waste_pages - self.attempt_waste_pages, 0)

    def summary(self) -> dict:
        return {"killed_calls": self.killed_calls,
                "recovered_calls": self.recovered_calls,
                "recovery_rate": self.recovery_rate,
                "kills": self.kills, "exhausted": self.exhausted,
                "attempt_waste_pages": self.attempt_waste_pages,
                "baseline_waste_pages": self.baseline_waste_pages,
                "saved_pages": self.saved_pages}


class Escalator:
    """Binds a policy to a facade: turn a killed lease into a retried
    one.  The negotiation cap is the tightest ancestor ``memory.max``
    above the lease (the limit the hierarchy could actually admit)."""

    def __init__(self, cg: AgentCgroup,
                 policy: Optional[EscalationPolicy] = None,
                 ledger: Optional[WasteLedger] = None):
        self.cg = cg
        self.policy = policy if policy is not None else EscalationPolicy()
        self.ledger = ledger if ledger is not None else WasteLedger()

    def _ancestor_cap(self, path: str) -> int:
        cap = UNLIMITED
        for anc in AgentCgroup.ancestors(path):
            m = self.cg.read(anc, "memory.max")
            if m < cap:
                cap = m
        return cap

    def escalate(self, lease: Lease) -> tuple[Lease, Negotiation]:
        """Close the killed ``lease`` and re-declare it at the
        negotiated limit.  Raises ``EscalationExhausted`` when the
        policy yields no further grant (the lease is still closed, so
        the session's accounting stays clean)."""
        ev = lease.oom
        assert ev is not None, f"lease {lease.path} was not killed"
        neg = self.policy.negotiate(ev, self._ancestor_cap(lease.parent))
        if neg is None:
            lease.close()
            self.ledger.record_exhausted(lease.path)
            raise EscalationExhausted(
                ev, f"{lease.path}: no grant after attempt {ev.attempt} "
                    f"(peak {ev.peak_pages}, limit {ev.limit_pages})")
        lease.close()                    # killed: no DONE, frees the slot
        new = self.cg.intent.declare(
            lease.tool_id, lease.hint, parent=lease.parent,
            priority=lease.priority, high=neg.grant_pages,
            max=neg.grant_pages, attempt=neg.attempt)
        return new, neg

"""PSI-style pressure accounting + allocation-latency histograms.

The paper's responsiveness analysis (§4.2) hinges on *when* a pressure
signal becomes actionable: PSI aggregates stalls over 2s/10s windows and
a user-space daemon adds tens of ms of reaction latency, while agent
bursts live 1-2 s.  This module provides both the PSI-window view (for
the reactive baseline policy) and exact per-allocation latency records
(for the Fig-8 P50/P95 metrics).
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass, field
from typing import Optional


class PSITracker:
    """Sliding-window 'some' pressure: fraction of wall time in which at
    least one allocation in the domain was stalled."""

    def __init__(self, window_ms: float = 2000.0):
        self.window_ms = window_ms
        self._stalls: list[tuple[float, float]] = []   # (start, end)

    def record_stall(self, start_ms: float, duration_ms: float) -> None:
        if duration_ms > 0:
            self._stalls.append((start_ms, start_ms + duration_ms))

    def pressure(self, now_ms: float) -> float:
        lo = now_ms - self.window_ms
        total = 0.0
        for s, e in self._stalls:
            total += max(0.0, min(e, now_ms) - max(s, lo))
        return min(1.0, total / self.window_ms)

    def gc(self, now_ms: float) -> None:
        lo = now_ms - self.window_ms
        self._stalls = [(s, e) for s, e in self._stalls if e > lo]


@dataclass
class LatencyStats:
    samples: list = field(default_factory=list)

    def add(self, ms: float) -> None:
        self.samples.append(ms)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        xs = sorted(self.samples)
        k = (len(xs) - 1) * p / 100.0
        f = math.floor(k)
        c = min(f + 1, len(xs) - 1)
        if f == c:
            return xs[int(k)]
        return xs[f] * (c - k) + xs[c] * (k - f)

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def mean(self) -> float:
        return sum(self.samples) / len(self.samples) if self.samples else 0.0

    @property
    def n(self) -> int:
        return len(self.samples)


class Accounting:
    """Per-domain-prefix accounting bundle used by the replay harness."""

    def __init__(self, psi_window_ms: float = 2000.0):
        self.psi: dict[str, PSITracker] = {}
        self.alloc_latency: dict[str, LatencyStats] = {}
        self.psi_window_ms = psi_window_ms

    def _psi(self, key: str) -> PSITracker:
        if key not in self.psi:
            self.psi[key] = PSITracker(self.psi_window_ms)
        return self.psi[key]

    def _lat(self, key: str) -> LatencyStats:
        if key not in self.alloc_latency:
            self.alloc_latency[key] = LatencyStats()
        return self.alloc_latency[key]

    def record_alloc(self, key: str, t_ms: float, latency_ms: float) -> None:
        self._lat(key).add(latency_ms)
        if latency_ms > 0:
            self._psi(key).record_stall(t_ms, latency_ms)

    def pressure(self, key: str, now_ms: float) -> float:
        return self._psi(key).pressure(now_ms) if key in self.psi else 0.0

    def latency(self, key: str) -> LatencyStats:
        return self._lat(key)

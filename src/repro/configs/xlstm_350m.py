"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (xLSTM[7:1]).  24L
d_model=1024 4H d_ff=0 vocab=50304 [arXiv:2405.04517; unverified].

d_ff=0: xLSTM blocks carry their own up/down projections (proj_factor=2);
there is no separate FFN sublayer.  7 mLSTM : 1 sLSTM per 8-layer group.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    xlstm=XLSTMConfig(slstm_period=8, proj_factor=2.0, conv_kernel=4),
    rope_theta=0.0,          # recurrence provides position
    tie_embeddings=True,
    group_size=8,
    source="arXiv:2405.04517; unverified",
)

"""minicpm-2b [dense] — llama-like arch trained with the WSD schedule.
40L d_model=2304 36H (GQA kv=36) d_ff=5760 vocab=122753
[arXiv:2404.06395; hf].  vocab padded to 122880 for 16-way TP."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122753,
    head_dim=64,
    rope_theta=1e4,
    tie_embeddings=True,
    schedule="wsd",
    group_size=1,
    source="arXiv:2404.06395; hf",
)

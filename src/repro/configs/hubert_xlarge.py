"""hubert-xlarge [audio] — encoder-only transformer (wav2vec2-style
backbone).  48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504
[arXiv:2106.07447; unverified].

Encoder-only: bidirectional attention, no KV cache, no decode shapes.
The CNN waveform frontend is a STUB: ``input_specs()`` supplies
precomputed frame embeddings (B, S, d_model); training is masked-frame
prediction over 504 cluster classes (vocab padded to 512 for TP).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    head_dim=80,
    encoder_only=True,
    frontend="audio",
    rope_theta=1e4,          # conv-positional in the original; RoPE stand-in noted in DESIGN.md
    group_size=1,
    source="arXiv:2106.07447; unverified",
)

"""Model / shape configuration schema for the AgentServe framework.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig`` entries in ``SHAPES``.  A
(arch x shape) *cell* is applicable per the rules in ``cell_applicability``
(encoder-only archs have no decode step; ``long_500k`` needs sub-quadratic
context handling).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts sub-config (routed + optional shared experts)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    period: int = 1          # MoE FFN on layers where (i % period) == period-1
    aux_coef: float = 0.01   # load-balance auxiliary loss coefficient
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2).

    KV is cached as a single ``kv_lora_rank + qk_rope_head_dim`` latent
    vector per token — the KV cache is ~9x smaller than GQA at kv=128.
    """

    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba (SSD/Mamba-2 chunked form) sub-config."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256         # intra-chunk parallel block for the SSD scan
    n_ssm_heads: int = 8     # SSD head count (d_inner split)


@dataclass(frozen=True)
class XLSTMConfig:
    slstm_period: int = 8    # sLSTM at layers where (i % period) == period-1
    proj_factor: float = 2.0
    conv_kernel: int = 4
    chunk: int = 256         # mLSTM chunked-parallel block


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid interleave: attention on layers where (i % attn_period) == attn_offset,
    # all other layers are Mamba blocks.  attn_period=1 -> all-attention.
    attn_period: int = 1
    attn_offset: int = 0
    encoder_only: bool = False
    frontend: Optional[str] = None   # None | "vision" | "audio"
    n_frontend_tokens: int = 0       # patch/frame embeddings supplied by input_specs
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # training-time knobs
    remat: bool = True
    schedule: str = "cosine"         # cosine | wsd (minicpm)
    # scanning: layers are grouped into repeated groups of `group_size` layers;
    # the (attn/mamba/moe) pattern must be periodic in group_size.
    group_size: int = 1
    source: str = ""                 # provenance note [arXiv/hf; tier]

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab, 256)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (self.name, self.n_layers, self.group_size)
        return self.n_layers // self.group_size

    def layer_kinds(self) -> list[str]:
        """Sequence-mixer kind for each layer inside one scan group."""
        kinds = []
        for i in range(self.group_size):
            if self.xlstm is not None:
                kinds.append("slstm" if (i % self.xlstm.slstm_period) == self.xlstm.slstm_period - 1
                             else "mlstm")
            elif self.ssm is not None and self.attn_period > 1:
                kinds.append("attn" if (i % self.attn_period) == self.attn_offset else "mamba")
            elif self.ssm is not None:
                kinds.append("mamba")
            else:
                kinds.append("attn")
        return kinds

    def ffn_kinds(self) -> list[str]:
        """FFN kind ('dense' | 'moe' | 'none') for each layer in one group."""
        kinds = []
        for i in range(self.group_size):
            if self.d_ff == 0:
                kinds.append("none")
            elif self.moe is not None and (i % self.moe.period) == self.moe.period - 1:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return kinds

    @property
    def subquadratic(self) -> bool:
        """True when per-token decode state is O(1) or near-O(1) in context."""
        return self.family in ("hybrid", "ssm")

    # ---- parameter counting (for MODEL_FLOPS = 6*N*D) ----
    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count, analytic."""
        d, hd = self.d_model, self.head_dim_
        n = 0
        # embeddings (+ untied head)
        n += self.padded_vocab * d
        if not self.tie_embeddings and not self.encoder_only:
            n += self.padded_vocab * d
        if self.encoder_only:
            n += d * self.padded_vocab  # classifier head
        kinds, ffns = self.layer_kinds(), self.ffn_kinds()
        per_group = 0
        for kind, ffn in zip(kinds, ffns):
            if kind == "attn":
                if self.mla is not None:
                    m = self.mla
                    per_group += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.qk_rope_head_dim)
                    per_group += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    per_group += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    per_group += self.n_heads * m.v_head_dim * d
                else:
                    per_group += d * self.n_heads * hd          # Q
                    per_group += 2 * d * self.n_kv_heads * hd   # K, V
                    per_group += self.n_heads * hd * d          # O
            elif kind == "mamba":
                s = self.ssm
                d_in = s.expand * d
                per_group += d * 2 * d_in                       # in_proj (x, z)
                per_group += d_in * s.d_conv                    # conv
                per_group += d_in * 2 * s.d_state               # B, C proj (per SSD head shared)
                per_group += d_in + d_in                        # dt proj + A_log/D
                per_group += d_in * d                           # out_proj
            elif kind in ("mlstm", "slstm"):
                x = self.xlstm
                d_in = int(x.proj_factor * d)
                per_group += d * 2 * d_in + d_in * d            # up (x,z) + down
                per_group += 3 * d_in * d_in // 4               # q,k,v block-diag-ish
                per_group += 3 * d_in                           # gates
            if ffn == "dense":
                per_group += 3 * d * self.d_ff                  # SwiGLU
            elif ffn == "moe":
                m = self.moe
                n_routed = m.top_k if active_only else m.n_experts
                per_group += 3 * d * m.d_ff_expert * (n_routed + m.n_shared)
                per_group += d * m.n_experts                    # router
        n += per_group * self.n_groups
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def cell_applicability(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). See DESIGN.md §4."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch; 500k decode needs sub-quadratic context (see DESIGN.md)"
    return True, ""

"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536 [arXiv:2403.19887; hf]
Attention on one layer per 8-layer group (1:7 attn:mamba); MoE FFN every
other layer (period 2), as in the Jamba paper.
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, n_shared=0, period=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, n_ssm_heads=8),
    attn_period=8,
    attn_offset=4,           # attention mid-group, as in Jamba's block layout
    rope_theta=0.0,          # Jamba attention layers use no positional encoding
    group_size=8,
    source="arXiv:2403.19887; hf",
)

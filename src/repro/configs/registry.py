"""Architecture registry: ``--arch <id>`` resolution + reduced configs.

``get_config(arch)`` returns the full assigned config; ``reduced(cfg)``
shrinks it to a CPU-smoke-test size *of the same family* (same layer
pattern, few layers/experts, tiny embeddings) per the assignment.
"""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, MoEConfig, SHAPES, ShapeConfig, cell_applicability

_ARCH_MODULES = {
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "minicpm-2b": "repro.configs.minicpm_2b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ModelConfig, seed_vocab: int = 512) -> ModelConfig:
    """Same-family miniature for CPU smoke tests: one scan group, narrow
    width, few experts, tiny vocab."""
    changes: dict = dict(
        n_layers=cfg.group_size,          # one full pattern group
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=seed_vocab,
        head_dim=32,
        n_frontend_tokens=min(cfg.n_frontend_tokens, 16),
        remat=False,
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=128)
    if cfg.mla is not None:
        changes["mla"] = dataclasses.replace(
            cfg.mla, kv_lora_rank=32, q_lora_rank=64,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=8, chunk=32, n_ssm_heads=2)
    if cfg.xlstm is not None:
        changes["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=32)
    return dataclasses.replace(cfg, **changes)


def iter_cells():
    """Yield (arch, shape, applicable, reason) for all 40 assignment cells."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = cell_applicability(cfg, shape)
            yield arch, shape, ok, reason

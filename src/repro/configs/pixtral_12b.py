"""pixtral-12b [vlm] — pixtral-ViT frontend + mistral-nemo decoder.
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified].

The ViT frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (n_frontend_tokens x d_model) that are fused
into the token stream at embedding time (early fusion).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=160,          # mistral-nemo style: head_dim > d_model/n_heads? no: 5120/32=160
    frontend="vision",
    n_frontend_tokens=1024,   # one 1024-patch image per sequence
    rope_theta=1e9,
    group_size=1,
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared / 160 routed
top-6 experts.  60L d_model=5120 128H d_ff_expert=1536 vocab=102400
[arXiv:2405.04434; hf].

Per the assignment line, every layer is MoE with d_ff=1536 experts (the
official model's single first dense layer is folded into the MoE stack —
noted in DESIGN.md).  MLA caches a 512+64 latent per token: the KV cache
is ~9x smaller than GQA kv=128 would be.
"""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,          # nominal; MLA replaces per-head KV with the latent
    d_ff=1536,
    vocab=102400,
    head_dim=128,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2, period=1),
    rope_theta=1e4,
    group_size=1,
    source="arXiv:2405.04434; hf",
)

"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + shared
expert, MoE every other layer; early fusion.  48L d_model=5120 40H (GQA
kv=8) d_ff=8192 vocab=202048 [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1, period=2),
    rope_theta=5e5,
    group_size=2,            # dense/MoE alternation scans as 2-layer groups
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig, SHAPES,
                                SSMConfig, ShapeConfig, XLSTMConfig,
                                cell_applicability)
from repro.configs.registry import ARCH_IDS, all_configs, get_config, iter_cells, reduced

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "XLSTMConfig",
    "ShapeConfig", "SHAPES", "cell_applicability",
    "ARCH_IDS", "get_config", "all_configs", "reduced", "iter_cells",
]

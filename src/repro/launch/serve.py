"""Multi-tenant serving driver: agent sessions under AgentCgroup control.

Builds a reduced model, derives agent sessions from §3-calibrated traces
(or synthetic phase scripts), and runs the continuous-batching engine in
one of the controller modes:

  inkernel   — AgentCgroup: in-step enforcement + tool-call domains +
               intent hints + freeze/thaw + feedback  (the paper's system)
  userspace  — poll/react daemon gating (responsiveness baseline)
  nolimit    — accounting only (no isolation baseline)

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
      --mode inkernel --sessions 4 --pool-pages 48
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import jax

from repro.configs import get_config, reduced
from repro.core import domains as D
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import Phase, Session, session_from_trace
from repro.traces.generator import generate_task


def default_sessions(n: int, seed: int = 0) -> list:
    """1 HIGH-priority session + (n-1) LOW sessions from generated traces."""
    out = []
    for i in range(n):
        trace = generate_task(f"agent-{i}", "glm" if i % 2 else "haiku",
                              seed=seed * 1000 + i, scale=0.6)
        out.append(session_from_trace(
            sid=f"s{i}", tenant="tenant0", trace=trace,
            priority=D.HIGH if i == 0 else D.LOW,
            tokens_per_mb=0.2, gen_per_call=16, max_phases=6))
    return out


def run(args) -> dict:
    cfg = reduced(get_config(args.arch))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(args.seed),
                         cfg.dtype)
    perf = perf_replace(DEFAULT_PERF, scan_chunk=32)
    ecfg = EngineConfig(
        max_slots=args.slots, s_max=args.s_max, pool_pages=args.pool_pages,
        page_tokens=args.page_tokens, mode=args.mode,
        use_freeze=(args.mode == "inkernel"),
        use_tool_domains=(args.mode == "inkernel"),
        use_intent=(args.mode == "inkernel"),
        session_high=json.loads(args.session_high) if args.session_high else None,
    )
    eng = Engine(cfg, params, perf=perf, ecfg=ecfg, seed=args.seed)
    for s in default_sessions(args.sessions, seed=args.seed):
        eng.submit(s)
    eng.run(args.max_steps)
    report = eng.report()
    print(json.dumps(report, indent=1), flush=True)
    return report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--mode", default="inkernel",
                    choices=["inkernel", "userspace", "nolimit"])
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--s-max", type=int, default=512)
    ap.add_argument("--pool-pages", type=int, default=48)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--session-high", default=None,
                    help='JSON dict sid->pages, e.g. {"s1": 12}')
    ap.add_argument("--max-steps", type=int, default=8000)
    ap.add_argument("--seed", type=int, default=0)
    run(ap.parse_args())
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Production meshes + logical-axis rule resolution.

Single pod: (16, 16) = 256 chips, axes (data, model) — all ICI.
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod
axis crosses DCN; collectives on it are the expensive ones and the
roofline's collective term prices them at DCN bandwidth.

``rules_for`` resolves the logical axes used by parameter schemas and
activation constraints into mesh axes, per (mode, shape):
  train:   weights FSDP over data + TP over model; batch over (pod,data)
  serve:   weights TP only (replicated over data) except expert stacks;
           decode caches sequence-sharded over model (flash-decoding);
           long-context (batch=1) shards the cache over EVERY axis.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro import compat
from repro.configs.base import ShapeConfig
from repro.models.schema import RULES

# TPU v5e-class hardware constants (per chip) for the roofline
HW = {
    "flops_bf16": 197e12,       # peak bf16 FLOP/s
    "hbm_bw": 819e9,            # HBM bytes/s
    "ici_bw": 50e9,             # per-link ICI bytes/s
    "dcn_bw": 25e9,             # cross-pod bytes/s
    "hbm_bytes": 16 * 2 ** 30,  # capacity
}

POD_CHIPS = 256                 # devices per pod (16 x 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_auto_mesh(shape, axes)


def _batch_axes(mesh) -> tuple:
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def _axis_prod(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= sizes[a]
    return n


def rules_for(mesh, *, mode: str, shape: Optional[ShapeConfig] = None) -> dict:
    """Logical-axis -> mesh-axis rules for one (mode, shape) cell."""
    assert mode in ("train", "serve"), mode
    rules = dict(RULES[mode])
    # sequence-parallel residual stream in training: carries + remat-saved
    # activations are sharded over the model axis between layers
    rules["act_seq"] = "model" if mode == "train" else None
    batch_axes = _batch_axes(mesh)
    nb = _axis_prod(mesh, batch_axes)
    gb = shape.global_batch if shape is not None else nb
    if gb % nb == 0 and gb >= nb:
        rules["act_batch"] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    elif gb % 16 == 0:
        rules["act_batch"] = "data"
    else:
        rules["act_batch"] = None            # e.g. long-context batch=1
    if shape is not None and shape.kind == "decode":
        if shape.global_batch == 1:
            # long-context: the cache is the whole working set — shard its
            # sequence axis over every mesh axis
            rules["cache_seq"] = tuple(mesh.axis_names)
        else:
            rules["cache_seq"] = "model"
    else:
        rules["cache_seq"] = "model"
    return rules

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each applicable cell this lowers the real step function — train_step
(forward+backward+AdamW), prefill_step, or serve_step (one token against
a seq_len KV cache) — against ShapeDtypeStruct inputs carrying the
production NamedShardings (no allocation), compiles it for the 256-chip
single-pod mesh and the 512-chip two-pod mesh, and records:

  * compiled.memory_analysis()  — proves the cell fits per-device HBM;
  * compiled.cost_analysis()    — XLA's per-device FLOPs/bytes (while
    bodies counted once — see analysis/hlo.py);
  * trip-count-corrected FLOPs / bytes / collective bytes from the
    optimized HLO text (analysis/hlo.analyze);
  * the three roofline terms + dominant bottleneck (analysis/roofline).

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --out results/dryrun   # orchestrates
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.analysis import hlo as hlo_mod
from repro.analysis.roofline import roofline_from_costs
from repro.configs import SHAPES, cell_applicability, get_config, ARCH_IDS
from repro.launch.mesh import HW, POD_CHIPS, make_production_mesh, rules_for
from repro.models import model as M
from repro.models.schema import Leaf, shape_structs, tree_map_schema
from repro.perf import DEFAULT_PERF, PerfConfig
from repro.sharding_ctx import activation_rules
from repro.training.optimizer import OptConfig
from repro.training.train_step import make_train_step


def _opt_schema(param_sch):
    f32 = lambda l: Leaf(l.shape, l.spec, init="zeros", dtype="float32")
    return {"m": tree_map_schema(f32, param_sch),
            "v": tree_map_schema(f32, param_sch),
            "count": Leaf((), init="zeros", dtype="int32")}


# per-arch production perf defaults for TRAIN cells: the giant-MoE /
# MLA configs cannot afford remat-saving their head-expansion dots
# (120 GiB of stacked saved activations) and use deeper grad
# accumulation; everything else uses the standard dots policy.
TRAIN_PERF_OVERRIDES = {
    "deepseek-v2-236b": dict(remat="full", microbatches=8),
    "llama4-maverick-400b-a17b": dict(remat="full", microbatches=4),
    "jamba-v0.1-52b": dict(remat="full", microbatches=2),
    "pixtral-12b": dict(microbatches=4),
    "internlm2-20b": dict(microbatches=4),
    "phi3-medium-14b": dict(microbatches=4),
    "xlstm-350m": dict(remat="full"),
}


def build_cell(arch: str, shape_name: str, mesh, perf: PerfConfig):
    """Returns (fn, arg_structs) for one cell, or raises."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mode = "train" if shape.kind == "train" else "serve"
    rules = rules_for(mesh, mode=mode, shape=shape)
    psch = M.param_schema(cfg)
    params = shape_structs(psch, cfg.dtype, mesh, rules)
    batch_leaves = M.batch_spec_leaves(cfg, shape)
    batch = {k: shape_structs(l, cfg.dtype, mesh, rules)
             for k, l in batch_leaves.items()}

    if shape.kind == "train":
        opt = shape_structs(_opt_schema(psch), "float32", mesh, rules)
        if perf.microbatches == 1:
            # baseline: 2 microbatches (64k tokens/device at train_4k on
            # the single pod does not fit HBM without grad accumulation)
            ov = {"microbatches": 2, **TRAIN_PERF_OVERRIDES.get(arch, {})}
            perf = dataclasses.replace(perf, **ov)
        step_fn = make_train_step(cfg, perf, OptConfig())
        step = jax.ShapeDtypeStruct((), jnp.int32)
        # params/opt are donated (aliased to the outputs), as the real
        # training driver does — memory_analysis must reflect that
        return (step_fn, (params, opt, batch, step), rules, (0, 1))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = M.forward(cfg, params, batch, perf=perf)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return prefill_step, (params, batch), rules, ()

    # decode: one new token against a seq_len cache (cache donated)
    ssch = M.decode_state_schema(cfg, shape.global_batch, shape.seq_len)
    state = shape_structs(ssch, cfg.dtype, mesh, rules)

    def serve_step(params, state, batch):
        return M.serve_step(cfg, params, state, batch["tokens"],
                            batch["lengths"], perf=perf)
    return serve_step, (params, state, batch), rules, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             perf: PerfConfig = DEFAULT_PERF) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicability(cfg, shape)
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "applicable": ok}
    if not ok:
        rec["skip_reason"] = reason
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    fn, args, rules, donate = build_cell(arch, shape_name, mesh, perf)
    with mesh:
        with activation_rules(rules, mesh=mesh):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compat.cost_analysis(compiled)
    txt = compiled.as_text()
    parsed = hlo_mod.analyze(txt, pod_size=POD_CHIPS)
    per_dev_bytes = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                     + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    rec.update({
        "n_chips": n_chips,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "fits_hbm": bool(per_dev_bytes <= HW["hbm_bytes"]),
        },
        "cost_analysis": {"flops": ca.get("flops", 0.0),
                          "bytes": ca.get("bytes accessed", 0.0)},
        "hlo": parsed,
    })
    rec["roofline"] = roofline_from_costs(cfg, shape, parsed, n_chips=n_chips)
    return rec


# --------------------------------------------------------------- CLI driver


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in subprocesses")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--moe-impl", default=None, choices=["dense", "gather"])
    ap.add_argument("--perf-json", default=None,
                    help="JSON dict of PerfConfig overrides")
    args = ap.parse_args()

    perf = DEFAULT_PERF
    if args.moe_impl:
        perf = dataclasses.replace(perf, moe_impl=args.moe_impl)
    if args.perf_json:
        perf = dataclasses.replace(perf, **json.loads(args.perf_json))

    if args.all:
        return orchestrate(args, perf)

    assert args.arch and args.shape, "--arch/--shape required without --all"
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)
    status = 0
    for mp in meshes:
        name = f"{args.arch}__{args.shape}__{'multi' if mp else 'single'}"
        try:
            rec = run_cell(args.arch, args.shape, mp, perf)
        except Exception as e:
            rec = {"arch": args.arch, "shape": args.shape,
                   "mesh": "multi" if mp else "single", "applicable": True,
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
            status = 1
        path = os.path.join(args.out, f"{name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        summary = (rec.get("skip_reason") or rec.get("error")
                   or f"ok compile={rec.get('t_compile_s')}s "
                      f"fits={rec.get('memory', {}).get('fits_hbm')}")
        print(f"[{name}] {summary}", flush=True)
    return status


def orchestrate(args, perf: PerfConfig) -> int:
    """Run every (arch x shape x mesh) cell, each in its own subprocess
    (isolates jit caches / memory), a few at a time."""
    os.makedirs(args.out, exist_ok=True)
    cells = []
    for arch in ARCH_IDS:
        for shape_name in SHAPES:
            ok, reason = cell_applicability(get_config(arch),
                                            SHAPES[shape_name])
            if not ok:
                for mesh in ("single", "multi"):
                    path = os.path.join(
                        args.out, f"{arch}__{shape_name}__{mesh}.json")
                    with open(path, "w") as f:
                        json.dump({"arch": arch, "shape": shape_name,
                                   "mesh": mesh, "applicable": False,
                                   "skip_reason": reason}, f, indent=1)
                print(f"[{arch}/{shape_name}] SKIP: {reason}", flush=True)
                continue
            cells.append((arch, shape_name))
    procs: list = []
    failures = 0

    def reap(block: bool):
        nonlocal failures
        done = []
        for p, name in procs:
            if p.poll() is not None or block:
                rc = p.wait()
                if rc:
                    failures += 1
                    print(f"[{name}] FAILED rc={rc}", flush=True)
                done.append((p, name))
        for d in done:
            procs.remove(d)

    for arch, shape_name in cells:
        while len(procs) >= args.jobs:
            reap(False)
            time.sleep(1.0)
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape_name,
               "--mesh", args.mesh, "--out", args.out]
        if args.moe_impl:
            cmd += ["--moe-impl", args.moe_impl]
        if args.perf_json:
            cmd += ["--perf-json", args.perf_json]
        p = subprocess.Popen(cmd)
        procs.append((p, f"{arch}/{shape_name}"))
    while procs:
        reap(False)
        time.sleep(1.0)
    print(f"dry-run complete; failures={failures}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Fault-tolerant training driver.

Features exercised by the integration tests and examples:
  * resume-from-latest checkpoint (bit-exact: data is a pure function of
    (seed, step), optimizer state is checkpointed with params);
  * periodic async checkpoints with keep-k GC and atomic writes — a
    mid-write crash leaves the previous checkpoint intact;
  * failure injection (``--crash-at N``) to demonstrate restart;
  * straggler watchdog: per-step wall times are tracked against a
    rolling median; slow steps are logged (on a real pod this feeds the
    re-meshing / elastic-scaling decision, here it is surfaced in the
    run report);
  * optional int8 gradient compression with error feedback.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --steps 100 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import sys
import time

import jax
import numpy as np

from repro.configs import SHAPES, get_config, reduced
from repro.data.pipeline import DataIterator
from repro.checkpoint.manager import CheckpointManager
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step


class StragglerWatchdog:
    """Flags steps slower than ``factor`` x rolling median (straggler /
    slow-host detection; the elastic driver would re-mesh on repeats)."""

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times: list[float] = []
        self.flagged: list[tuple[int, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        hist = self.times[-self.window:]
        if len(hist) >= 8:
            med = statistics.median(hist)
            if dt > self.factor * med:
                self.flagged.append((step, dt))
                return True
        return False


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
        cfg = dataclasses.replace(cfg, dtype="float32")
    shape = SHAPES[args.shape]
    perf = perf_replace(DEFAULT_PERF, scan_chunk=args.scan_chunk,
                        microbatches=args.microbatches,
                        grad_compress=args.grad_compress,
                        remat="none" if args.reduced else "dots")
    opt_cfg = OptConfig(schedule=cfg.schedule, total_steps=args.steps,
                        warmup_steps=max(args.steps // 20, 5), lr=args.lr)
    step_fn = jax.jit(make_train_step(cfg, perf, opt_cfg),
                      donate_argnums=(0, 1))
    data = DataIterator(cfg, shape, seed=args.data_seed,
                        batch=args.batch, seq=args.seq)
    mgr = CheckpointManager(args.ckpt_dir, keep=args.keep,
                            every=args.ckpt_every,
                            async_write=not args.sync_ckpt)

    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(args.seed),
                         cfg.dtype)
    opt_state = init_train_state(cfg, params, perf)
    start = 0
    restored = mgr.restore_latest({"params": params, "opt": opt_state})
    if restored is not None:
        start, tree = restored
        params = jax.tree.map(jax.numpy.asarray, tree["params"])
        opt_state = jax.tree.map(jax.numpy.asarray, tree["opt"])
        start += 1
        print(f"[train] resumed from step {start - 1}", flush=True)

    dog = StragglerWatchdog()
    losses = []
    for step in range(start, args.steps):
        t0 = time.time()
        batch = data.at(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch, step)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.time() - t0
        if dog.observe(step, dt):
            print(f"[train] straggler: step {step} took {dt:.2f}s", flush=True)
        mgr.maybe_save(step, {"params": params, "opt": opt_state})
        if step % args.log_every == 0:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} {dt * 1e3:.0f}ms", flush=True)
        if args.crash_at is not None and step == args.crash_at:
            print(f"[train] FAILURE INJECTION at step {step}", flush=True)
            os._exit(42)
    mgr.maybe_save(args.steps - 1, {"params": params, "opt": opt_state},
                   force=True)
    mgr.finalize()
    report = {
        "arch": args.arch, "steps": args.steps,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "stragglers": dog.flagged,
        "resumed_from": start - 1 if start else None,
    }
    print(json.dumps(report), flush=True)
    return report


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--scan-chunk", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--sync-ckpt", action="store_true",
                    help="synchronous checkpoint writes (deterministic "
                         "crash tests)")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=None)
    run(ap.parse_args())
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Replay paper-calibrated agent traces under every resource-control
policy and print the survival / latency / overhead comparison —
the fastest way to see the paper's three mismatches and their fix.

Run: PYTHONPATH=src python examples/replay_traces.py
"""
import numpy as np

from repro.core import domains as D
from repro.core.policy import (AgentCgroupPolicy, NoIsolationPolicy,
                               PredictiveP95Policy, ReactivePSIPolicy,
                               StaticLimitPolicy)
from repro.traces.generator import generate_task, named_trace
from repro.traces.replay import ReplayConfig, replay


def main():
    traces = [named_trace("dask/dask#11628", seed=1),
              named_trace("sigmavirus24/github3.py#673", seed=2),
              named_trace("sigmavirus24/github3.py#673", seed=3)]
    prios = [D.HIGH, D.LOW, D.LOW]
    avg = int(np.mean([t.avg_mb for t in traces]))
    hist = {t.task_id: [t.peak_mb * 0.6] for t in traces}  # stale history
    policies = [
        NoIsolationPolicy(),
        StaticLimitPolicy(limit_mb=avg),
        ReactivePSIPolicy(),
        PredictiveP95Policy(hist),
        AgentCgroupPolicy(session_high={"sigmavirus24/github3.py#673": 400}),
    ]
    cfg = ReplayConfig(capacity_mb=1100)
    print(f"pool 1100 MB, demand ~{sum(t.peak_mb for t in traces):.0f} MB "
          f"(1 HIGH + 2 LOW sessions)\n")
    print(f"{'policy':16s} {'survival':>8s} {'HIGH P95':>9s} "
          f"{'throttles':>9s} {'kills':>6s} {'freezes':>7s}")
    for pol in policies:
        r = replay(traces, prios, pol, cfg)
        s = r.summary()
        print(f"{s['policy']:16s} {s['survival']:8.2f} "
              f"{s['high_p95_ms']:8.2f}m {s['throttles']:9d} "
              f"{s['oom_kills']:6d} {s['freezes']:7d}")


if __name__ == "__main__":
    main()

"""Train a ~100M-parameter llama-family model for a few hundred steps on
CPU, with checkpoints, WSD or cosine schedule, and optional gradient
compression — the end-to-end training driver at example scale.

Run: PYTHONPATH=src python examples/train_100m.py [--steps 300]
(~100M params is slow on CPU; --d-model 256 gives a quick demo run.)
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import SHAPES, get_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataIterator
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step


def build_cfg(d_model: int, n_layers: int):
    base = get_config("llama3.2-3b")
    return dataclasses.replace(
        base, n_layers=n_layers, d_model=d_model, n_heads=max(d_model // 64, 2),
        n_kv_heads=max(d_model // 128, 1), d_ff=d_model * 4, vocab=8192,
        head_dim=64, dtype="float32", group_size=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/train100m")
    args = ap.parse_args()

    cfg = build_cfg(args.d_model, args.layers)
    n = cfg.param_count()
    print(f"model: {n / 1e6:.1f}M params, {cfg.n_layers}L x {cfg.d_model}")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0),
                         cfg.dtype)
    perf = perf_replace(DEFAULT_PERF, remat="none",
                        grad_compress=args.grad_compress)
    opt_cfg = OptConfig(lr=6e-4, warmup_steps=args.steps // 20,
                        total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, perf, opt_cfg),
                      donate_argnums=(0, 1))
    opt = init_train_state(cfg, params, perf)
    data = DataIterator(cfg, SHAPES["train_4k"], seed=0,
                        batch=args.batch, seq=args.seq)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, every=100)

    t0 = time.time()
    tokens = 0
    for i in range(args.steps):
        params, opt, m = step_fn(params, opt, data.at(i), i)
        tokens += args.batch * args.seq
        mgr.maybe_save(i, {"params": params, "opt": opt})
        if i % 20 == 0 or i == args.steps - 1:
            dt = time.time() - t0
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{tokens / max(dt, 1e-9):,.0f} tok/s")
    mgr.finalize()
    print(f"done: final loss {float(m['loss']):.4f} "
          f"({time.time() - t0:.0f}s); checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()

"""End-to-end driver: multi-tenant agent serving with batched requests.

Serves a reduced model to agent sessions derived from paper-calibrated
traces (each tool call's result floods the context, the KV-page analogue
of the paper's §3 memory bursts), under all three controller modes, and
prints a Fig-8-style comparison.

Run: PYTHONPATH=src python examples/serve_agents.py [--sessions 5]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, reduced
from repro.core import domains as D
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import session_from_trace
from repro.traces.generator import generate_task


def make_sessions(n: int, seed: int):
    out = []
    for i in range(n):
        trace = generate_task(f"agent-{i}", "glm" if i % 2 else "haiku",
                              seed=seed * 131 + i, scale=0.5)
        out.append(session_from_trace(
            sid=f"s{i}", tenant=f"tenant{i % 2}", trace=trace,
            priority=D.HIGH if i == 0 else D.LOW,
            tokens_per_mb=0.6, gen_per_call=12, max_phases=5))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--sessions", type=int, default=5)
    ap.add_argument("--pool-pages", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(get_config(args.arch)),
                              dtype="float32")
    params = init_params(M.param_schema(cfg), jax.random.PRNGKey(args.seed),
                         cfg.dtype)
    perf = perf_replace(DEFAULT_PERF, scan_chunk=32)
    common = dict(max_slots=4, s_max=512, pool_pages=args.pool_pages,
                  page_tokens=16)
    modes = {
        "nolimit": dict(mode="nolimit", use_freeze=False,
                        use_tool_domains=False, use_intent=False),
        "userspace": dict(mode="userspace", use_freeze=False,
                          use_tool_domains=False, use_intent=False),
        "agentcgroup": dict(mode="inkernel", use_freeze=True),
    }
    print(f"serving {args.sessions} agent sessions on {args.arch} "
          f"(reduced), pool={args.pool_pages} KV pages\n")
    print(f"{'mode':12s} {'done':>5s} {'evict':>5s} {'overshoot':>9s} "
          f"{'throttles':>9s} {'freezes':>7s} {'feedbacks':>9s} "
          f"{'steps':>6s}")
    for name, kw in modes.items():
        eng = Engine(cfg, params, perf=perf,
                     ecfg=EngineConfig(**common, **kw), seed=args.seed)
        for s in make_sessions(args.sessions, args.seed):
            eng.submit(s)
        eng.run(12000)
        r = eng.report()
        print(f"{name:12s} {r['completed']:5d} {r['evicted']:5d} "
              f"{r['overshoot_pages']:9d} {r['throttle_triggers']:9d} "
              f"{r['freezes']:7d} {r['feedbacks']:9d} {r['steps']:6d}")
    print("\nAgentCgroup: everyone finishes, the pool is never "
          "overshot, and bursts are absorbed by throttle/freeze/feedback "
          "instead of evictions.")


if __name__ == "__main__":
    main()

"""Quickstart: the three layers of the framework in ~60 seconds on CPU.

 1. resource domains + in-step controller (the AgentCgroup core),
 2. a reduced model doing a few training steps,
 3. a multi-tenant serving engine with enforcement.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, reduced
from repro.core import domains as D
from repro.core.controller import (ControllerConfig, DeviceDomainTable,
                                   charge_batch)
from repro.data.pipeline import DataIterator
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import Phase, Session
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step

print("== 1. hierarchical resource domains (cgroup v2 analogue) ==")
tree = D.DomainTree(capacity=1000)
tree.create("/tenant", high=800)
tree.create("/tenant/sess", priority=D.HIGH)
tree.create("/tenant/sess/tool_1", high=50)      # intent hint: memory:low
res = tree.try_charge("/tenant/sess/tool_1", 80)
print(f"charge 80 pages into tool domain (high=50): ok={res.ok}, "
      f"soft-breach at {res.over_high}")
print(f"graduated throttle delay: "
      f"{tree.throttle_delay_ms('/tenant/sess/tool_1'):.0f} ms")

print("\n== 1b. the same semantics, device-resident & jitted ==")
tab = DeviceDomainTable(1000, cfg=ControllerConfig())
idx = tab.create("/s", high=50)
ctrl_cfg = ControllerConfig()
st, granted, stalled = jax.jit(
    lambda s, d, a, t: charge_batch(s, d, a, t, ctrl_cfg))(
    tab.state, jnp.array([idx]), jnp.array([80], jnp.int32), 0)
print(f"in-step charge granted={bool(granted[0])}, "
      f"throttled until step {int(st['throttle_until'][idx])}")

print("\n== 2. train a reduced llama3.2 for 10 steps ==")
cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                          dtype="float32")
params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0), cfg.dtype)
perf = perf_replace(DEFAULT_PERF, scan_chunk=32, remat="none")
step = jax.jit(make_train_step(cfg, perf, OptConfig(lr=1e-3, warmup_steps=2,
                                                    total_steps=10)))
opt = init_train_state(cfg, params, perf)
data = DataIterator(cfg, SHAPES["train_4k"], seed=0, batch=4, seq=64)
for i in range(10):
    params, opt, m = step(params, opt, data.at(i), i)
    if i % 3 == 0:
        print(f"  step {i}: loss {float(m['loss']):.3f}")

print("\n== 3. serve two agent sessions under AgentCgroup ==")
eng = Engine(cfg, params, perf=perf_replace(DEFAULT_PERF, scan_chunk=32),
             ecfg=EngineConfig(max_slots=2, s_max=256, pool_pages=24,
                               page_tokens=16, mode="inkernel"))
eng.submit(Session(sid="hi", tenant="t", priority=D.HIGH,
                   prompt=list(range(2, 18)),
                   phases=[Phase(8, 64, "test"), Phase(8, 0)]))
eng.submit(Session(sid="lo", tenant="t", priority=D.LOW,
                   prompt=list(range(2, 18)),
                   phases=[Phase(8, 96, "test"), Phase(8, 0)]))
eng.run(3000)
r = eng.report()
print(f"  survival={r['survival']:.0%} throttles={r['throttle_triggers']} "
      f"freezes={r['freezes']} pool_overshoot={r['overshoot_pages']} pages")
print("\nquickstart done.")

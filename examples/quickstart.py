"""Quickstart: the three layers of the framework in ~60 seconds on CPU.

 1. resource domains + in-step controller (the AgentCgroup core),
 2. a reduced model doing a few training steps,
 3. a multi-tenant serving engine with enforcement.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, reduced
from repro.core import domains as D
from repro.core.cgroup import (AgentCgroup, DeviceTableBackend, DomainSpec,
                               HostTreeBackend)
from repro.core.daemon import AsyncDaemonBackend
from repro.core.controller import ControllerConfig
from repro.core.intent import Hint
from repro.data.pipeline import DataIterator
from repro.models import model as M
from repro.models.schema import init_params
from repro.perf import DEFAULT_PERF, replace as perf_replace
from repro.serving.engine import Engine, EngineConfig
from repro.serving.session import Phase, Session
from repro.training.optimizer import OptConfig
from repro.training.train_step import init_train_state, make_train_step

print("== 1. one cgroupfs-style control plane, two backends ==")


def drive(cg: AgentCgroup) -> dict:
    """The SAME op sequence works against any backend: mkdir a
    hierarchy, declare a tool-call lease from an intent hint, charge
    through it, close the lease (residual transfers to the session)."""
    cg.mkdir("/tenant", DomainSpec(high=800))
    cg.mkdir("/tenant/sess", DomainSpec(priority=D.HIGH))
    lease = cg.intent.declare("tool_1", Hint.LOW, parent="/tenant/sess",
                              high=50)
    ticket = cg.try_charge(lease.path, 80)
    granted = ticket.granted
    lease.close()                      # rmdir + residual moves upward
    return {"granted": granted, "root": cg.usage("/"),
            "sess": cg.usage("/tenant/sess"),
            "sess_peak": cg.peak("/tenant/sess")}


# zero-delay config so host and device grant/deny semantics align
no_throttle = ControllerConfig(base_delay_ms=0.0, max_delay_ms=0.0)
host_cg = AgentCgroup(HostTreeBackend(1000))
# the async lifecycle daemon: same ops, but queued to a daemon thread
# and applied in FIFO epochs — bit-exact with its inner backend
async_cg = AgentCgroup(AsyncDaemonBackend(HostTreeBackend(1000)))
host = drive(host_cg)
dev = drive(AgentCgroup(DeviceTableBackend(1000, cfg=no_throttle)))
asy = drive(async_cg)
print(f"host   backend: {host}")
print(f"device backend: {dev}")
print(f"async  backend: {asy} (epoch {async_cg.backend.epoch})")
assert host == dev == asy, "backends diverged!"
# identical op sequence -> identical memcg event counters, async or not:
# shrink the session high and breach it on both host-class backends
for c in (host_cg, async_cg):
    c.write("/tenant/sess", "memory.high", 10)
    c.try_charge("/tenant/sess", 20)     # high breach + graduated throttle
ev_host = host_cg.read("/tenant/sess", "memory.events")
ev_async = async_cg.read("/tenant/sess", "memory.events")
print(f"memory.events: host {ev_host} == async {ev_async}")
assert ev_host == ev_async, "event counters diverged!"
async_cg.backend.close()

print("\n== 1b. backend-specific extras ==")
cg = AgentCgroup(HostTreeBackend(1000))
cg.mkdir("/sess", DomainSpec(high=50))
t = cg.try_charge("/sess", 80)
print(f"host:   memory.events = {cg.read('/sess', 'memory.events')}, "
      f"graduated delay {t.delay_ms:.0f} ms")
dcg = AgentCgroup(DeviceTableBackend(1000, cfg=ControllerConfig()))
idx = dcg.mkdir("/sess", DomainSpec(high=50))
view = dcg.device_view()
st, granted, _ = jax.jit(view.charge)(view.state, jnp.array([idx]),
                                      jnp.array([80], jnp.int32), 0)
print(f"device: in-step charge granted={bool(granted[0])}, "
      f"throttled until step {int(st['throttle_until'][idx])}")

print("\n== 1c. pluggable policy programs (memcg_bpf_ops analogue) ==")
from repro.core.progs import TokenBucketProgram

pcg = AgentCgroup(DeviceTableBackend(1000))
pcg.attach("/", TokenBucketProgram(bucket_capacity=16, refill=(1, 2, 4)))
pcg.mkdir("/agent")
g0 = pcg.try_charge("/agent", 16, step=0).granted    # drains the bucket
g1 = pcg.try_charge("/agent", 16, step=1).granted    # rate-limited
pcg.update_params("/agent", refill_normal=16.0)      # live retune: no re-jit
g2 = pcg.try_charge("/agent", 16, step=2).granted    # refilled at new rate
print(f"token bucket: step0 granted={g0}, step1 granted={g1}, "
      f"after update_params(refill_normal=16) step2 granted={g2}")
assert (g0, g1, g2) == (True, False, True)

print("\n== 2. train a reduced llama3.2 for 10 steps ==")
cfg = dataclasses.replace(reduced(get_config("llama3.2-3b")),
                          dtype="float32")
params = init_params(M.param_schema(cfg), jax.random.PRNGKey(0), cfg.dtype)
perf = perf_replace(DEFAULT_PERF, scan_chunk=32, remat="none")
step = jax.jit(make_train_step(cfg, perf, OptConfig(lr=1e-3, warmup_steps=2,
                                                    total_steps=10)))
opt = init_train_state(cfg, params, perf)
data = DataIterator(cfg, SHAPES["train_4k"], seed=0, batch=4, seq=64)
for i in range(10):
    params, opt, m = step(params, opt, data.at(i), i)
    if i % 3 == 0:
        print(f"  step {i}: loss {float(m['loss']):.3f}")

print("\n== 3. serve two agent sessions under AgentCgroup ==")
eng = Engine(cfg, params, perf=perf_replace(DEFAULT_PERF, scan_chunk=32),
             ecfg=EngineConfig(max_slots=2, s_max=256, pool_pages=24,
                               page_tokens=16, mode="inkernel"))
eng.submit(Session(sid="hi", tenant="t", priority=D.HIGH,
                   prompt=list(range(2, 18)),
                   phases=[Phase(8, 64, "test"), Phase(8, 0)]))
eng.submit(Session(sid="lo", tenant="t", priority=D.LOW,
                   prompt=list(range(2, 18)),
                   phases=[Phase(8, 96, "test"), Phase(8, 0)]))
eng.run(3000)
r = eng.report()
print(f"  survival={r['survival']:.0%} throttles={r['throttle_triggers']} "
      f"freezes={r['freezes']} pool_overshoot={r['overshoot_pages']} pages")
print("\nquickstart done.")
